//! GEMM kernel benchmark: the scalar ikj oracle vs the tiled
//! multithreaded packed kernel (`conv::gemm`) on VGG-sized shapes.
//!
//! Prints the comparison table, verifies bitwise determinism across
//! thread counts, and writes `BENCH_gemm.json` (see `bench::harness::
//! BenchJson`) so the repo's perf trajectory accumulates run over run.
//!
//! ```text
//! cargo bench --bench bench_gemm                 # default scale
//! COCOI_BENCH_SCALE=quick cargo bench --bench bench_gemm
//! ```

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();
    cocoi::bench::experiments::gemm(cocoi::bench::experiments::Scale::from_env())
}
