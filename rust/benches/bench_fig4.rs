//! Regenerates paper Fig. 4: per-conv-layer latency stacks (encode/decode
//! vs worker time), CoCoI vs uncoded, under scenario-1 with λ_tr = 0.5.
fn main() -> anyhow::Result<()> {
    cocoi::bench::experiments::fig4(cocoi::bench::experiments::Scale::from_env())
}
