//! Regenerates paper Fig. 5: end-to-end inference latency vs λ_tr
//! (scenario-1) for all six methods, VGG16 + ResNet18, n = 10.
fn main() -> anyhow::Result<()> {
    cocoi::bench::experiments::fig5(cocoi::bench::experiments::Scale::from_env())
}
