//! Regenerates paper Fig. 10 (App. E): impact of μ/θ (compute and
//! transmission) on the optimal split k* and its approximation k°,
//! plus the §IV-C theory margins (Props. 2–3).
fn main() -> anyhow::Result<()> {
    let scale = cocoi::bench::experiments::Scale::from_env();
    cocoi::bench::experiments::fig10(scale)?;
    cocoi::bench::experiments::theory()
}
