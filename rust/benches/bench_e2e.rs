//! End-to-end serving benchmark: real coded inference on TinyVGG over 6
//! in-process workers — wall-clock latency per scheme, with and without
//! injected faults, through the PJRT provider when artifacts exist.

use std::sync::Arc;

use cocoi::bench::harness::{BenchJson, BenchTimer, Table};
use cocoi::util::json::Json;
use cocoi::conv::Tensor;
use cocoi::coordinator::{
    LocalCluster, MasterConfig, ScenarioFaults, SchemeKind, WorkerFaults,
};
use cocoi::planner::SplitPolicy;
use cocoi::runtime::{ConvProvider, FallbackProvider, Manifest, PjrtProvider, PjrtService};
use cocoi::util::Rng;

fn provider(pool: usize) -> (Arc<dyn ConvProvider>, Option<PjrtService>, &'static str) {
    let dir = cocoi::runtime::artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        let service = PjrtService::spawn().expect("pjrt service");
        let manifest = Arc::new(Manifest::load(&dir).expect("manifest"));
        (
            Arc::new(PjrtProvider::new(service.handle(), manifest)),
            Some(service),
            "pjrt",
        )
    } else {
        // `pool` in-proc workers share this host: split the kernel
        // thread budget so the wall-clock comparison stays clean.
        (Arc::new(FallbackProvider::for_pool(pool)), None, "fallback")
    }
}

fn bench_case(
    provider: Arc<dyn ConvProvider>,
    scheme: SchemeKind,
    faults: Vec<WorkerFaults>,
    iters: usize,
) -> anyhow::Result<f64> {
    let n = faults.len();
    let config = MasterConfig {
        scheme,
        policy: SplitPolicy::Fixed(4),
        ..Default::default()
    };
    let mut cluster = LocalCluster::spawn("tinyvgg", n, config, provider, faults)?;
    let mut rng = Rng::new(5);
    let timer = BenchTimer::new(1, iters);
    let s = timer.run(|| {
        let mut input = Tensor::zeros(3, 56, 56);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let _ = cluster.master.infer(&input).unwrap();
    });
    cluster.shutdown()?;
    Ok(s.mean())
}

fn main() -> anyhow::Result<()> {
    cocoi::util::logger::init();
    let n = 6;
    let (prov, _service, prov_name) = provider(n);
    let iters = 5;

    let mut table = Table::new(
        &format!("E2E: tinyvgg inference wall-clock, n={n}, provider={prov_name}"),
        &["scheme", "healthy", "straggling λ=0.5", "n_f=2 failures"],
    );
    let mut json = BenchJson::new("e2e");
    json.set("provider", Json::Str(prov_name.to_string()));
    json.set_num("workers", n as f64);
    json.set_num("iters", iters as f64);
    for scheme in [SchemeKind::Mds, SchemeKind::Uncoded, SchemeKind::Replication] {
        let healthy = bench_case(
            prov.clone(),
            scheme,
            (0..n).map(|_| WorkerFaults::none()).collect(),
            iters,
        )?;
        let straggle = bench_case(
            prov.clone(),
            scheme,
            ScenarioFaults::straggling(n, 0.5, 0.015),
            iters,
        )?;
        let mut rng = Rng::new(77);
        let failures = bench_case(
            prov.clone(),
            scheme,
            ScenarioFaults::failures(n, 2, 4096, &mut rng),
            iters,
        )?;
        table.row(vec![
            scheme.name().to_string(),
            format!("{:.0}ms", healthy * 1e3),
            format!("{:.0}ms", straggle * 1e3),
            format!("{:.0}ms", failures * 1e3),
        ]);
        json.set(
            scheme.name(),
            Json::obj(vec![
                ("healthy_mean_s", Json::Num(healthy)),
                ("straggle_mean_s", Json::Num(straggle)),
                ("failures_mean_s", Json::Num(failures)),
            ]),
        );
    }
    table.print();
    match json.write() {
        Ok(path) => println!("machine-readable results -> {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_e2e.json: {e:#}"),
    }
    println!(
        "(1-core host: worker compute serializes, so healthy-case distribution \
         shows overhead; the straggle/failure columns show the coded advantage)"
    );

    // -- multi-request throughput: round-barrier vs pipelined engine ----
    // (same driver as `cocoi experiment throughput`, on this bench's
    // larger pool + provider)
    cocoi::bench::experiments::throughput_with(n, prov.clone(), prov_name, 8)?;
    Ok(())
}
