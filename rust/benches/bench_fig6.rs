//! Regenerates paper Fig. 6: latency under device failures (scenario-2)
//! and failures + chronic straggler (scenario-3), n_f ∈ {0, 1, 2}.
fn main() -> anyhow::Result<()> {
    cocoi::bench::experiments::fig6(cocoi::bench::experiments::Scale::from_env())
}
