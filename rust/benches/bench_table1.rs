//! Regenerates paper Table I: k* vs k° statistics (max/avg gap, latency
//! difference) across λ_tr ∈ {0.2, …, 1.0}, VGG16 + ResNet18.
fn main() -> anyhow::Result<()> {
    cocoi::bench::experiments::table1(cocoi::bench::experiments::Scale::from_env())
}
