//! Regenerates paper Fig. 9 (App. D): (a) |k* − k°| heatmap over
//! (μ_tr, μ_cmp), n = 20; (b) actual vs approximate E[T(k)] curves.
fn main() -> anyhow::Result<()> {
    cocoi::bench::experiments::fig9(cocoi::bench::experiments::Scale::from_env())
}
