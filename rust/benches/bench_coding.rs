//! Coding micro-benchmarks: MDS/LT encode + decode throughput on
//! feature-map-sized rows, and the `G_S` inversion. These are the master
//! hot path whose FLOP counts (eqs. 8, 12) the latency model charges.

use cocoi::bench::harness::BenchTimer;
use cocoi::coding::{matrix::Matrix, LtCode, MdsCode, RedundancyScheme};
use cocoi::util::Rng;

fn main() {
    let timer = BenchTimer::new(2, 15);
    let mut rng = Rng::new(1);

    // VGG conv3-ish partition: C_I*H_I*W_I^p = 128*114*21 ≈ 306k floats.
    let row_len = 128 * 114 * 21;
    for (n, k) in [(10usize, 7usize), (10, 5), (6, 4)] {
        let code = MdsCode::new(n, k);
        let sources: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                let mut v = vec![0f32; row_len];
                rng.fill_uniform_f32(&mut v, -1.0, 1.0);
                v
            })
            .collect();

        let mut tasks = Vec::new();
        let s = timer.run(|| {
            tasks = code.encode(&sources);
        });
        let gbps = code.encode_flops(row_len) / s.mean() / 1e9;
        timer.report(
            &format!("mds({n},{k}) encode {row_len} floats [{gbps:.2} GFLOP/s]"),
            &s,
        );

        let subset: Vec<usize> = rng.sample_distinct(n, k);
        let s = timer.run(|| {
            let mut dec = code.decoder();
            for &t in &subset {
                dec.add(tasks[t].id, tasks[t].payload.clone());
            }
            std::hint::black_box(dec.decode().unwrap());
        });
        timer.report(&format!("mds({n},{k}) decode (incl. G_S^-1)"), &s);
    }

    // G_S inversion alone (k ≤ 20 stays trivially cheap — eq. 12 note).
    for k in [5usize, 10, 20] {
        let code = MdsCode::new(k + 2, k);
        let idx: Vec<usize> = (0..k).collect();
        let gs = code.generator().select_rows(&idx);
        let s = timer.run(|| {
            std::hint::black_box(gs.inverse().unwrap());
        });
        timer.report(&format!("vandermonde G_S^-1 (k={k})"), &s);
    }

    // Dense coefficient apply (the decode hot loop).
    for k in [4usize, 8] {
        let coeff = Matrix::identity(k);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| vec![1.0f32; row_len]).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let s = timer.run(|| {
            std::hint::black_box(cocoi::coding::matrix::apply_f32(&coeff, &refs));
        });
        timer.report(&format!("apply_f32 {k}x{k} × {row_len}"), &s);
    }

    // LT encode + rank-k decode at the paper's k_s scale.
    let k = 8;
    let code = LtCode::new(10, k, 99);
    let sources: Vec<Vec<f32>> = (0..k).map(|_| vec![1.0f32; row_len / 4]).collect();
    let mut tasks = Vec::new();
    let s = timer.run(|| {
        tasks = code.encode(&sources);
    });
    timer.report(&format!("lt(k={k}) encode budget={}", code.num_subtasks()), &s);
    let s = timer.run(|| {
        let mut dec = code.decoder();
        for t in &tasks {
            if dec.add(t.id, t.payload.clone()) {
                break;
            }
        }
        std::hint::black_box(dec.decode().unwrap());
    });
    timer.report(&format!("lt(k={k}) decode (rank tracking + solve)"), &s);
}
