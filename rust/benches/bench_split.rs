//! Regenerates paper App. A Fig. 7 (per-layer local latency / conv
//! bottleneck) and App. B Fig. 8 (shift-exponential fit of real measured
//! transmission + compute latencies), plus split/im2col micro-benches.
use cocoi::bench::harness::BenchTimer;
use cocoi::conv::{im2col, ConvSpec, SplitPlan, Tensor};
use cocoi::util::Rng;

fn main() -> anyhow::Result<()> {
    cocoi::bench::experiments::fig7()?;
    cocoi::bench::experiments::fig8()?;

    // Micro: split geometry + im2col on a VGG-scale layer.
    let timer = BenchTimer::new(2, 20);
    let spec = ConvSpec::new(128, 128, 3, 1, 1);
    let mut rng = Rng::new(1);
    let mut input = Tensor::zeros(128, 114, 114);
    rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);

    let s = timer.run(|| {
        let plan = SplitPlan::new(&spec, 114, 7).unwrap();
        std::hint::black_box(&plan);
    });
    timer.report("split_plan(vgg conv3, k=7)", &s);

    let s = timer.run(|| {
        let pieces = SplitPlan::new(&spec, 114, 7)
            .unwrap()
            .in_ranges
            .iter()
            .map(|r| input.slice_w(r.start, r.end))
            .collect::<Vec<_>>();
        std::hint::black_box(&pieces);
    });
    timer.report("slice 7 input partitions (128x114)", &s);

    let piece = input.slice_w(0, 21);
    let s = timer.run(|| {
        std::hint::black_box(im2col::im2col(&piece, 3, 1));
    });
    timer.report("im2col(128x114x21, 3x3)", &s);
    Ok(())
}
