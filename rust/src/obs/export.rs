//! The scrapeable metrics surface: one [`Snapshot`] of counters, gauges,
//! and histograms rendered as Prometheus text exposition *and* as JSON.
//!
//! `obs` stays dependency-free: a snapshot is a flat list of named metric
//! families, and the coordinator layers (`InferenceServer::scrape`,
//! `Master::telemetry_json`) assemble one from their own state. Names
//! follow Prometheus conventions (`cocoi_` prefix, `_total` counters,
//! `_seconds` histograms); [`check_exposition`] is the hard schema check
//! CI runs against every emitted scrape — exactly one `# TYPE` per
//! family, cumulative bucket counts monotone, `_count` matching the
//! `+Inf` bucket.

use anyhow::{bail, Result};

use crate::util::json::Json;

use super::hist::LogHistogram;

#[derive(Clone, Debug)]
struct Family<T> {
    name: String,
    help: String,
    value: T,
}

/// A counter/gauge family whose samples carry one label (e.g. `tenant`):
/// one `# TYPE`, one sample line per label value. Histograms stay
/// unlabelled — per-label bucket series would break the per-family
/// monotonicity walk in [`check_exposition`]; labelled quantile *gauges*
/// carry the per-tenant latency signal instead.
#[derive(Clone, Debug)]
struct LabelledFamily {
    name: String,
    help: String,
    /// `counter` or `gauge` (the `# TYPE` token).
    kind: &'static str,
    /// Label key, e.g. `tenant`.
    label: String,
    /// `(label value, sample)` pairs, emitted in the given order.
    samples: Vec<(String, f64)>,
}

/// One coherent scrape of the system: counters, gauges, histograms.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    counters: Vec<Family<f64>>,
    gauges: Vec<Family<f64>>,
    hists: Vec<Family<LogHistogram>>,
    labelled: Vec<LabelledFamily>,
}

impl Snapshot {
    pub fn new() -> Snapshot {
        Snapshot::default()
    }

    /// Add a monotone counter family (name should end in `_total`).
    pub fn counter(&mut self, name: &str, help: &str, value: f64) -> &mut Snapshot {
        self.counters.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            value,
        });
        self
    }

    /// Add a gauge family (instantaneous value).
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) -> &mut Snapshot {
        self.gauges.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            value,
        });
        self
    }

    /// Add a histogram family (name should end in `_seconds`).
    pub fn histogram(&mut self, name: &str, help: &str, hist: &LogHistogram) -> &mut Snapshot {
        self.hists.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            value: hist.clone(),
        });
        self
    }

    /// Add a counter family with one sample per `label` value.
    pub fn labelled_counter(
        &mut self,
        name: &str,
        help: &str,
        label: &str,
        samples: Vec<(String, f64)>,
    ) -> &mut Snapshot {
        self.labelled.push(LabelledFamily {
            name: name.to_string(),
            help: help.to_string(),
            kind: "counter",
            label: label.to_string(),
            samples,
        });
        self
    }

    /// Add a gauge family with one sample per `label` value.
    pub fn labelled_gauge(
        &mut self,
        name: &str,
        help: &str,
        label: &str,
        samples: Vec<(String, f64)>,
    ) -> &mut Snapshot {
        self.labelled.push(LabelledFamily {
            name: name.to_string(),
            help: help.to_string(),
            kind: "gauge",
            label: label.to_string(),
            samples,
        });
        self
    }

    /// Prometheus text exposition format (version 0.0.4).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for f in &self.counters {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} counter\n", f.name));
            out.push_str(&format!("{} {}\n", f.name, fmt_num(f.value)));
        }
        for f in &self.gauges {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} gauge\n", f.name));
            out.push_str(&format!("{} {}\n", f.name, fmt_num(f.value)));
        }
        for f in &self.labelled {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} {}\n", f.name, f.kind));
            for (lv, v) in &f.samples {
                out.push_str(&format!(
                    "{}{{{}=\"{}\"}} {}\n",
                    f.name,
                    f.label,
                    lv,
                    fmt_num(*v)
                ));
            }
        }
        for f in &self.hists {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} histogram\n", f.name));
            for (le, cum) in f.value.cumulative_buckets() {
                out.push_str(&format!("{}_bucket{{le=\"{}\"}} {}\n", f.name, fmt_num(le), cum));
            }
            out.push_str(&format!("{}_bucket{{le=\"+Inf\"}} {}\n", f.name, f.value.count()));
            out.push_str(&format!("{}_sum {}\n", f.name, fmt_num(f.value.sum())));
            out.push_str(&format!("{}_count {}\n", f.name, f.value.count()));
        }
        out
    }

    /// The same snapshot as JSON (quantile summaries instead of buckets).
    pub fn to_json(&self) -> Json {
        let fam = |fs: &[Family<f64>]| -> Json {
            Json::obj(fs.iter().map(|f| (f.name.as_str(), Json::Num(f.value))).collect())
        };
        Json::obj(vec![
            ("counters", fam(&self.counters)),
            ("gauges", fam(&self.gauges)),
            (
                "histograms",
                Json::obj(
                    self.hists
                        .iter()
                        .map(|f| (f.name.as_str(), f.value.to_json()))
                        .collect(),
                ),
            ),
            (
                "labelled",
                Json::obj(
                    self.labelled
                        .iter()
                        .map(|f| {
                            (
                                f.name.as_str(),
                                Json::obj(
                                    f.samples
                                        .iter()
                                        .map(|(lv, v)| (lv.as_str(), Json::Num(*v)))
                                        .collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Family names in emit order (tests pin stability against this).
    pub fn family_names(&self) -> Vec<String> {
        self.counters
            .iter()
            .map(|f| f.name.clone())
            .chain(self.gauges.iter().map(|f| f.name.clone()))
            .chain(self.labelled.iter().map(|f| f.name.clone()))
            .chain(self.hists.iter().map(|f| f.name.clone()))
            .collect()
    }
}

/// Render a float the exposition way: integers without a fraction, other
/// values in shortest round-trip form.
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Hard schema check for an emitted exposition: every sample line belongs
/// to a family with exactly one `# TYPE`, histogram bucket counts are
/// cumulative-monotone with ascending `le` edges, and `_count` equals the
/// `+Inf` bucket. Returns the number of families on success.
pub fn check_exposition(text: &str) -> Result<usize> {
    use std::collections::BTreeMap;
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (Some(name), Some(ty)) = (it.next(), it.next()) else {
                bail!("malformed TYPE line: {line}");
            };
            if types.insert(name.to_string(), ty.to_string()).is_some() {
                bail!("duplicate # TYPE for family {name}");
            }
        }
    }
    if types.is_empty() {
        bail!("no # TYPE lines");
    }
    // Histogram structure: walk buckets per family.
    for (name, ty) in types.iter().filter(|(_, t)| t.as_str() == "histogram") {
        let bucket_prefix = format!("{name}_bucket{{le=\"");
        let mut last_le = f64::NEG_INFINITY;
        let mut last_cum: i64 = -1;
        let mut inf_count: Option<i64> = None;
        let mut count: Option<i64> = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix(&bucket_prefix) {
                let Some((le_str, cnt_str)) = rest.split_once("\"}") else {
                    bail!("malformed bucket line: {line}");
                };
                let cum: i64 = cnt_str.trim().parse()?;
                if le_str == "+Inf" {
                    inf_count = Some(cum);
                } else {
                    let le: f64 = le_str.parse()?;
                    if le <= last_le {
                        bail!("{name}: bucket edges not ascending at le={le}");
                    }
                    last_le = le;
                }
                if cum < last_cum {
                    bail!("{name}: bucket counts not monotone at {line}");
                }
                last_cum = cum;
            } else if let Some(rest) = line.strip_prefix(&format!("{name}_count ")) {
                count = Some(rest.trim().parse()?);
            }
        }
        match (inf_count, count) {
            (Some(i), Some(c)) if i == c => {}
            (i, c) => bail!("{name}: +Inf bucket {i:?} != _count {c:?}"),
        }
    }
    // Every sample line's family must have a TYPE.
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some(metric) = line.split([' ', '{']).next() else {
            continue;
        };
        let family = metric
            .strip_suffix("_bucket")
            .or_else(|| metric.strip_suffix("_sum"))
            .or_else(|| metric.strip_suffix("_count"))
            .unwrap_or(metric);
        if !types.contains_key(family) && !types.contains_key(metric) {
            bail!("sample {metric} has no # TYPE");
        }
    }
    Ok(types.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Snapshot {
        let mut h = LogHistogram::new();
        for i in 1..=50 {
            h.record(i as f64 * 1e-3);
        }
        let mut s = Snapshot::new();
        s.counter("cocoi_requests_submitted_total", "Requests accepted.", 50.0)
            .gauge("cocoi_pool_members", "Current pool size.", 4.0)
            .histogram("cocoi_sojourn_seconds", "End-to-end sojourn.", &h);
        s
    }

    #[test]
    fn exposition_passes_schema_check() {
        let s = demo();
        let text = s.to_prometheus();
        assert!(text.contains("# TYPE cocoi_requests_submitted_total counter"));
        assert!(text.contains("# TYPE cocoi_sojourn_seconds histogram"));
        assert!(text.contains("cocoi_sojourn_seconds_bucket{le=\"+Inf\"} 50"));
        assert!(text.contains("cocoi_sojourn_seconds_count 50"));
        assert_eq!(check_exposition(&text).unwrap(), 3);
    }

    #[test]
    fn schema_check_rejects_duplicates_and_tears() {
        let s = demo();
        let good = s.to_prometheus();
        let dup = format!("{good}# TYPE cocoi_pool_members gauge\n");
        assert!(check_exposition(&dup).is_err(), "duplicate TYPE accepted");
        let untyped = format!("{good}mystery_metric 3\n");
        assert!(check_exposition(&untyped).is_err(), "untyped sample accepted");
        let torn = good.replace("cocoi_sojourn_seconds_count 50", "cocoi_sojourn_seconds_count 49");
        assert!(check_exposition(&torn).is_err(), "+Inf/_count mismatch accepted");
        assert!(check_exposition("").is_err(), "empty scrape accepted");
    }

    #[test]
    fn labelled_families_pass_schema_check() {
        let mut s = demo();
        s.labelled_counter(
            "cocoi_tenant_submitted_total",
            "Per-tenant submissions.",
            "tenant",
            vec![("alpha".to_string(), 3.0), ("beta".to_string(), 1.0)],
        )
        .labelled_gauge(
            "cocoi_tenant_open_requests",
            "Per-tenant open requests.",
            "tenant",
            vec![("alpha".to_string(), 2.0)],
        );
        let text = s.to_prometheus();
        assert!(text.contains("# TYPE cocoi_tenant_submitted_total counter"));
        assert!(text.contains("cocoi_tenant_submitted_total{tenant=\"alpha\"} 3"));
        assert!(text.contains("cocoi_tenant_submitted_total{tenant=\"beta\"} 1"));
        assert!(text.contains("cocoi_tenant_open_requests{tenant=\"alpha\"} 2"));
        // 3 demo families + 2 labelled; one TYPE per family even with
        // multiple samples.
        assert_eq!(check_exposition(&text).unwrap(), 5);
        let j = s.to_json();
        assert_eq!(
            j.get("labelled").get("cocoi_tenant_submitted_total").req_f64("alpha").unwrap(),
            3.0
        );
        assert_eq!(s.family_names().len(), 5);
    }

    #[test]
    fn json_mirror_has_all_families() {
        let s = demo();
        let j = s.to_json();
        assert_eq!(
            j.get("counters").req_f64("cocoi_requests_submitted_total").unwrap(),
            50.0
        );
        assert_eq!(j.get("gauges").req_f64("cocoi_pool_members").unwrap(), 4.0);
        let h = j.get("histograms").get("cocoi_sojourn_seconds");
        assert_eq!(h.req_f64("count").unwrap(), 50.0);
        assert_eq!(s.family_names().len(), 3);
    }
}
