//! Observability: per-request span tracing, mergeable latency histograms,
//! and the scrapeable metrics surface.
//!
//! * [`hist`] — log-bucketed mergeable histograms (constant memory,
//!   ~4.4% quantile relative error, exact count/sum/min/max).
//! * [`trace`] — bounded per-request span trees + pool-level events,
//!   exportable as Chrome trace-event JSON (Perfetto) or compact text.
//! * [`export`] — the [`export::Snapshot`] rendered as Prometheus text
//!   exposition and JSON, plus the hard schema check CI runs on scrapes.
//!
//! [`MetricsHub`] is the always-on recording surface the master, engine,
//! and server all write through: one mutex-guarded set of histograms and
//! gauges, cloned (`Arc`) into whichever thread stamps the `Instant`.
//! Tracing, by contrast, is opt-in (`MasterConfig::trace`) and costs one
//! `Option` branch when off.

pub mod export;
pub mod hist;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

use hist::LogHistogram;

/// Instantaneous pool/engine gauges mirrored into the scrape.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauges {
    pub members: usize,
    pub healthy: usize,
    pub round: u64,
    pub hedges: u64,
    pub fallbacks: u64,
    pub retries: u64,
    pub cancels: u64,
    pub plan_switches: u64,
    /// Heartbeats whose `seq` regressed vs the worker's last-seen seq —
    /// a zombie half-open link replaying stale beacons.
    pub hb_regressions: u64,
}

/// Per-tenant serving meters: admission counters plus the sojourn
/// histogram behind the tenant-labelled scrape families and the
/// `telemetry_json` per-tenant latency summaries.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub submitted: u64,
    pub completed: u64,
    /// Submissions refused by the per-tenant admission quota.
    pub quota_rejections: u64,
    /// Admitted but not yet delivered.
    pub open: u64,
    /// Submit → delivery for this tenant's requests.
    pub sojourn: LogHistogram,
}

/// The histogram set every latency-stamping layer records into. Field per
/// phase rather than a name-keyed map: call sites stay `grep`-able and the
/// scrape's family list stays stable.
#[derive(Clone, Debug, Default)]
pub struct HubInner {
    /// Submit → engine admission (server queue wait).
    pub queue_wait: LogHistogram,
    /// Submit → delivery (end-to-end sojourn).
    pub sojourn: LogHistogram,
    /// Per-distributed-layer phase times (one sample per layer execution).
    pub t_split: LogHistogram,
    pub t_encode: LogHistogram,
    pub t_workers: LogHistogram,
    pub t_decode: LogHistogram,
    pub t_local: LogHistogram,
    /// Hedge raced and the *backup* replied first: time from hedge
    /// dispatch to the winning reply (what the hedge bought).
    pub hedge_win: LogHistogram,
    /// Hedge raced and the *primary* replied first: time from hedge
    /// dispatch to that reply (what the hedge cost, wasted work).
    pub hedge_loss: LogHistogram,
    /// Local-fallback shard compute: last dispatch → local result ready.
    pub fallback_latency: LogHistogram,
    pub gauges: PoolGauges,
    /// Per-tenant meters, keyed by tenant id (BTreeMap: the scrape's
    /// label order stays deterministic). Empty until tenant-attributed
    /// traffic flows; the 5 `cocoi_tenant_*` families appear with it.
    pub tenants: BTreeMap<String, TenantStats>,
}

impl HubInner {
    /// The per-tenant meter row, created on first touch.
    pub fn tenant(&mut self, name: &str) -> &mut TenantStats {
        self.tenants.entry(name.to_string()).or_default()
    }
}

/// Shared, thread-safe metrics recording surface. Cheap to clone.
#[derive(Clone, Debug, Default)]
pub struct MetricsHub {
    inner: Arc<Mutex<HubInner>>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    /// Lock the hub for recording or reading. Holds are short — a few
    /// `record` calls — and only ever taken from coordinator threads.
    pub fn lock(&self) -> MutexGuard<'_, HubInner> {
        self.inner.lock().unwrap()
    }

    /// Poison-tolerant lock for panic-path bookkeeping (the engine's
    /// unwind guard zeroes per-tenant open counts through this — the
    /// panic may have happened while a recorder held the hub).
    pub fn lock_recover(&self) -> MutexGuard<'_, HubInner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Deep-copied snapshot for export (scrape builds run unlocked).
    pub fn snapshot(&self) -> HubInner {
        self.lock().clone()
    }

    /// Fill an [`export::Snapshot`] with this hub's histogram + gauge
    /// families under stable `cocoi_`-prefixed names.
    pub fn export_into(&self, snap: &mut export::Snapshot) {
        let h = self.snapshot();
        let g = h.gauges;
        snap.gauge("cocoi_pool_members", "Current worker pool size.", g.members as f64)
            .gauge("cocoi_pool_healthy", "Non-quarantined pool members.", g.healthy as f64)
            .gauge("cocoi_round", "Latest dispatch round id.", g.round as f64)
            .counter("cocoi_hedges_total", "Watchdog hedges fired.", g.hedges as f64)
            .counter(
                "cocoi_fallbacks_total",
                "Shards computed by master-local fallback.",
                g.fallbacks as f64,
            )
            .counter("cocoi_retries_total", "Subtask retry dispatches.", g.retries as f64)
            .counter(
                "cocoi_cancels_total",
                "Straggler subtasks cancelled after decode.",
                g.cancels as f64,
            )
            .counter(
                "cocoi_plan_switches_total",
                "Adaptive replanner (n, k) switches.",
                g.plan_switches as f64,
            )
            .counter(
                "cocoi_heartbeat_regressions_total",
                "Heartbeats with a regressed seq (stale-beacon replay).",
                g.hb_regressions as f64,
            );
        if !h.tenants.is_empty() {
            let col = |f: &dyn Fn(&TenantStats) -> f64| -> Vec<(String, f64)> {
                h.tenants.iter().map(|(t, s)| (t.clone(), f(s))).collect()
            };
            snap.labelled_counter(
                "cocoi_tenant_submitted_total",
                "Per-tenant requests accepted by submit().",
                "tenant",
                col(&|s| s.submitted as f64),
            )
            .labelled_counter(
                "cocoi_tenant_completed_total",
                "Per-tenant requests delivered successfully.",
                "tenant",
                col(&|s| s.completed as f64),
            )
            .labelled_counter(
                "cocoi_tenant_quota_rejections_total",
                "Per-tenant submissions refused by the admission quota.",
                "tenant",
                col(&|s| s.quota_rejections as f64),
            )
            .labelled_gauge(
                "cocoi_tenant_open_requests",
                "Per-tenant admitted-but-undelivered requests.",
                "tenant",
                col(&|s| s.open as f64),
            )
            .labelled_gauge(
                "cocoi_tenant_sojourn_p95_seconds",
                "Per-tenant p95 submit-to-delivery sojourn.",
                "tenant",
                col(&|s| if s.sojourn.count() == 0 { 0.0 } else { s.sojourn.quantile(0.95) }),
            );
        }
        let hists: [(&str, &str, &LogHistogram); 10] = [
            ("cocoi_queue_wait_seconds", "Submit to engine admission.", &h.queue_wait),
            ("cocoi_sojourn_seconds", "Submit to delivery, end to end.", &h.sojourn),
            ("cocoi_layer_split_seconds", "Per-layer input split time.", &h.t_split),
            ("cocoi_layer_encode_seconds", "Per-layer encode time.", &h.t_encode),
            (
                "cocoi_layer_workers_seconds",
                "Per-layer dispatch to k-th useful reply.",
                &h.t_workers,
            ),
            ("cocoi_layer_decode_seconds", "Per-layer decode time.", &h.t_decode),
            ("cocoi_layer_local_seconds", "Per-layer master-local work.", &h.t_local),
            (
                "cocoi_hedge_win_seconds",
                "Hedge dispatch to winning backup reply.",
                &h.hedge_win,
            ),
            (
                "cocoi_hedge_loss_seconds",
                "Hedge dispatch to primary reply that beat it.",
                &h.hedge_loss,
            ),
            (
                "cocoi_fallback_seconds",
                "Last dispatch to local fallback shard ready.",
                &h.fallback_latency,
            ),
        ];
        for (name, help, hist) in hists {
            snap.histogram(name, help, hist);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_records_and_exports_stable_names() {
        let hub = MetricsHub::new();
        {
            let mut h = hub.lock();
            h.sojourn.record(0.25);
            h.queue_wait.record(0.01);
            h.hedge_win.record(0.05);
            h.gauges.members = 4;
            h.gauges.hedges = 2;
        }
        // With no tenant traffic yet: 9 counters/gauges + 10 histograms.
        let mut pre = export::Snapshot::new();
        hub.export_into(&mut pre);
        assert_eq!(export::check_exposition(&pre.to_prometheus()).unwrap(), 19);
        {
            let mut h = hub.lock();
            let t = h.tenant("alpha");
            t.submitted = 3;
            t.completed = 2;
            t.open = 1;
            t.sojourn.record(0.2);
            h.tenant("beta").quota_rejections = 1;
            h.gauges.hb_regressions = 1;
        }
        let mut snap = export::Snapshot::new();
        hub.export_into(&mut snap);
        let text = snap.to_prometheus();
        // + the 5 tenant-labelled families once tenants exist.
        assert_eq!(export::check_exposition(&text).unwrap(), 24);
        assert!(text.contains("cocoi_pool_members 4"));
        assert!(text.contains("cocoi_hedges_total 2"));
        assert!(text.contains("cocoi_heartbeat_regressions_total 1"));
        assert!(text.contains("cocoi_sojourn_seconds_count 1"));
        assert!(text.contains("cocoi_hedge_win_seconds_count 1"));
        assert!(text.contains("cocoi_tenant_submitted_total{tenant=\"alpha\"} 3"));
        assert!(text.contains("cocoi_tenant_quota_rejections_total{tenant=\"beta\"} 1"));
        assert!(text.contains("cocoi_tenant_open_requests{tenant=\"alpha\"} 1"));
        // A second export sees the same family list (stability).
        let mut snap2 = export::Snapshot::new();
        hub.export_into(&mut snap2);
        assert_eq!(snap.family_names(), snap2.family_names());
    }
}
