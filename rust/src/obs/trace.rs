//! Bounded per-request span tracing.
//!
//! A [`TraceHandle`] records one span *tree* per request — root `request`
//! span, `queue-wait` child, one `round` span per distributed layer
//! execution, one `subtask` span per dispatch→reply — plus instant events
//! (hedge fired/won/lost, retry, cancel, local fallback, shed) and a small
//! global side ring for pool-level happenings (membership changes, worker
//! slot occupancy). Memory is fixed: when the total recorded span+event
//! count exceeds the configured capacity, the *oldest completed request's
//! whole tree* is dropped — a tree is never torn, and open (in-flight)
//! requests are never evicted.
//!
//! All timestamps are monotonic (`Instant`s against a shared epoch taken
//! at handle creation), so spans recorded on different threads — the
//! server front-end, the engine thread, in-proc worker slots — land on one
//! consistent timeline. Export targets:
//!
//! * **Chrome trace-event JSON** (`export_chrome`) — load the file in
//!   Perfetto (ui.perfetto.dev) or `chrome://tracing`. Request trees
//!   render as pid 1 with one track per request; worker slot spans render
//!   as pid 2 with one track per worker.
//! * **Compact text** (`export_text`) — an indented tree per request for
//!   terminals and test assertions.
//!
//! Tracing is opt-in and the hot path pays only an `Option` branch when
//! off: every emit site in the engine/server/worker is guarded by
//! `if let Some(trace) = ...`. A global allocation counter
//! ([`spans_allocated`]) lets tests pin the zero-cost-off property.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Spans + instant events allocated process-wide (all handles). Tests use
/// the delta across a run to pin that tracing-off allocates nothing.
static SPANS_ALLOCATED: AtomicU64 = AtomicU64::new(0);

/// Process-wide span/event allocation counter (monotone).
pub fn spans_allocated() -> u64 {
    SPANS_ALLOCATED.load(Ordering::Relaxed)
}

/// One closed-or-open span in a request tree (times in µs since epoch).
#[derive(Clone, Debug)]
pub struct Span {
    pub id: u64,
    pub parent: Option<u64>,
    pub name: String,
    pub worker: Option<usize>,
    pub start_us: f64,
    pub end_us: Option<f64>,
}

/// One instant event (µs since epoch).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    pub worker: Option<usize>,
    pub ts_us: f64,
    /// Optional latency payload (seconds) — e.g. hedge win margin.
    pub value: Option<f64>,
}

/// One request's span tree plus its instant events.
#[derive(Clone, Debug, Default)]
pub struct RequestTrace {
    pub request: u64,
    pub spans: Vec<Span>,
    pub events: Vec<TraceEvent>,
    pub done: bool,
}

impl RequestTrace {
    fn weight(&self) -> usize {
        self.spans.len() + self.events.len()
    }

    pub fn open_spans(&self) -> usize {
        self.spans.iter().filter(|s| s.end_us.is_none()).count()
    }
}

#[derive(Debug, Default)]
struct TraceBuf {
    requests: BTreeMap<u64, RequestTrace>,
    /// Completed request ids in completion order (eviction queue).
    completed: Vec<u64>,
    /// Pool-level spans (worker slot occupancy), bounded separately.
    pool_spans: Vec<Span>,
    /// Pool-level instant events (membership), bounded separately.
    pool_events: Vec<TraceEvent>,
    /// Spans+events across all request trees (pool entries not counted —
    /// they have their own fixed share).
    total_weight: usize,
    dropped_requests: u64,
    next_span_id: u64,
    /// Well-formedness violations (closed twice, child of a dead parent,
    /// emit on an unknown request) — empty in a correct integration.
    violations: Vec<String>,
}

impl TraceBuf {
    fn alloc_id(&mut self) -> u64 {
        self.next_span_id += 1;
        self.next_span_id
    }

    /// Drop oldest completed trees while the total weight exceeds `cap`.
    /// Open trees are never touched, so a tree is never torn mid-flight.
    fn evict(&mut self, cap: usize) {
        while self.total_weight > cap && !self.completed.is_empty() {
            let victim = self.completed.remove(0);
            if let Some(rt) = self.requests.remove(&victim) {
                self.total_weight -= rt.weight();
                self.dropped_requests += 1;
            }
        }
    }
}

/// Shared, thread-safe trace recorder. Cheap to clone; all emits take one
/// short mutex hold on the handful of traced runs that opt in.
#[derive(Clone, Debug)]
pub struct TraceHandle {
    epoch: Instant,
    cap: usize,
    buf: Arc<Mutex<TraceBuf>>,
}

impl TraceHandle {
    /// A recorder bounded at `cap` total spans+events (min 64).
    pub fn new(cap: usize) -> TraceHandle {
        TraceHandle {
            epoch: Instant::now(),
            cap: cap.max(64),
            buf: Arc::new(Mutex::new(TraceBuf::default())),
        }
    }

    /// Microseconds since the handle's epoch for an explicit instant —
    /// back-dating support (e.g. a queue-wait span whose start was stamped
    /// before admission).
    pub fn us_of(&self, t: Instant) -> f64 {
        t.saturating_duration_since(self.epoch).as_secs_f64() * 1e6
    }

    /// Open a request tree with its root `request` span. Returns the root
    /// span id (parent for the request's children).
    pub fn begin_request(&self, request: u64, start: Instant) -> u64 {
        let start_us = self.us_of(start);
        let mut b = self.buf.lock().unwrap();
        let id = b.alloc_id();
        let rt = b.requests.entry(request).or_default();
        rt.request = request;
        rt.spans.push(Span {
            id,
            parent: None,
            name: "request".to_string(),
            worker: None,
            start_us,
            end_us: None,
        });
        b.total_weight += 1;
        SPANS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
        id
    }

    /// Open a child span in a request tree. The parent must exist and be
    /// open (violation logged otherwise). Returns the new span id.
    pub fn span_start(
        &self,
        request: u64,
        parent: u64,
        name: &str,
        worker: Option<usize>,
        start: Instant,
    ) -> u64 {
        let start_us = self.us_of(start);
        let mut b = self.buf.lock().unwrap();
        let id = b.alloc_id();
        // Parent liveness check first (immutable), then the mutation —
        // keeps the borrow checker and the violation log both happy.
        let parent_open = b
            .requests
            .get(&request)
            .map(|rt| rt.spans.iter().find(|s| s.id == parent).map(|s| s.end_us.is_none()));
        match parent_open {
            None => {
                b.violations.push(format!("span {name}: unknown request {request}"));
                return id;
            }
            Some(None) => b.violations.push(format!("span {name}: parent {parent} missing")),
            Some(Some(false)) => {
                b.violations.push(format!("span {name}: parent {parent} already closed"))
            }
            Some(Some(true)) => {}
        }
        if let Some(rt) = b.requests.get_mut(&request) {
            rt.spans.push(Span {
                id,
                parent: Some(parent),
                name: name.to_string(),
                worker,
                start_us,
                end_us: None,
            });
        }
        b.total_weight += 1;
        SPANS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
        id
    }

    /// Close a span opened by [`span_start`] / [`begin_request`].
    pub fn span_end(&self, request: u64, span: u64, end: Instant) {
        let end_us = self.us_of(end);
        let mut b = self.buf.lock().unwrap();
        enum Outcome {
            Ok,
            ClosedTwice,
            NoSpan,
            NoRequest,
        }
        let outcome = match b.requests.get_mut(&request) {
            None => Outcome::NoRequest,
            Some(rt) => match rt.spans.iter_mut().find(|s| s.id == span) {
                Some(s) if s.end_us.is_none() => {
                    s.end_us = Some(end_us.max(s.start_us));
                    Outcome::Ok
                }
                Some(_) => Outcome::ClosedTwice,
                None => Outcome::NoSpan,
            },
        };
        match outcome {
            Outcome::Ok => {}
            Outcome::ClosedTwice => b.violations.push(format!("span {span}: closed twice")),
            Outcome::NoSpan => b.violations.push(format!("span_end: unknown span {span}")),
            Outcome::NoRequest => {
                b.violations.push(format!("span_end: unknown request {request}"))
            }
        }
    }

    /// Record an already-closed span (convenience for spans measured with
    /// two stamps in hand, e.g. a subtask dispatch→reply window).
    pub fn span_closed(
        &self,
        request: u64,
        parent: u64,
        name: &str,
        worker: Option<usize>,
        start: Instant,
        end: Instant,
    ) -> u64 {
        let id = self.span_start(request, parent, name, worker, start);
        self.span_end(request, id, end);
        id
    }

    /// Record an instant event in a request tree.
    pub fn instant(
        &self,
        request: u64,
        name: &str,
        worker: Option<usize>,
        value: Option<f64>,
        at: Instant,
    ) {
        let ts_us = self.us_of(at);
        let mut b = self.buf.lock().unwrap();
        let known = b.requests.contains_key(&request);
        if known {
            let rt = b.requests.get_mut(&request).expect("checked above");
            rt.events.push(TraceEvent { name: name.to_string(), worker, ts_us, value });
            b.total_weight += 1;
            SPANS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
        } else {
            b.violations.push(format!("instant {name}: unknown request {request}"));
        }
    }

    /// Close a request's root span, mark its tree complete, and run the
    /// eviction sweep (drop oldest *completed* trees while over capacity).
    pub fn end_request(&self, request: u64, root: u64, end: Instant) {
        let end_us = self.us_of(end);
        let mut b = self.buf.lock().unwrap();
        let known = b.requests.contains_key(&request);
        if !known {
            b.violations.push(format!("end_request: unknown request {request}"));
            return;
        }
        let rt = b.requests.get_mut(&request).expect("checked above");
        // Close the root and any straggler children still open (a shed or
        // engine-death delivery can leave a round span open — closing at
        // the request boundary keeps the tree well-formed by construction).
        for s in rt.spans.iter_mut() {
            if s.end_us.is_none() && (s.id == root || s.parent.is_some()) {
                s.end_us = Some(end_us.max(s.start_us));
            }
        }
        rt.done = true;
        b.completed.push(request);
        let cap = self.cap;
        b.evict(cap);
    }

    /// Record a pool-level (non-request) span, e.g. worker slot occupancy.
    /// Pool spans keep a fixed share of the capacity to themselves.
    pub fn pool_span(&self, name: &str, worker: Option<usize>, start: Instant, end: Instant) {
        let (start_us, end_us) = (self.us_of(start), self.us_of(end));
        let mut b = self.buf.lock().unwrap();
        let id = b.alloc_id();
        b.pool_spans.push(Span {
            id,
            parent: None,
            name: name.to_string(),
            worker,
            start_us,
            end_us: Some(end_us.max(start_us)),
        });
        SPANS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
        let share = (self.cap / 4).max(64);
        let len = b.pool_spans.len();
        if len > share {
            b.pool_spans.drain(..len - share);
        }
    }

    /// Record a pool-level instant event (membership: joined/evicted/...).
    pub fn pool_instant(&self, name: &str, worker: Option<usize>, at: Instant) {
        let ts_us = self.us_of(at);
        let mut b = self.buf.lock().unwrap();
        b.pool_events.push(TraceEvent { name: name.to_string(), worker, ts_us, value: None });
        SPANS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
        let share = (self.cap / 4).max(64);
        let len = b.pool_events.len();
        if len > share {
            b.pool_events.drain(..len - share);
        }
    }

    /// Snapshot of the currently-held request trees, ascending request id.
    pub fn requests(&self) -> Vec<RequestTrace> {
        self.buf.lock().unwrap().requests.values().cloned().collect()
    }

    /// Well-formedness violations recorded so far (empty when correct).
    pub fn violations(&self) -> Vec<String> {
        self.buf.lock().unwrap().violations.clone()
    }

    /// Whole trees dropped by the capacity sweep.
    pub fn dropped_requests(&self) -> u64 {
        self.buf.lock().unwrap().dropped_requests
    }

    /// Total spans+events currently held across request trees.
    pub fn weight(&self) -> usize {
        self.buf.lock().unwrap().total_weight
    }

    /// Chrome trace-event JSON (`{"traceEvents": [...]}`): request trees
    /// as pid 1 / tid = request id, pool spans as pid 2 / tid = worker id.
    /// Timestamps and durations in µs as the format requires.
    pub fn export_chrome(&self) -> Json {
        let b = self.buf.lock().unwrap();
        let mut evs: Vec<Json> = Vec::new();
        evs.push(meta_event(1.0, 0.0, "requests"));
        evs.push(meta_event(2.0, 0.0, "worker-pool"));
        for rt in b.requests.values() {
            let tid = rt.request as f64;
            for s in &rt.spans {
                let dur = s.end_us.unwrap_or(s.start_us) - s.start_us;
                let mut args = vec![("request", Json::Num(rt.request as f64))];
                if let Some(w) = s.worker {
                    args.push(("worker", Json::Num(w as f64)));
                }
                evs.push(Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("ph", Json::Str("X".to_string())),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(tid)),
                    ("ts", Json::Num(s.start_us)),
                    ("dur", Json::Num(dur)),
                    ("args", Json::obj(args)),
                ]));
            }
            for e in &rt.events {
                evs.push(instant_event(1.0, tid, e, Some(rt.request)));
            }
        }
        for s in &b.pool_spans {
            let tid = s.worker.unwrap_or(0) as f64;
            evs.push(Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("ph", Json::Str("X".to_string())),
                ("pid", Json::Num(2.0)),
                ("tid", Json::Num(tid)),
                ("ts", Json::Num(s.start_us)),
                ("dur", Json::Num(s.end_us.unwrap_or(s.start_us) - s.start_us)),
                ("args", Json::obj(vec![])),
            ]));
        }
        for e in &b.pool_events {
            evs.push(instant_event(2.0, e.worker.unwrap_or(0) as f64, e, None));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(evs)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ])
    }

    /// Compact indented text, one tree per request.
    pub fn export_text(&self) -> String {
        let b = self.buf.lock().unwrap();
        let mut out = String::new();
        for rt in b.requests.values() {
            out.push_str(&format!(
                "request {} ({}{} spans, {} events)\n",
                rt.request,
                if rt.done { "" } else { "open, " },
                rt.spans.len(),
                rt.events.len()
            ));
            walk_text(&mut out, &rt.spans, None, 0);
            for e in &rt.events {
                let worker = e.worker.map(|w| format!(" w{w}")).unwrap_or_default();
                let val = e.value.map(|v| format!(" {:.3} ms", v * 1e3)).unwrap_or_default();
                out.push_str(&format!("  ! {}{}{}\n", e.name, worker, val));
            }
        }
        out
    }
}

fn walk_text(out: &mut String, spans: &[Span], parent: Option<u64>, depth: usize) {
    for s in spans.iter().filter(|s| s.parent == parent) {
        let worker = s.worker.map(|w| format!(" w{w}")).unwrap_or_default();
        match s.end_us {
            Some(e) => out.push_str(&format!(
                "{:indent$}{}{} {:.3} ms\n",
                "",
                s.name,
                worker,
                (e - s.start_us) / 1e3,
                indent = 2 + depth * 2
            )),
            None => out.push_str(&format!(
                "{:indent$}{}{} (open)\n",
                "",
                s.name,
                worker,
                indent = 2 + depth * 2
            )),
        }
        walk_text(out, spans, Some(s.id), depth + 1);
    }
}

fn meta_event(pid: f64, tid: f64, process: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str("process_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid)),
        ("tid", Json::Num(tid)),
        ("args", Json::obj(vec![("name", Json::Str(process.to_string()))])),
    ])
}

fn instant_event(pid: f64, tid: f64, e: &TraceEvent, request: Option<u64>) -> Json {
    let mut args = Vec::new();
    if let Some(r) = request {
        args.push(("request", Json::Num(r as f64)));
    }
    if let Some(w) = e.worker {
        args.push(("worker", Json::Num(w as f64)));
    }
    if let Some(v) = e.value {
        args.push(("seconds", Json::Num(v)));
    }
    Json::obj(vec![
        ("name", Json::Str(e.name.clone())),
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("t".to_string())),
        ("pid", Json::Num(pid)),
        ("tid", Json::Num(tid)),
        ("ts", Json::Num(e.ts_us)),
        ("args", Json::obj(args)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn now() -> Instant {
        Instant::now()
    }

    #[test]
    fn span_tree_records_and_closes() {
        let tr = TraceHandle::new(1024);
        let t0 = now();
        let root = tr.begin_request(1, t0);
        let round = tr.span_start(1, root, "round", None, t0);
        tr.span_closed(1, round, "subtask", Some(3), t0, t0 + Duration::from_millis(2));
        tr.instant(1, "hedge-fired", Some(3), None, t0);
        tr.span_end(1, round, t0 + Duration::from_millis(3));
        tr.end_request(1, root, t0 + Duration::from_millis(4));
        assert!(tr.violations().is_empty(), "{:?}", tr.violations());
        let reqs = tr.requests();
        assert_eq!(reqs.len(), 1);
        assert!(reqs[0].done);
        assert_eq!(reqs[0].open_spans(), 0);
        assert_eq!(reqs[0].spans.len(), 3);
        assert_eq!(reqs[0].events.len(), 1);
    }

    #[test]
    fn eviction_drops_oldest_whole_tree_only_when_completed() {
        let tr = TraceHandle::new(64); // floor cap
        let t0 = now();
        // An open tree survives any pressure.
        let open_root = tr.begin_request(0, t0);
        for r in 1..40u64 {
            let root = tr.begin_request(r, t0);
            tr.span_closed(r, root, "round", None, t0, t0);
            tr.instant(r, "cancel", None, None, t0);
            tr.end_request(r, root, t0);
        }
        assert!(tr.weight() <= 64 + 3, "weight={}", tr.weight());
        assert!(tr.dropped_requests() > 0);
        let reqs = tr.requests();
        // Request 0 (still open) was never evicted; survivors are the
        // newest completed trees, each intact (3 entries).
        assert!(reqs.iter().any(|r| r.request == 0 && !r.done));
        for r in reqs.iter().filter(|r| r.done) {
            assert_eq!(r.spans.len() + r.events.len(), 3, "torn tree: {:?}", r);
        }
        // Oldest completed ids are gone, newest retained.
        assert!(!reqs.iter().any(|r| r.request == 1));
        assert!(reqs.iter().any(|r| r.request == 39));
        tr.end_request(0, open_root, t0);
        assert!(tr.violations().is_empty());
    }

    #[test]
    fn violations_catch_bad_parents() {
        let tr = TraceHandle::new(256);
        let t0 = now();
        let root = tr.begin_request(9, t0);
        tr.span_end(9, root, t0);
        tr.span_start(9, root, "late-child", None, t0); // parent closed
        tr.span_start(3, 999, "orphan", None, t0); // unknown request
        assert_eq!(tr.violations().len(), 2);
    }

    #[test]
    fn chrome_export_round_trips_through_json() {
        let tr = TraceHandle::new(256);
        let t0 = now();
        let root = tr.begin_request(5, t0);
        tr.span_closed(5, root, "round", Some(1), t0, t0 + Duration::from_millis(1));
        tr.instant(5, "hedge-won", Some(1), Some(0.012), t0);
        tr.end_request(5, root, t0 + Duration::from_millis(2));
        tr.pool_span("slot", Some(1), t0, t0 + Duration::from_millis(1));
        tr.pool_instant("joined", Some(2), t0);
        let j = tr.export_chrome();
        let text = j.to_string_pretty();
        let back = Json::parse(&text).expect("chrome trace JSON parses");
        let evs = back.get("traceEvents").as_arr().expect("traceEvents array");
        // 2 metadata + 2 request spans + 1 instant + 1 pool span + 1 pool instant.
        assert_eq!(evs.len(), 7);
        assert!(evs
            .iter()
            .any(|e| e.get("ph").as_str() == Some("X")
                && e.get("name").as_str() == Some("request")));
        assert!(evs
            .iter()
            .any(|e| e.get("ph").as_str() == Some("i")
                && e.get("name").as_str() == Some("hedge-won")));
        let text_dump = tr.export_text();
        assert!(text_dump.contains("request 5"));
        assert!(text_dump.contains("hedge-won"));
    }

    #[test]
    fn allocation_counter_moves_only_when_recording() {
        let before = spans_allocated();
        let tr = TraceHandle::new(256);
        let mid = spans_allocated();
        assert_eq!(before, mid, "constructing a handle allocates no spans");
        let root = tr.begin_request(1, now());
        tr.end_request(1, root, now());
        assert!(spans_allocated() > mid);
    }
}
