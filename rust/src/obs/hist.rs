//! Log-bucketed mergeable latency histograms.
//!
//! Fixed memory (one `u64` per bucket), exact `count`/`sum`/`min`/`max`,
//! and a documented relative-error bound on quantiles: bucket edges grow
//! geometrically by `GROWTH = 2^(1/8)`, so any reported quantile is within
//! `sqrt(GROWTH) - 1 ≈ 4.4%` of the true sample value (the estimate is the
//! geometric mean of the enclosing bucket's edges, clamped to the exact
//! observed `[min, max]`). Two histograms built with the same layout merge
//! by bucket-wise addition, and `merge(a, b)` is exactly the histogram of
//! the concatenated samples — the property the scrape endpoint relies on
//! when it sums per-phase histograms across restarts or shards.
//!
//! The layout spans `LOWEST = 1 µs` up to ~10⁴ s in `N_BUCKETS` buckets;
//! values below `LOWEST` clamp into bucket 0 and values above the top edge
//! clamp into the last bucket (both still contribute exactly to
//! `count`/`sum`/`min`/`max`, so means stay exact even when tails clamp).

use crate::util::json::Json;

/// Smallest resolved latency (seconds). Everything below lands in bucket 0.
pub const LOWEST: f64 = 1e-6;
/// Geometric growth per bucket: 2^(1/8).
pub const GROWTH: f64 = 1.090_507_732_665_257_7;
/// Bucket count: covers `LOWEST * GROWTH^N_BUCKETS ≈ 1e4 s`, comfortably
/// past any latency this system can produce.
pub const N_BUCKETS: usize = 268;

/// Documented quantile relative-error bound: `sqrt(GROWTH) - 1`.
pub fn quantile_error_bound() -> f64 {
    GROWTH.sqrt() - 1.0
}

/// A mergeable log-bucketed histogram of nonnegative latencies (seconds).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a value (clamped into `[0, N_BUCKETS)`).
    fn index(v: f64) -> usize {
        if !(v > LOWEST) {
            return 0;
        }
        let i = (v / LOWEST).ln() / GROWTH.ln();
        (i as usize).min(N_BUCKETS - 1)
    }

    /// Lower edge of bucket `i` (seconds).
    pub fn edge(i: usize) -> f64 {
        LOWEST * GROWTH.powi(i as i32)
    }

    /// Record one latency sample (negative values clamp to 0).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let v = v.max(0.0);
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Quantile estimate for `q ∈ [0, 1]`: geometric mean of the enclosing
    /// bucket's edges, clamped to the exact observed `[min, max]`. `NaN`
    /// when empty. Relative error ≤ [`quantile_error_bound`].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        // Rank of the sample the quantile falls on (1-based, ceil), so
        // q=0 → first sample, q=1 → last sample.
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let est = if i == 0 {
                    // Bucket 0 spans [0, LOWEST·GROWTH): no useful geometric
                    // mean; the clamp below does the work.
                    LOWEST
                } else {
                    Self::edge(i) * GROWTH.sqrt()
                };
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Bucket-wise sum; exactly the histogram of the concatenated samples.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(upper_edge_seconds, cumulative_count)`,
    /// ascending — the shape Prometheus `_bucket{le=...}` lines want. The
    /// final implicit `+Inf` bucket is `count()`.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((Self::edge(i + 1), cum));
            }
        }
        out
    }

    /// Compact JSON: count/sum/min/max plus selected quantiles.
    pub fn to_json(&self) -> Json {
        let q = |p: f64| -> Json {
            let v = self.quantile(p);
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        };
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("mean", if self.count == 0 { Json::Null } else { Json::Num(self.mean()) }),
            ("min", if self.count == 0 { Json::Null } else { Json::Num(self.min) }),
            ("max", if self.count == 0 { Json::Null } else { Json::Num(self.max) }),
            ("p50", q(0.50)),
            ("p95", q(0.95)),
            ("p99", q(0.99)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile;

    #[test]
    fn empty_histogram_is_nan_and_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        assert!(h.cumulative_buckets().is_empty());
    }

    #[test]
    fn count_sum_min_max_exact() {
        let mut h = LogHistogram::new();
        for v in [0.003, 0.5, 12.0, 1e-9, 0.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - (0.003 + 0.5 + 12.0 + 1e-9)).abs() < 1e-15);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 12.0);
        h.record(f64::NAN); // ignored
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn quantiles_within_documented_bound() {
        let mut rng = Rng::new(0xC0C0_0B5);
        let mut h = LogHistogram::new();
        let mut xs = Vec::new();
        for _ in 0..4000 {
            // Log-uniform over ~[50 µs, 5 s]: exercises many buckets.
            let v = 5e-5 * (11.5 * rng.uniform()).exp();
            h.record(v);
            xs.push(v);
        }
        let bound = quantile_error_bound();
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999] {
            let exact = percentile(&xs, q);
            let est = h.quantile(q);
            let rel = (est - exact).abs() / exact;
            // The exact percentile interpolates between two samples that
            // can straddle a bucket edge; allow 2x the single-value bound.
            assert!(rel <= 2.0 * bound, "q={q}: est={est} exact={exact} rel={rel}");
        }
    }

    #[test]
    fn merge_equals_concat() {
        let mut rng = Rng::new(7);
        let mut all = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 0..1000 {
            let v = 1e-4 * (9.0 * rng.uniform()).exp();
            all.record(v);
            if i % 3 == 0 { a.record(v) } else { b.record(v) }
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), all.count());
        assert!((merged.sum() - all.sum()).abs() < 1e-9);
        assert_eq!(merged.min(), all.min());
        assert_eq!(merged.max(), all.max());
        assert_eq!(merged.cumulative_buckets(), all.cumulative_buckets());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(merged.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn cumulative_buckets_monotone() {
        let mut rng = Rng::new(42);
        let mut h = LogHistogram::new();
        for _ in 0..500 {
            h.record(1e-5 * (10.0 * rng.uniform()).exp());
        }
        let cb = h.cumulative_buckets();
        assert!(!cb.is_empty());
        for w in cb.windows(2) {
            assert!(w[1].0 > w[0].0, "edges ascending");
            assert!(w[1].1 >= w[0].1, "counts monotone");
        }
        assert_eq!(cb.last().unwrap().1, h.count());
    }

    #[test]
    fn clamps_do_not_lose_samples() {
        let mut h = LogHistogram::new();
        h.record(1e-9); // below LOWEST → bucket 0
        h.record(1e9); // above top edge → last bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.cumulative_buckets().last().unwrap().1, 2);
        // Quantiles clamp to exact extremes.
        assert_eq!(h.quantile(0.0), 1e-9);
        assert_eq!(h.quantile(1.0), 1e9);
    }

    #[test]
    fn json_has_percentiles() {
        let mut h = LogHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let j = h.to_json();
        assert_eq!(j.req_f64("count").unwrap(), 100.0);
        let p50 = j.req_f64("p50").unwrap();
        assert!((p50 - 0.050).abs() / 0.050 < 2.0 * quantile_error_bound());
    }
}
