//! CNN model layer: graph spec (`config/models.json` schema), the
//! VGG16/ResNet18/Tiny zoo, deterministic weights, local execution, and
//! the type-1/type-2 distribution plan.

pub mod graph;
pub mod plan;
pub mod spec;
pub mod weights;
pub mod zoo;

pub use plan::{ConvPlan, ModelPlan};
pub use spec::{ModelSpec, Node, Op};
pub use weights::{LayerParams, WeightStore};
