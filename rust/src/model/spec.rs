//! CNN graph representation: a flat, topologically-ordered node list
//! (DAG — ResNet skip connections reference earlier nodes by id), parsed
//! from the checked-in `config/models.json` that the python build layer
//! reads too (single source of truth across languages).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::conv::ConvSpec;
use crate::util::json::Json;

/// One node of the CNN graph.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// 2D convolution (+ optional fused ReLU). Bias always present.
    Conv { spec: ConvSpec, relu: bool },
    /// Max pooling (square window).
    MaxPool { k: usize, s: usize, pad: usize },
    /// Global average pooling to `(C, 1, 1)`.
    GlobalAvgPool,
    /// Fully-connected on the flattened input (+ optional ReLU).
    Linear { c_in: usize, c_out: usize, relu: bool },
    /// Element-wise sum of two inputs (ResNet shortcut), then ReLU if set.
    Add { relu: bool },
    /// Standalone ReLU.
    Relu,
}

/// A named node with its input edges (ids of earlier nodes, or `"input"`).
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    pub id: String,
    pub op: Op,
    pub inputs: Vec<String>,
}

/// A CNN: input shape plus topologically ordered nodes; the last node is
/// the output.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// `(C, H, W)` of the network input.
    pub input: (usize, usize, usize),
    pub nodes: Vec<Node>,
}

impl ModelSpec {
    /// Parse one model object from the `config/models.json` schema.
    pub fn from_json(j: &Json) -> Result<ModelSpec> {
        let name = j.req_str("name")?.to_string();
        let input = j.req_arr("input")?;
        ensure!(input.len() == 3, "input shape must be [C, H, W]");
        let shape = (
            input[0].as_usize().context("input C")?,
            input[1].as_usize().context("input H")?,
            input[2].as_usize().context("input W")?,
        );
        let mut nodes = Vec::new();
        for lj in j.req_arr("layers")? {
            let id = lj.req_str("id")?.to_string();
            let op_name = lj.req_str("op")?;
            let relu = lj.get("relu").as_bool().unwrap_or(false);
            let op = match op_name {
                "conv" => Op::Conv {
                    spec: ConvSpec::new(
                        lj.req_usize("c_in")?,
                        lj.req_usize("c_out")?,
                        lj.req_usize("k")?,
                        lj.req_usize("s")?,
                        lj.req_usize("p")?,
                    ),
                    relu,
                },
                "maxpool" => Op::MaxPool {
                    k: lj.req_usize("k")?,
                    s: lj.req_usize("s")?,
                    pad: lj.get("p").as_usize().unwrap_or(0),
                },
                "gap" => Op::GlobalAvgPool,
                "linear" => Op::Linear {
                    c_in: lj.req_usize("c_in")?,
                    c_out: lj.req_usize("c_out")?,
                    relu,
                },
                "add" => Op::Add { relu },
                "relu" => Op::Relu,
                other => bail!("unknown op '{other}' in layer '{id}'"),
            };
            let inputs: Vec<String> = lj
                .req_arr("in")?
                .iter()
                .map(|x| x.as_str().map(str::to_string).context("input id"))
                .collect::<Result<_>>()?;
            nodes.push(Node { id, op, inputs });
        }
        let spec = ModelSpec {
            name,
            input: shape,
            nodes,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural checks: unique ids, topologically ordered references,
    /// correct arity per op.
    pub fn validate(&self) -> Result<()> {
        let mut seen: BTreeMap<&str, usize> = BTreeMap::new();
        for (i, node) in self.nodes.iter().enumerate() {
            ensure!(
                !seen.contains_key(node.id.as_str()),
                "duplicate node id '{}'",
                node.id
            );
            let arity = match node.op {
                Op::Add { .. } => 2,
                _ => 1,
            };
            ensure!(
                node.inputs.len() == arity,
                "node '{}' wants {} inputs, has {}",
                node.id,
                arity,
                node.inputs.len()
            );
            for input in &node.inputs {
                ensure!(
                    input == "input" || seen.contains_key(input.as_str()),
                    "node '{}' references '{}' which is not defined earlier",
                    node.id,
                    input
                );
            }
            seen.insert(&node.id, i);
        }
        ensure!(!self.nodes.is_empty(), "model '{}' has no nodes", self.name);
        Ok(())
    }

    /// Shape inference: `(C, H, W)` produced by every node (Linear output
    /// is reported as `(c_out, 1, 1)`).
    pub fn infer_shapes(&self) -> Result<BTreeMap<String, (usize, usize, usize)>> {
        let mut shapes: BTreeMap<String, (usize, usize, usize)> = BTreeMap::new();
        shapes.insert("input".to_string(), self.input);
        for node in &self.nodes {
            let of = |name: &str| -> Result<(usize, usize, usize)> {
                shapes
                    .get(name)
                    .copied()
                    .with_context(|| format!("shape of '{name}'"))
            };
            let (c0, h0, w0) = of(&node.inputs[0])?;
            let out = match &node.op {
                Op::Conv { spec, .. } => {
                    ensure!(
                        c0 == spec.c_in,
                        "node '{}': input channels {} != {}",
                        node.id,
                        c0,
                        spec.c_in
                    );
                    (spec.c_out, spec.out_dim(h0), spec.out_dim(w0))
                }
                Op::MaxPool { k, s, pad } => {
                    let dim = |d: usize| (d + 2 * pad - k) / s + 1;
                    (c0, dim(h0), dim(w0))
                }
                Op::GlobalAvgPool => (c0, 1, 1),
                Op::Linear { c_in, c_out, .. } => {
                    ensure!(
                        c0 * h0 * w0 == *c_in,
                        "node '{}': flatten {}*{}*{} != c_in {}",
                        node.id,
                        c0,
                        h0,
                        w0,
                        c_in
                    );
                    (*c_out, 1, 1)
                }
                Op::Add { .. } => {
                    let s1 = of(&node.inputs[1])?;
                    ensure!(
                        (c0, h0, w0) == s1,
                        "node '{}': add shapes differ {:?} vs {:?}",
                        node.id,
                        (c0, h0, w0),
                        s1
                    );
                    (c0, h0, w0)
                }
                Op::Relu => (c0, h0, w0),
            };
            shapes.insert(node.id.clone(), out);
        }
        Ok(shapes)
    }

    /// Ids + conv specs + input shapes of all conv nodes (for the planner).
    pub fn conv_layers(&self) -> Result<Vec<(String, ConvSpec, (usize, usize, usize))>> {
        let shapes = self.infer_shapes()?;
        let mut out = Vec::new();
        for node in &self.nodes {
            if let Op::Conv { spec, .. } = &node.op {
                let in_shape = shapes[&node.inputs[0]];
                out.push((node.id.clone(), *spec, in_shape));
            }
        }
        Ok(out)
    }

    /// Parameter element counts per node id (weights + bias), for the
    /// weight store.
    pub fn param_lens(&self) -> Result<Vec<(String, usize, usize)>> {
        let mut out = Vec::new();
        for node in &self.nodes {
            match &node.op {
                Op::Conv { spec, .. } => {
                    out.push((node.id.clone(), spec.weight_len(), spec.c_out))
                }
                Op::Linear { c_in, c_out, .. } => {
                    out.push((node.id.clone(), c_in * c_out, *c_out))
                }
                _ => {}
            }
        }
        Ok(out)
    }
}

/// Parse every model in a `models.json` document.
pub fn parse_models(doc: &Json) -> Result<Vec<ModelSpec>> {
    doc.req_arr("models")?.iter().map(ModelSpec::from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_json() -> Json {
        Json::parse(
            r#"{
              "name": "t", "input": [3, 8, 8],
              "layers": [
                {"id": "c1", "op": "conv", "c_in": 3, "c_out": 4, "k": 3, "s": 1, "p": 1, "relu": true, "in": ["input"]},
                {"id": "c2", "op": "conv", "c_in": 4, "c_out": 4, "k": 3, "s": 1, "p": 1, "in": ["c1"]},
                {"id": "a", "op": "add", "relu": true, "in": ["c1", "c2"]},
                {"id": "p", "op": "maxpool", "k": 2, "s": 2, "in": ["a"]},
                {"id": "g", "op": "gap", "in": ["p"]},
                {"id": "fc", "op": "linear", "c_in": 4, "c_out": 10, "in": ["g"]}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_and_shapes() {
        let m = ModelSpec::from_json(&tiny_json()).unwrap();
        let shapes = m.infer_shapes().unwrap();
        assert_eq!(shapes["c1"], (4, 8, 8));
        assert_eq!(shapes["a"], (4, 8, 8));
        assert_eq!(shapes["p"], (4, 4, 4));
        assert_eq!(shapes["g"], (4, 1, 1));
        assert_eq!(shapes["fc"], (10, 1, 1));
        assert_eq!(m.conv_layers().unwrap().len(), 2);
    }

    #[test]
    fn rejects_forward_reference() {
        let j = Json::parse(
            r#"{"name": "bad", "input": [1, 4, 4], "layers": [
              {"id": "c1", "op": "conv", "c_in": 1, "c_out": 1, "k": 1, "s": 1, "p": 0, "in": ["c2"]},
              {"id": "c2", "op": "conv", "c_in": 1, "c_out": 1, "k": 1, "s": 1, "p": 0, "in": ["input"]}
            ]}"#,
        )
        .unwrap();
        assert!(ModelSpec::from_json(&j).is_err());
    }

    #[test]
    fn rejects_channel_mismatch() {
        let j = Json::parse(
            r#"{"name": "bad", "input": [2, 4, 4], "layers": [
              {"id": "c1", "op": "conv", "c_in": 3, "c_out": 1, "k": 1, "s": 1, "p": 0, "in": ["input"]}
            ]}"#,
        )
        .unwrap();
        let m = ModelSpec::from_json(&j).unwrap();
        assert!(m.infer_shapes().is_err());
    }
}
