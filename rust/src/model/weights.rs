//! Deterministic weight store.
//!
//! Weights are generated from a seed derived from `(model, layer-id)` —
//! every process (and every test) sees identical parameters without any
//! file exchange. A simple binary format (`.cocow`) supports explicit
//! save/load for the examples that want a weights file on disk.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::util::Rng;

use super::spec::ModelSpec;

/// Per-layer parameters: weight tensor (flattened) + bias vector.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerParams {
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
}

/// All parameters of a model, keyed by node id.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WeightStore {
    pub params: BTreeMap<String, LayerParams>,
}

/// Stable 64-bit hash of a string (FNV-1a) — seeds per-layer generators.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl WeightStore {
    /// Deterministically initialize every parameterized layer of `model`.
    /// He-style scaling: uniform in `±sqrt(3 / fan_in)`.
    pub fn generate(model: &ModelSpec, seed: u64) -> Result<WeightStore> {
        let mut params = BTreeMap::new();
        for (id, w_len, b_len) in model.param_lens()? {
            let mut rng = Rng::new(seed ^ fnv1a(&format!("{}/{}", model.name, id)));
            let fan_in = (w_len / b_len.max(1)).max(1);
            let bound = (3.0 / fan_in as f32).sqrt();
            let mut weights = vec![0.0f32; w_len];
            rng.fill_uniform_f32(&mut weights, -bound, bound);
            let mut bias = vec![0.0f32; b_len];
            rng.fill_uniform_f32(&mut bias, -0.05, 0.05);
            params.insert(id, LayerParams { weights, bias });
        }
        Ok(WeightStore { params })
    }

    pub fn get(&self, id: &str) -> Result<&LayerParams> {
        self.params
            .get(id)
            .with_context(|| format!("no parameters for layer '{id}'"))
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.params
            .values()
            .map(|p| p.weights.len() + p.bias.len())
            .sum()
    }

    // ---- binary save/load (.cocow) --------------------------------------
    // Format: magic "COCW1\n", then per layer:
    //   u32 id_len, id bytes, u64 w_len, u64 b_len, f32 LE data.

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"COCW1\n")?;
        for (id, p) in &self.params {
            f.write_all(&(id.len() as u32).to_le_bytes())?;
            f.write_all(id.as_bytes())?;
            f.write_all(&(p.weights.len() as u64).to_le_bytes())?;
            f.write_all(&(p.bias.len() as u64).to_le_bytes())?;
            for v in &p.weights {
                f.write_all(&v.to_le_bytes())?;
            }
            for v in &p.bias {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<WeightStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        ensure!(&magic == b"COCW1\n", "bad weight file magic");
        let mut params = BTreeMap::new();
        loop {
            let mut len4 = [0u8; 4];
            match f.read_exact(&mut len4) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                Err(e) => bail!("reading weight file: {e}"),
            }
            let id_len = u32::from_le_bytes(len4) as usize;
            ensure!(id_len < 4096, "implausible id length {id_len}");
            let mut id_bytes = vec![0u8; id_len];
            f.read_exact(&mut id_bytes)?;
            let id = String::from_utf8(id_bytes).context("weight id utf8")?;
            let mut len8 = [0u8; 8];
            f.read_exact(&mut len8)?;
            let w_len = u64::from_le_bytes(len8) as usize;
            f.read_exact(&mut len8)?;
            let b_len = u64::from_le_bytes(len8) as usize;
            let read_f32s = |f: &mut dyn Read, n: usize| -> Result<Vec<f32>> {
                let mut buf = vec![0u8; n * 4];
                f.read_exact(&mut buf)?;
                Ok(buf
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            };
            let weights = read_f32s(&mut f, w_len)?;
            let bias = read_f32s(&mut f, b_len)?;
            params.insert(id, LayerParams { weights, bias });
        }
        Ok(WeightStore { params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn generation_is_deterministic() {
        let m = zoo::model("tinyvgg").unwrap();
        let a = WeightStore::generate(&m, 42).unwrap();
        let b = WeightStore::generate(&m, 42).unwrap();
        assert_eq!(a, b);
        let c = WeightStore::generate(&m, 43).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn save_load_roundtrip() {
        let m = zoo::model("tinyresnet").unwrap();
        let w = WeightStore::generate(&m, 7).unwrap();
        let dir = std::env::temp_dir().join("cocoi_test_weights");
        let path = dir.join("tinyresnet.cocow");
        w.save(&path).unwrap();
        let loaded = WeightStore::load(&path).unwrap();
        assert_eq!(w, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn every_conv_and_linear_has_params() {
        let m = zoo::model("tinyvgg").unwrap();
        let w = WeightStore::generate(&m, 1).unwrap();
        for (id, w_len, b_len) in m.param_lens().unwrap() {
            let p = w.get(&id).unwrap();
            assert_eq!(p.weights.len(), w_len);
            assert_eq!(p.bias.len(), b_len);
        }
        assert!(w.num_params() > 10_000);
    }
}
