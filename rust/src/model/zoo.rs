//! Model zoo: VGG16 / ResNet18 (full-scale, for the latency model and DES
//! figures) and TinyVGG / TinyResNet (executed end-to-end on this
//! testbed). The canonical definition is `config/models.json` — shared
//! with `python/compile/models_zoo.py` — and baked into the binary at
//! compile time so the planner works without any filesystem setup.

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::spec::{parse_models, ModelSpec};

/// The checked-in zoo document (see `tools/gen_models_json.py`).
pub const MODELS_JSON: &str = include_str!("../../../config/models.json");

/// All models in the zoo.
pub fn all_models() -> Result<Vec<ModelSpec>> {
    let doc = Json::parse(MODELS_JSON).context("parsing embedded models.json")?;
    parse_models(&doc)
}

/// Look up one model by name.
pub fn model(name: &str) -> Result<ModelSpec> {
    all_models()?
        .into_iter()
        .find(|m| m.name == name)
        .with_context(|| format!("unknown model '{name}' (see config/models.json)"))
}

/// Load a zoo document from an explicit path (overrides the embedded one).
pub fn model_from_file(path: &std::path::Path, name: &str) -> Result<ModelSpec> {
    let doc = Json::parse_file(path)?;
    parse_models(&doc)?
        .into_iter()
        .find(|m| m.name == name)
        .with_context(|| format!("model '{name}' not in {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_parses_and_validates() {
        let models = all_models().unwrap();
        let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["vgg16", "resnet18", "tinyvgg", "tinyresnet"]);
        for m in &models {
            m.infer_shapes().unwrap();
        }
    }

    #[test]
    fn vgg16_structure_matches_paper() {
        let m = model("vgg16").unwrap();
        let convs = m.conv_layers().unwrap();
        assert_eq!(convs.len(), 13, "VGG16 has 13 conv layers");
        // All 3x3 stride 1 pad 1.
        assert!(convs.iter().all(|(_, s, _)| s.k_w == 3 && s.s_w == 1 && s.pad == 1));
        // Feature map halves five times: final conv input is 14x14.
        let shapes = m.infer_shapes().unwrap();
        assert_eq!(shapes["conv13"], (512, 14, 14));
    }

    #[test]
    fn resnet18_structure_matches_paper() {
        let m = model("resnet18").unwrap();
        let convs = m.conv_layers().unwrap();
        assert_eq!(convs.len(), 20, "ResNet18 table has 20 convs incl. downsamples");
        let shapes = m.infer_shapes().unwrap();
        // Stem: 224 -> 112, pool -> 56.
        assert_eq!(shapes["conv1"], (64, 112, 112));
        assert_eq!(shapes["pool1"], (64, 56, 56));
        // Final stage produces 512x7x7.
        let last_add = m
            .nodes
            .iter()
            .rev()
            .find(|n| matches!(n.op, super::super::spec::Op::Add { .. }))
            .unwrap();
        assert_eq!(shapes[&last_add.id], (512, 7, 7));
        assert_eq!(shapes["fc"], (1000, 1, 1));
    }

    #[test]
    fn tiny_models_are_small() {
        for name in ["tinyvgg", "tinyresnet"] {
            let m = model(name).unwrap();
            let params: usize = m
                .param_lens()
                .unwrap()
                .iter()
                .map(|(_, w, b)| w + b)
                .sum();
            assert!(params < 2_000_000, "{name} has {params} params");
        }
    }
}
