//! Local (single-device) CNN execution — the reference the distributed
//! pipeline must match bit-for-bit up to MDS round-off, and the master's
//! executor for type-2 layers.

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::conv::Tensor;

use super::spec::{ModelSpec, Node, Op};
use super::weights::WeightStore;

/// Execute one non-conv op (the master-local type-2 work). Conv nodes are
/// handled by the caller (locally via `ConvSpec::forward` or distributed).
pub fn execute_simple_op(
    node: &Node,
    inputs: &[&Tensor],
    weights: &WeightStore,
) -> Result<Tensor> {
    match &node.op {
        Op::Conv { .. } => anyhow::bail!("conv node '{}' routed to simple-op executor", node.id),
        Op::MaxPool { k, s, pad } => Ok(maxpool(inputs[0], *k, *s, *pad)),
        Op::GlobalAvgPool => Ok(global_avg_pool(inputs[0])),
        Op::Linear { c_in, c_out, relu } => {
            let x = inputs[0];
            ensure!(x.numel() == *c_in, "linear '{}' input mismatch", node.id);
            let p = weights.get(&node.id)?;
            let mut out = vec![0.0f32; *c_out];
            for (o, out_v) in out.iter_mut().enumerate() {
                let row = &p.weights[o * c_in..(o + 1) * c_in];
                let mut acc = p.bias[o];
                for (w, v) in row.iter().zip(&x.data) {
                    acc += w * v;
                }
                *out_v = if *relu { acc.max(0.0) } else { acc };
            }
            Tensor::from_vec(*c_out, 1, 1, out)
        }
        Op::Add { relu } => {
            let mut out = inputs[0].add(inputs[1])?;
            if *relu {
                out.relu_inplace();
            }
            Ok(out)
        }
        Op::Relu => {
            let mut out = inputs[0].clone();
            out.relu_inplace();
            Ok(out)
        }
    }
}

/// Max pooling with optional symmetric zero padding (padding uses -inf
/// semantics: padded cells never win the max — matches torch).
pub fn maxpool(x: &Tensor, k: usize, s: usize, pad: usize) -> Tensor {
    let h_o = (x.h + 2 * pad - k) / s + 1;
    let w_o = (x.w + 2 * pad - k) / s + 1;
    let mut out = Tensor::zeros(x.c, h_o, w_o);
    for c in 0..x.c {
        for oy in 0..h_o {
            for ox in 0..w_o {
                let mut m = f32::NEG_INFINITY;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * s + ky) as isize - pad as isize;
                        let ix = (ox * s + kx) as isize - pad as isize;
                        if iy >= 0 && ix >= 0 && (iy as usize) < x.h && (ix as usize) < x.w {
                            m = m.max(x.at(c, iy as usize, ix as usize));
                        }
                    }
                }
                *out.at_mut(c, oy, ox) = m;
            }
        }
    }
    out
}

/// Global average pooling to `(C, 1, 1)`.
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let plane = (x.h * x.w) as f32;
    let data = (0..x.c)
        .map(|c| {
            x.data[c * x.h * x.w..(c + 1) * x.h * x.w]
                .iter()
                .sum::<f32>()
                / plane
        })
        .collect();
    Tensor::from_vec(x.c, 1, 1, data).unwrap()
}

/// Run the whole model locally (every layer on this device).
pub fn forward_local(model: &ModelSpec, weights: &WeightStore, input: &Tensor) -> Result<Tensor> {
    ensure!(
        input.shape() == model.input,
        "input shape {:?} != model input {:?}",
        input.shape(),
        model.input
    );
    let mut values: BTreeMap<&str, Tensor> = BTreeMap::new();
    values.insert("input", input.clone());
    for node in &model.nodes {
        let fetched: Vec<&Tensor> = node
            .inputs
            .iter()
            .map(|i| values.get(i.as_str()).context("missing value").unwrap())
            .collect();
        let out = match &node.op {
            Op::Conv { spec, relu } => {
                let p = weights.get(&node.id)?;
                let mut t = spec.forward(fetched[0], &p.weights, Some(&p.bias))?;
                if *relu {
                    t.relu_inplace();
                }
                t
            }
            _ => execute_simple_op(node, &fetched, weights)?,
        };
        values.insert(node.id.as_str(), out);
    }
    let last = model.nodes.last().unwrap();
    Ok(values.remove(last.id.as_str()).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::util::Rng;

    #[test]
    fn maxpool_basics() {
        let x = Tensor::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let p = maxpool(&x, 2, 2, 0);
        assert_eq!(p.shape(), (1, 1, 1));
        assert_eq!(p.data, vec![4.0]);
        // Padding never wins over negatives.
        let neg = Tensor::from_vec(1, 1, 1, vec![-5.0]).unwrap();
        let padded = maxpool(&neg, 3, 1, 1);
        assert_eq!(padded.data, vec![-5.0]);
    }

    #[test]
    fn gap_means() {
        let x = Tensor::from_vec(2, 1, 2, vec![1.0, 3.0, 10.0, 20.0]).unwrap();
        let g = global_avg_pool(&x);
        assert_eq!(g.data, vec![2.0, 15.0]);
    }

    #[test]
    fn tinyvgg_forward_runs() {
        let m = zoo::model("tinyvgg").unwrap();
        let w = WeightStore::generate(&m, 3).unwrap();
        let mut input = Tensor::zeros(3, 56, 56);
        Rng::new(8).fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let out = forward_local(&m, &w, &input).unwrap();
        assert_eq!(out.shape(), (10, 1, 1));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tinyresnet_forward_runs() {
        let m = zoo::model("tinyresnet").unwrap();
        let w = WeightStore::generate(&m, 3).unwrap();
        let mut input = Tensor::zeros(3, 56, 56);
        Rng::new(9).fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let out = forward_local(&m, &w, &input).unwrap();
        assert_eq!(out.shape(), (10, 1, 1));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
}
