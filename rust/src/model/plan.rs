//! Execution planning: classify each conv layer as type-1 (distribute) or
//! type-2 (master-local) and choose its split `k` (paper §II-A + App. A:
//! "a layer is type-1 iff distributed execution can accelerate it").

use anyhow::Result;

use crate::coding::SchemeKind;
use crate::latency::approx::l_integer;
use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;
use crate::planner::{choose_k, SplitPolicy};
use crate::util::Rng;

use super::spec::{ModelSpec, Op};

/// Planned treatment of one conv layer.
#[derive(Clone, Debug)]
pub struct ConvPlan {
    pub node_id: String,
    pub dims: LayerDims,
    /// Distribute (type-1) or run on the master (type-2).
    pub distributed: bool,
    /// Chosen source-piece count (meaningful when `distributed`).
    pub k: usize,
    /// Redundancy scheme for this layer. `ModelPlan::build` seeds the
    /// MDS default; a master running `--scheme auto` re-seeds each
    /// distributed layer from its [`crate::coding::SchemeSelector`] and
    /// the replanner may swap it between requests.
    pub scheme: SchemeKind,
    /// Estimated local latency (master executes the full layer).
    pub est_local: f64,
    /// Estimated distributed latency at the chosen `k`.
    pub est_distributed: f64,
}

/// The whole-model execution plan.
#[derive(Clone, Debug)]
pub struct ModelPlan {
    pub model_name: String,
    pub n_workers: usize,
    pub convs: Vec<ConvPlan>,
}

impl ModelPlan {
    /// Build a plan: for each conv layer, pick `k` under `policy` and
    /// distribute iff the estimated distributed latency beats local
    /// master execution.
    pub fn build(
        model: &ModelSpec,
        profile: &SystemProfile,
        n_workers: usize,
        policy: SplitPolicy,
        rng: &mut Rng,
    ) -> Result<ModelPlan> {
        let mut convs = Vec::new();
        for (node_id, spec, (_, in_h, in_w)) in model.conv_layers()? {
            let dims = LayerDims::new(spec, in_h, in_w);
            let k = choose_k(policy, &dims, profile, n_workers, rng);
            let est_local = profile.local_conv_dist(dims.full_flops()).mean();
            let est_distributed = l_integer(&dims, profile, n_workers, k);
            let distributed = est_distributed < est_local;
            convs.push(ConvPlan {
                node_id,
                dims,
                distributed,
                k,
                scheme: SchemeKind::Mds,
                est_local,
                est_distributed,
            });
        }
        Ok(ModelPlan {
            model_name: model.name.clone(),
            n_workers,
            convs,
        })
    }

    pub fn conv(&self, node_id: &str) -> Option<&ConvPlan> {
        self.convs.iter().find(|c| c.node_id == node_id)
    }

    /// Ids of type-1 (distributed) layers — the paper's `L_d` set.
    pub fn type1_ids(&self) -> Vec<&str> {
        self.convs
            .iter()
            .filter(|c| c.distributed)
            .map(|c| c.node_id.as_str())
            .collect()
    }

    /// Estimated end-to-end conv latency of the plan (sum over layers).
    pub fn estimated_conv_latency(&self) -> f64 {
        self.convs
            .iter()
            .map(|c| {
                if c.distributed {
                    c.est_distributed
                } else {
                    c.est_local
                }
            })
            .sum()
    }
}

/// Total FLOPs of a model's conv layers vs everything else — App. A's
/// ">99% of latency is convolution" bottleneck statement.
pub fn conv_flop_share(model: &ModelSpec) -> Result<f64> {
    let shapes = model.infer_shapes()?;
    let mut conv = 0.0;
    let mut other = 0.0;
    for node in &model.nodes {
        let out = shapes[&node.id];
        match &node.op {
            Op::Conv { spec, .. } => conv += spec.flops(out.1, out.2),
            Op::Linear { c_in, c_out, .. } => other += 2.0 * (*c_in * *c_out) as f64,
            Op::MaxPool { k, .. } => other += (out.0 * out.1 * out.2 * k * k) as f64,
            Op::GlobalAvgPool | Op::Add { .. } | Op::Relu => {
                other += (out.0 * out.1 * out.2) as f64
            }
        }
    }
    Ok(conv / (conv + other))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn conv_dominates_flops_appendix_a() {
        // App. A: convolution is >99% of inference work on both CNNs.
        for name in ["vgg16", "resnet18"] {
            let m = zoo::model(name).unwrap();
            let share = conv_flop_share(&m).unwrap();
            assert!(share > 0.99, "{name}: conv share = {share}");
        }
    }

    #[test]
    fn plan_distributes_heavy_layers() {
        let m = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(1);
        let plan = ModelPlan::build(&m, &p, 10, SplitPolicy::KCircle, &mut rng).unwrap();
        assert_eq!(plan.convs.len(), 13);
        // The big mid-network layers must be type-1 under an RPi-class
        // profile; the paper found all but conv1 distributable for VGG16.
        let t1 = plan.type1_ids();
        assert!(t1.len() >= 10, "only {} type-1 layers: {t1:?}", t1.len());
        for c in &plan.convs {
            assert!(c.k >= 1 && c.k <= 10);
        }
    }

    #[test]
    fn resnet_downsample_convs_are_light() {
        // The paper's App. A: some convs (1x1 downsamples) are type-2.
        let m = zoo::model("resnet18").unwrap();
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(2);
        let plan = ModelPlan::build(&m, &p, 10, SplitPolicy::KCircle, &mut rng).unwrap();
        let one_by_one: Vec<&ConvPlan> = plan
            .convs
            .iter()
            .filter(|c| c.dims.spec.k_w == 1)
            .collect();
        assert_eq!(one_by_one.len(), 3, "ResNet18 has 3 downsample 1x1 convs");
        // Their per-FLOP weight is tiny; the planner may or may not
        // distribute them, but the best possible gain from distributing a
        // 1x1 downsample must be far below the best 3x3 gain.
        let max_gain = |pred: &dyn Fn(&ConvPlan) -> bool| {
            plan.convs
                .iter()
                .filter(|c| pred(c))
                .map(|c| c.est_local - c.est_distributed)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let best_3x3 = max_gain(&|c| c.dims.spec.k_w == 3);
        let best_1x1 = max_gain(&|c| c.dims.spec.k_w == 1);
        assert!(
            best_3x3 > 10.0 * best_1x1.max(0.0),
            "best 3x3 gain {best_3x3} vs best 1x1 gain {best_1x1}"
        );
    }
}
