//! One driver per paper table/figure (DESIGN.md §4 experiment index).
//!
//! Absolute numbers come from this testbed's calibrated profile, not the
//! authors' Raspberry-Pi cluster; the *shape* of each result (who wins,
//! by what factor, where crossovers fall) is the reproduction target.

use anyhow::Result;

use crate::latency::approx::l_integer;
use crate::transport::Link as _;
use crate::latency::phases::LayerDims;
use crate::latency::{ShiftExp, SystemProfile};
use crate::model::plan::conv_flop_share;
use crate::model::{zoo, ModelPlan};
use crate::planner::{montecarlo, solve_k_circ, Param, SplitPolicy};
use crate::sim::{simulate_model, MethodSim, Scenario};
use crate::util::stats::Summary;
use crate::util::Rng;

use super::harness::{fmt_secs, BenchJson, BenchTimer, Table};

/// Fold scenario-1's extra `Exp(λ_tr · T̄_tr)` transmission delay into the
/// profile: each transmission phase's exponential part grows by
/// `λ_tr × (θ + 1/μ)` per unit, i.e. `1/μ' = 1/μ + λ_tr (θ + 1/μ)`.
/// (Thin alias of [`crate::sim::straggling_profile`], kept for the
/// bench drivers' historical name.)
pub fn scenario1_profile(base: &SystemProfile, lambda_tr: f64) -> SystemProfile {
    crate::sim::straggling_profile(base, lambda_tr)
}

/// Per-model calibrated profile (App. B): θ_cmp scaled so total conv
/// FLOPs reproduce the paper's measured single-RPi latency.
pub fn model_profile(name: &str) -> Result<SystemProfile> {
    let base = SystemProfile::paper_default();
    let measured = match name {
        "vgg16" => 50.8,
        "resnet18" => 89.8,
        _ => return Ok(base),
    };
    let model = zoo::model(name)?;
    let conv_flops: f64 = model
        .conv_layers()?
        .iter()
        .map(|(_, spec, (_, h, w))| LayerDims::new(*spec, *h, *w).full_flops())
        .sum();
    Ok(base.calibrated_for(conv_flops, measured))
}

/// Experiment scale: quick mode for CI, full for EXPERIMENTS.md numbers.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub trials: usize,
    pub mc_samples: usize,
    pub grid: usize,
}

impl Scale {
    pub fn quick() -> Scale {
        Scale {
            trials: 8,
            mc_samples: 3_000,
            grid: 4,
        }
    }

    /// Paper-scale: 20 trials per point (§V), 3×10⁵ MC samples (App. D).
    pub fn full() -> Scale {
        Scale {
            trials: 20,
            mc_samples: 300_000,
            grid: 7,
        }
    }

    pub fn from_env() -> Scale {
        match std::env::var("COCOI_BENCH_SCALE").as_deref() {
            Ok("full") => Scale::full(),
            Ok("quick") => Scale::quick(),
            _ => Scale {
                trials: 20,
                mc_samples: 20_000,
                grid: 5,
            },
        }
    }
}

const METHODS: [MethodSim; 6] = [
    MethodSim::CocoiKStar { samples: 10_000 },
    MethodSim::CocoiKCirc,
    MethodSim::Uncoded,
    MethodSim::Replication,
    MethodSim::LtFine,
    MethodSim::LtCoarse,
];

// ====================================================================
// Appendix A, Fig. 7: per-layer local latency; conv share > 99%.
// ====================================================================
pub fn fig7() -> Result<()> {
    for name in ["vgg16", "resnet18"] {
        // Local single-device inference: everything runs at the model's
        // calibrated worker-compute speed (θ_cmp + 1/μ_cmp per FLOP).
        let p = model_profile(name)?;
        let per_flop = p.theta_cmp + 1.0 / p.mu_cmp;
        let model = zoo::model(name)?;
        let mut table = Table::new(
            &format!("Fig. 7 — {name}: estimated local per-layer latency"),
            &["layer", "c_in->c_out", "kxk/s", "flops", "latency"],
        );
        let mut total_conv = 0.0;
        for (id, spec, (_, h, w)) in model.conv_layers()? {
            let dims = LayerDims::new(spec, h, w);
            let t = dims.full_flops() * per_flop;
            total_conv += t;
            table.row(vec![
                id,
                format!("{}->{}", spec.c_in, spec.c_out),
                format!("{}x{}/{}", spec.k_w, spec.k_w, spec.s_w),
                format!("{:.2}G", dims.full_flops() / 1e9),
                fmt_secs(t),
            ]);
        }
        table.print();
        let share = conv_flop_share(&model)?;
        println!(
            "total conv latency {:.1}s; conv FLOP share {:.2}% (paper: VGG16 50.8s/99.43%, \
             ResNet18 89.8s/99.68%)",
            total_conv,
            share * 100.0
        );
    }
    Ok(())
}

// ====================================================================
// Appendix B, Fig. 8: shift-exponential fit of real measured latencies.
// ====================================================================
pub fn fig8() -> Result<()> {
    // (a) transmission: real TCP loopback transfers of a 2 MB tensor.
    let payload = vec![0u8; 2 << 20];
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server = std::thread::spawn(move || -> Result<()> {
        let (stream, _) = listener.accept()?;
        let mut link = crate::transport::tcp::TcpLink::from_stream(stream);
        while let Some(frame) = link.recv()? {
            link.send(&frame[..1])?; // short ack, like the paper's RTT probe
        }
        Ok(())
    });
    let mut link = crate::transport::tcp::TcpLink::connect(&addr.to_string())?;
    let mut tr_samples = Vec::new();
    for _ in 0..200 {
        let t0 = std::time::Instant::now();
        crate::transport::Link::send(&mut link, &payload)?;
        crate::transport::Link::recv(&mut link)?;
        tr_samples.push(t0.elapsed().as_secs_f64());
    }
    drop(link);
    let _ = server.join();

    // (b) computation: repeated real conv subtask execution (VGG16 conv3
    // analogue scaled to this host) through the fallback provider.
    use crate::runtime::ConvProvider;
    let spec = crate::conv::ConvSpec::new(64, 64, 3, 1, 0);
    let mut rng = Rng::new(42);
    let mut input = crate::conv::Tensor::zeros(64, 58, 16);
    rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
    let mut weights = vec![0f32; spec.weight_len()];
    rng.fill_uniform_f32(&mut weights, -1.0, 1.0);
    let provider = crate::runtime::FallbackProvider::new();
    let mut cmp_samples = Vec::new();
    for _ in 0..100 {
        let t0 = std::time::Instant::now();
        let _ = provider.conv(&spec, &input, &weights)?;
        cmp_samples.push(t0.elapsed().as_secs_f64());
    }

    let mut table = Table::new(
        "Fig. 8 — shift-exponential fit of measured latencies",
        &["series", "n", "min(=Nθ)", "mean", "fit μ/N", "KS", "KS(robust/bulk)"],
    );
    for (name, samples) in [("transmission 2MB", &tr_samples), ("conv subtask", &cmp_samples)] {
        let fit = ShiftExp::fit(samples, 1.0);
        // Virtualized 1-core hosts add scheduler spikes the RPi testbed
        // does not have; the robust (censored-tail) fit estimates the
        // underlying distribution with the spike tail treated as
        // censored, and its KS is taken against the bulk (bottom-95%)
        // sample. Its tail is slightly heavier than a bulk-only fit by
        // design, so this column is a robust-fit quality indicator, not
        // a pure bulk-fit score.
        let trimmed = ShiftExp::fit_trimmed(samples, 1.0, 0.05);
        let mut sorted = samples.clone();
        sorted.sort_by(f64::total_cmp);
        let keep = &sorted[..(sorted.len() * 95) / 100];
        let s = Summary::from_slice(samples);
        table.row(vec![
            name.into(),
            format!("{}", samples.len()),
            format!("{:.3}ms", s.min() * 1e3),
            format!("{:.3}ms", s.mean() * 1e3),
            format!("{:.1}", fit.mu),
            format!("{:.3}", fit.ks_statistic(samples)),
            format!("{:.3}", trimmed.ks_statistic(keep)),
        ]);
    }
    table.print();
    println!(
        "(paper Fig. 8: RPi/WiFi latencies fit shift-exponential well; on this \
         virtualized host the spike tail inflates the raw KS — the robust column \
         scores the censored-tail fit against the bulk sample)"
    );
    Ok(())
}

// ====================================================================
// Fig. 4: per-layer latency stacks, CoCoI vs uncoded, scenario-1 λ=0.5.
// ====================================================================
pub fn fig4(scale: Scale) -> Result<()> {
    for name in ["vgg16", "resnet18"] {
        let base = model_profile(name)?;
        let model = zoo::model(name)?;
        let mut rng = Rng::new(0xF16_4);
        let scenario = Scenario::Straggling { lambda_tr: 0.5 };
        let coc = simulate_model(
            &model,
            &base,
            10,
            MethodSim::CocoiKCirc,
            scenario,
            scale.trials,
            &mut rng,
        )?;
        let unc = simulate_model(
            &model,
            &base,
            10,
            MethodSim::Uncoded,
            scenario,
            scale.trials,
            &mut rng,
        )?;
        let mut table = Table::new(
            &format!("Fig. 4 — {name}: per-layer latency, scenario-1 λ=0.5 (n=10)"),
            &[
                "layer",
                "k0",
                "enc+dec",
                "workers",
                "cocoi total",
                "uncoded",
                "coding %",
            ],
        );
        let mut coding_shares = Vec::new();
        for (i, (id, b)) in coc.per_layer.iter().enumerate() {
            let coding = b.enc + b.dec;
            let total = coding + b.workers;
            let u = unc.per_layer.get(i).map(|(_, x)| x.workers).unwrap_or(0.0);
            let share = 100.0 * coding / total;
            coding_shares.push(share);
            table.row(vec![
                id.clone(),
                format!("{}", coc.k_per_layer[i].1),
                fmt_secs(coding),
                fmt_secs(b.workers),
                fmt_secs(total),
                fmt_secs(u),
                format!("{share:.1}%"),
            ]);
        }
        table.print();
        let lo = coding_shares.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = coding_shares.iter().cloned().fold(0.0, f64::max);
        println!(
            "encode/decode share per layer: {lo:.1}%–{hi:.1}% (paper: 2%–9%); \
             CoCoI total {} vs uncoded {}",
            fmt_secs(coc.mean()),
            fmt_secs(unc.mean())
        );
    }
    Ok(())
}

// ====================================================================
// Table I: k* vs k° statistics under scenario-1.
// ====================================================================
pub fn table1(scale: Scale) -> Result<()> {
    let lambdas = [0.2, 0.4, 0.6, 0.8, 1.0];
    for name in ["vgg16", "resnet18"] {
        let base = model_profile(name)?;
        let model = zoo::model(name)?;
        let mut table = Table::new(
            &format!("Table I — {name}: k* vs k° under scenario-1 (n=10)"),
            &[
                "lambda_tr",
                "max|k*-k0|",
                "avg|k*-k0|",
                "sum t(k0)-t(k*) (s)",
            ],
        );
        for &lambda in &lambdas {
            let p = scenario1_profile(&base, lambda);
            let mut rng = Rng::new(0x7AB1E1 ^ (lambda * 10.0) as u64);
            let plan = ModelPlan::build(&model, &p, 10, SplitPolicy::KCircle, &mut rng)?;
            let mut max_gap = 0usize;
            let mut sum_gap = 0usize;
            let mut latency_gap = 0.0;
            let mut n_layers = 0usize;
            for c in plan.convs.iter().filter(|c| c.distributed) {
                let k_circ = solve_k_circ(&c.dims, &p, 10).k;
                let (k_star, est) =
                    montecarlo::optimal_k_star(&c.dims, &p, 10, scale.mc_samples, &mut rng);
                let gap = k_star.abs_diff(k_circ);
                max_gap = max_gap.max(gap);
                sum_gap += gap;
                // t° − t*: extra expected latency from using k° instead of k*.
                let t_star = est[k_star - 1];
                let t_circ = est[(k_circ - 1).min(est.len() - 1)];
                latency_gap += (t_circ - t_star).max(0.0);
                n_layers += 1;
            }
            table.row(vec![
                format!("{lambda}"),
                format!("{max_gap}"),
                format!("{:.2}", sum_gap as f64 / n_layers.max(1) as f64),
                format!("{latency_gap:.2}"),
            ]);
        }
        table.print();
    }
    println!("(paper: max gap 1, avg ~0.3–0.5, latency gap ≤ 1.3 s)");
    Ok(())
}

// ====================================================================
// Fig. 5: end-to-end latency vs λ_tr (scenario-1), all methods.
// ====================================================================
pub fn fig5(scale: Scale) -> Result<()> {
    for name in ["vgg16", "resnet18"] {
        let base = model_profile(name)?;
        let model = zoo::model(name)?;
        let mut table = Table::new(
            &format!("Fig. 5 — {name}: inference latency vs λ_tr (scenario-1, n=10)"),
            &["method", "0.0", "0.2", "0.4", "0.6", "0.8", "1.0"],
        );
        let lambdas = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0];
        let mut means = std::collections::BTreeMap::new();
        for method in METHODS {
            let mut cells = vec![method.label().to_string()];
            for &lambda in &lambdas {
                let mut rng = Rng::new(0xF165 ^ (lambda * 100.0) as u64);
                let r = simulate_model(
                    &model,
                    &base,
                    10,
                    method,
                    Scenario::Straggling { lambda_tr: lambda },
                    scale.trials,
                    &mut rng,
                )?;
                means.insert((method.label(), (lambda * 10.0) as i64), r.mean());
                cells.push(fmt_secs(r.mean()));
            }
            table.row(cells);
        }
        table.print();
        let unc = means[&("uncoded", 10)];
        let coc = means[&("cocoi-k0", 10)];
        println!(
            "reduction vs uncoded at λ=1.0: {:.1}% (paper: up to 20.2%)",
            100.0 * (1.0 - coc / unc)
        );
    }
    Ok(())
}

// ====================================================================
// Fig. 6: scenarios 2 and 3 (failures, + chronic straggler).
// ====================================================================
pub fn fig6(scale: Scale) -> Result<()> {
    for name in ["vgg16", "resnet18"] {
        let base = model_profile(name)?;
        let model = zoo::model(name)?;
        let scenarios: [(&str, fn(usize) -> Scenario); 2] = [
            ("scenario-2", |n_f| Scenario::Failures { n_f }),
            ("scenario-3", |n_f| Scenario::FailuresPlusStraggler {
                n_f,
                slowdown: 1.68,
            }),
        ];
        for (scen_name, make) in scenarios {
            let mut table = Table::new(
                &format!("Fig. 6 — {name}: latency under {scen_name} (n=10)"),
                &["method", "n_f=0", "n_f=1", "n_f=2"],
            );
            let mut means = std::collections::BTreeMap::new();
            for method in METHODS {
                let mut cells = vec![method.label().to_string()];
                for n_f in 0..=2usize {
                    let mut rng = Rng::new(0xF166 ^ n_f as u64);
                    let r = simulate_model(
                        &model,
                        &base,
                        10,
                        method,
                        make(n_f),
                        scale.trials,
                        &mut rng,
                    )?;
                    means.insert((method.label(), n_f), (r.mean(), r.std()));
                    cells.push(format!("{}±{}", fmt_secs(r.mean()), fmt_secs(r.std())));
                }
                table.row(cells);
            }
            table.print();
            let (u0, _) = means[&("uncoded", 0)];
            let (u2, _) = means[&("uncoded", 2)];
            let (c2, _) = means[&("cocoi-k0", 2)];
            println!(
                "uncoded degradation n_f 0→2: +{:.1}% (paper: 68.3–79.2%); \
                 CoCoI vs uncoded at n_f=2: −{:.1}% (paper: up to 34.2% s2 / 26.5% s3)",
                100.0 * (u2 / u0 - 1.0),
                100.0 * (1.0 - c2 / u2)
            );
        }
    }
    Ok(())
}

// ====================================================================
// Fig. 9: (a) |k*−k°| over (μ_tr, μ_cmp); (b) actual vs approx E[T(k)].
// ====================================================================
pub fn fig9(scale: Scale) -> Result<()> {
    let dims = LayerDims::new(crate::conv::ConvSpec::new(128, 128, 3, 1, 1), 112, 112);
    let n = 20;
    let mut rng = Rng::new(0xF169);

    // (a) grid heatmap.
    let logspace = |lo: f64, hi: f64, steps: usize| -> Vec<f64> {
        (0..steps)
            .map(|i| lo * (hi / lo).powf(i as f64 / (steps - 1).max(1) as f64))
            .collect()
    };
    let mut header = vec!["mu_tr\\mu_cmp".to_string()];
    for &mu in &logspace(1e6, 1e10, scale.grid) {
        header.push(format!("{mu:.0e}"));
    }
    let mut table = Table::new_owned(
        "Fig. 9a — |k* − k°| over (μ_tr rows ↓, μ_cmp cols →), n=20",
        header,
    );
    let mut worst = 0usize;
    for &mu_tr in &logspace(1e6, 1e10, scale.grid) {
        let mut cells = vec![format!("{mu_tr:.0e}")];
        for &mu_cmp in &logspace(1e6, 1e10, scale.grid) {
            let mut p = SystemProfile::paper_default();
            p.mu_rec = mu_tr;
            p.mu_sen = mu_tr;
            p.mu_cmp = mu_cmp;
            let k_circ = solve_k_circ(&dims, &p, n).k;
            let (k_star, _) =
                montecarlo::optimal_k_star(&dims, &p, n, scale.mc_samples / 4, &mut rng);
            let gap = k_star.abs_diff(k_circ);
            worst = worst.max(gap);
            cells.push(format!("{gap}"));
        }
        table.row(cells);
    }
    table.print();
    println!("worst gap on grid: {worst} (paper Fig. 9a: ≈0 in the strong-straggling region)");

    // (b) actual (MC) vs approx L(k) curve at μ_tr=1e7, μ_cmp=1e8.
    let mut p = SystemProfile::paper_default();
    p.mu_rec = 1e7;
    p.mu_sen = 1e7;
    p.mu_cmp = 1e8;
    let mut table = Table::new(
        "Fig. 9b — E[T(k)]: actual (MC) vs approx L(k), n=20, μ_tr=1e7 μ_cmp=1e8",
        &["k", "actual", "approx", "rel err"],
    );
    let mut max_rel: f64 = 0.0;
    for k in (1..n).step_by(2) {
        let actual =
            montecarlo::expected_total_latency(&dims, &p, n, k, scale.mc_samples / 2, &mut rng);
        let approx = l_integer(&dims, &p, n, k);
        let rel = (actual - approx).abs() / actual;
        max_rel = max_rel.max(rel);
        table.row(vec![
            format!("{k}"),
            fmt_secs(actual),
            fmt_secs(approx),
            format!("{:.1}%", rel * 100.0),
        ]);
    }
    table.print();
    println!("max relative gap {:.1}% (paper: 'negligible')", max_rel * 100.0);
    Ok(())
}

// ====================================================================
// Fig. 10: impact of μ/θ on the optimal k (actual MC vs approx).
// ====================================================================
pub fn fig10(scale: Scale) -> Result<()> {
    let dims = LayerDims::new(crate::conv::ConvSpec::new(128, 128, 3, 1, 1), 112, 112);
    let base = SystemProfile::paper_default();
    let mut rng = Rng::new(0xF170);
    let sweeps: [(&str, Param, Vec<f64>); 4] = [
        (
            "mu_cmp",
            Param::MuCmp,
            vec![1e7, 1e8, 1e9, 1e10],
        ),
        (
            "theta_cmp",
            Param::ThetaCmp,
            vec![1e-10, 1e-9, 1e-8, 1e-7],
        ),
        ("mu_tr", Param::MuTr, vec![1e6, 1e7, 1e8, 1e9]),
        (
            "theta_tr",
            Param::ThetaTr,
            vec![1e-9, 1e-8, 1e-7, 1e-6],
        ),
    ];
    for (name, param, values) in sweeps {
        let mut table = Table::new(
            &format!("Fig. 10 — optimal k vs {name} (n=10 and n=20)"),
            &["value", "k* n=10", "k0 n=10", "k* n=20", "k0 n=20"],
        );
        for &v in &values {
            let p = param.apply(&base, v);
            let mut cells = vec![format!("{v:.0e}")];
            for n in [10usize, 20] {
                let (k_star, _) =
                    montecarlo::optimal_k_star(&dims, &p, n, scale.mc_samples / 4, &mut rng);
                let k_circ = solve_k_circ(&dims, &p, n).k;
                cells.push(format!("{k_star}"));
                cells.push(format!("{k_circ}"));
            }
            // reorder: k* n10, k0 n10, k* n20, k0 n20 already in order
            table.row(cells);
        }
        table.print();
    }
    println!(
        "(Prop. 1: k increases in worker μ and θ; larger n ⇒ larger k. \
         The k* and k° columns should move together.)"
    );
    Ok(())
}

// ====================================================================
// §Compute backbone: the tiled multithreaded GEMM kernel vs the scalar
// oracle on VGG-sized shapes. Emits BENCH_gemm.json (perf trajectory).
// ====================================================================
pub fn gemm(scale: Scale) -> Result<()> {
    use crate::conv::gemm::gemm_tiled;
    use crate::conv::im2col;
    use crate::util::json::Json;

    let threads = crate::util::threads::default_threads();
    let iters = if scale.trials <= 8 { 3 } else { 5 };
    let timer = BenchTimer::new(1, iters);
    // (m, kk, n) = (C_O, C_I·K², H_O·W_O) of VGG-shaped conv GEMMs, plus
    // one deliberately remainder-heavy shape.
    let shapes: [(usize, usize, usize, &str); 5] = [
        (64, 27, 50176, "3->64 k3 @224^2"),
        (64, 576, 12544, "64->64 k3 @112^2"),
        (256, 1152, 3136, "128->256 k3 @56^2"),
        (512, 4608, 196, "512->512 k3 @14^2"),
        (33, 301, 523, "odd remainders"),
    ];
    let mut table = Table::new(
        &format!("GEMM kernel — scalar oracle vs tiled (threads={threads})"),
        &["shape", "scalar", "tiled(1T)", &format!("tiled({threads}T)"), "speedup", "GFLOP/s", "bitwise"],
    );
    let mut json = BenchJson::new("gemm");
    json.set_num("iters", iters as f64);
    let mut rng = Rng::new(0x6E77);
    let mut worst_speedup = f64::INFINITY;
    for (m, kk, n, label) in shapes {
        let mut a = vec![0.0f32; m * kk];
        let mut b = vec![0.0f32; kk * n];
        rng.fill_uniform_f32(&mut a, -1.0, 1.0);
        rng.fill_uniform_f32(&mut b, -1.0, 1.0);

        // Determinism gate first: the multithreaded kernel must be
        // bitwise identical at every thread count.
        let c1 = gemm_tiled(&a, m, kk, &b, n, 1);
        let bitwise = [2usize, 4]
            .iter()
            .all(|&t| gemm_tiled(&a, m, kk, &b, n, t) == c1);
        anyhow::ensure!(bitwise, "tiled kernel diverged across thread counts ({label})");
        // Accuracy gate vs the scalar oracle (different summation order).
        let oracle = im2col::gemm(&a, m, kk, &b, n);
        let tol = 1e-5 * (kk as f32).max(16.0);
        for (x, y) in c1.iter().zip(&oracle) {
            anyhow::ensure!((x - y).abs() < tol, "tiled kernel off oracle ({label})");
        }

        let s_scalar = timer.run(|| {
            let _ = im2col::gemm(&a, m, kk, &b, n);
        });
        let s_tiled1 = timer.run(|| {
            let _ = gemm_tiled(&a, m, kk, &b, n, 1);
        });
        let s_tiled = timer.run(|| {
            let _ = gemm_tiled(&a, m, kk, &b, n, threads);
        });
        let flops = 2.0 * (m * kk * n) as f64;
        let speedup = s_scalar.mean() / s_tiled.mean();
        worst_speedup = worst_speedup.min(speedup);
        table.row(vec![
            format!("{m}x{kk} @ {kk}x{n} ({label})"),
            format!("{:.1}ms", s_scalar.mean() * 1e3),
            format!("{:.1}ms", s_tiled1.mean() * 1e3),
            format!("{:.1}ms", s_tiled.mean() * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.2}", flops / s_tiled.mean() / 1e9),
            "yes".to_string(),
        ]);
        json.set(
            &format!("m{m}_k{kk}_n{n}"),
            Json::obj(vec![
                ("label", Json::Str(label.to_string())),
                ("scalar", BenchJson::summary_json(&s_scalar)),
                ("tiled_1t", BenchJson::summary_json(&s_tiled1)),
                ("tiled_nt", BenchJson::summary_json(&s_tiled)),
                ("threads", Json::Num(threads as f64)),
                ("speedup_vs_scalar", Json::Num(speedup)),
                ("gflops_nt", Json::Num(flops / s_tiled.mean() / 1e9)),
                ("bitwise_across_threads", Json::Bool(bitwise)),
            ]),
        );
    }
    table.print();
    json.set_num("worst_speedup_vs_scalar", worst_speedup);
    let path = json.write()?;
    println!(
        "worst tiled({threads}T) speedup vs scalar: {worst_speedup:.2}x \
         (acceptance: >= 2x on a >= 4-core host); results -> {}",
        path.display()
    );
    Ok(())
}

// ====================================================================
// §Pipelining: multi-request throughput on the *real* coordinator,
// round-barrier vs pipelined engine (the PR-1 tentpole measurement).
// ====================================================================
pub fn throughput(scale: Scale) -> Result<()> {
    use crate::runtime::FallbackProvider;
    // for_pool: 4 in-proc workers share this host's cores — splitting
    // the kernel-thread budget keeps the latency comparison clean.
    throughput_with(
        4,
        std::sync::Arc::new(FallbackProvider::for_pool(4)),
        "fallback",
        scale.trials.clamp(4, 16),
    )
}

/// The throughput measurement itself, parameterized so bench drivers
/// (`bench_e2e`) can run it with their own pool size / provider. The
/// pipelined column runs through the streaming serving API
/// (`InferenceServer` submit/handle), which also yields the per-request
/// sojourn percentiles the makespan alone hides.
pub fn throughput_with(
    n: usize,
    provider: std::sync::Arc<dyn crate::runtime::ConvProvider>,
    prov_name: &str,
    batch: usize,
) -> Result<()> {
    use crate::coordinator::{
        InferenceRequest, InferenceServer, LocalCluster, MasterConfig, ScenarioFaults,
        SchemeKind, ServerConfig, WorkerFaults,
    };
    use crate::coordinator::ExecMode;
    use crate::sim::percentile;

    // k < n so MDS keeps redundancy on every pool size.
    let k = (n - 1).min(4).max(1);
    let mut table = Table::new(
        &format!(
            "Throughput — tinyvgg, n={n} in-proc workers, k={k}, batch={batch} \
             requests, provider={prov_name}"
        ),
        &["scheme", "faults", "barrier", "pipelined", "speedup", "req p50/p95"],
    );
    let healthy = || (0..n).map(|_| WorkerFaults::none()).collect::<Vec<_>>();
    let cases: [(SchemeKind, &str, Vec<WorkerFaults>); 3] = [
        (SchemeKind::Mds, "none", healthy()),
        // 10 ms mean extra send delay per subtask: the regime where
        // cancelling stragglers pays off.
        (SchemeKind::Mds, "straggle λ=0.5", ScenarioFaults::straggling(n, 0.5, 0.010)),
        (SchemeKind::Uncoded, "none", healthy()),
    ];
    let inputs_for = |batch: usize| -> Vec<crate::conv::Tensor> {
        let mut rng = Rng::new(42);
        (0..batch)
            .map(|_| {
                let mut t = crate::conv::Tensor::zeros(3, 56, 56);
                rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
                t
            })
            .collect()
    };
    for (scheme, faults_name, faults) in cases {
        let config = |mode: ExecMode| MasterConfig {
            scheme,
            policy: SplitPolicy::Fixed(k),
            mode,
            ..Default::default()
        };
        // Round barrier: the blocking batch path.
        let barrier = {
            let mut cluster = LocalCluster::spawn(
                "tinyvgg",
                n,
                config(ExecMode::RoundBarrier),
                provider.clone(),
                faults.clone(),
            )?;
            let inputs = inputs_for(batch);
            let _ = cluster.master.infer(&inputs[0])?; // warmup
            let t0 = std::time::Instant::now();
            let _ = cluster.master.infer_batch(&inputs)?;
            let dt = t0.elapsed().as_secs_f64();
            cluster.shutdown()?;
            dt
        };
        // Pipelined: submit the batch through the serving front-end and
        // record each request's submit→completion sojourn.
        let (pipe, lats) = {
            let cluster = LocalCluster::spawn(
                "tinyvgg",
                n,
                config(ExecMode::Pipelined),
                provider.clone(),
                faults.clone(),
            )?;
            let (mut master, workers) = cluster.into_parts();
            let inputs = inputs_for(batch);
            let _ = master.infer(&inputs[0])?; // warmup before serving
            let server = InferenceServer::start(
                master,
                ServerConfig {
                    queue_capacity: batch.max(1),
                    ..Default::default()
                },
            );
            let t0 = std::time::Instant::now();
            let mut handles = Vec::with_capacity(batch);
            for input in &inputs {
                let h = server
                    .submit(InferenceRequest::new(input.clone()))
                    .map_err(|e| anyhow::anyhow!("submit failed: {e}"))?;
                handles.push(h);
            }
            // Sojourns are engine-stamped, so awaiting in submission
            // order measures each request exactly.
            let mut lats = Vec::with_capacity(handles.len());
            for h in handles {
                let (res, sojourn) = h.wait_timed();
                res.map_err(|e| anyhow::anyhow!("request failed: {e}"))?;
                lats.push(sojourn.as_secs_f64());
            }
            let dt = t0.elapsed().as_secs_f64();
            let master = server.shutdown()?;
            master.shutdown();
            workers.join()?;
            (dt, lats)
        };
        table.row(vec![
            scheme.name().to_string(),
            faults_name.to_string(),
            format!("{:.0}ms ({:.1} req/s)", barrier * 1e3, batch as f64 / barrier),
            format!("{:.0}ms ({:.1} req/s)", pipe * 1e3, batch as f64 / pipe),
            format!("{:.2}x", barrier / pipe),
            format!(
                "{:.0}/{:.0}ms",
                percentile(&lats, 0.50) * 1e3,
                percentile(&lats, 0.95) * 1e3
            ),
        ]);
    }
    table.print();
    println!(
        "(pipelined engine: requests multiplexed over the pool via the serving \
         API, decode overlapped with other requests' compute, stragglers \
         cancelled; identical outputs to the barrier path — see \
         rust/tests/pipeline.rs and rust/tests/serving.rs)"
    );
    Ok(())
}

// ====================================================================
// §Serving: open-loop Poisson load through the serving stack — latency
// percentiles + shed rate, barrier vs pipelined vs pipelined+adaptive.
// Emits BENCH_serving.json and *fails* if the pipelined engine loses to
// the barrier on p95 at any load point (the API-redesign acceptance
// gate, validated per-trial in rust/tests as well).
// ====================================================================
pub fn serving(scale: Scale) -> Result<()> {
    use crate::sim::{
        simulate_serving_open, simulate_serving_open_with, simulate_serving_tenants, ServeKnobs,
        ServeSimMode, TenantLoad,
    };
    use crate::util::json::Json;

    let model = zoo::model("vgg16")?;
    let p = SystemProfile::paper_default();
    let n = 10;
    let method = MethodSim::CocoiKCirc;
    let scenario = Scenario::Straggling { lambda_tr: 0.5 };
    let arrivals = (scale.trials * 25).clamp(100, 600);
    let modes = [
        ServeSimMode::Barrier,
        ServeSimMode::Pipelined,
        ServeSimMode::PipelinedAdaptive,
    ];

    // Pilot: mean isolated service time (16 non-overlapping requests)
    // fixes the load scale.
    let service = {
        let mut rng = Rng::new(0x5E21);
        let r = simulate_serving_open(
            &model, &p, n, method, scenario,
            ServeSimMode::Barrier, 1e-9, 16, None, &mut rng,
        )?;
        r.latencies.iter().sum::<f64>() / r.latencies.len() as f64
    };

    let mut json = BenchJson::new("serving");
    json.set_num("n_workers", n as f64);
    json.set_num("arrivals", arrivals as f64);
    json.set_num("isolated_service_s", service);
    json.set("scenario", Json::Str(scenario.label()));

    // -- sweep 1: offered load, no deadlines (the p95 gate) -----------
    // Loads are relative to the *barrier's* capacity and start at its
    // saturation point: that is the regime that motivates pipelined
    // serving. (Below saturation both engines are stable and the FIFO
    // barrier keeps the classic tail advantage for near-deterministic
    // service times — pipelining buys capacity headroom there, which is
    // exactly what these points measure.)
    let rhos = [1.05, 1.15, 1.3];
    // The engine-knob arms: cross-request coalescing alone, and
    // coalescing + 2 worker slots (the full PR-5 configuration).
    let knob_arms: [(&str, ServeKnobs); 2] = [
        (
            "pipelined+coal4",
            ServeKnobs {
                coalesce: 4,
                ..ServeKnobs::default()
            },
        ),
        (
            "pipelined+coal4+slots2",
            ServeKnobs {
                coalesce: 4,
                worker_slots: 2,
                ..ServeKnobs::default()
            },
        ),
    ];
    let mut table = Table::new(
        &format!(
            "Serving — vgg16 open-loop sim, n={n}, {arrivals} Poisson arrivals per \
             point, {} (offered load relative to the barrier's capacity)",
            scenario.label()
        ),
        &["offered load", "mode", "p50", "p95", "p99", "mean"],
    );
    let mut gate_ok = true;
    let mut coal_gate_ok = true;
    for &rho in &rhos {
        let rate = rho / service;
        let mut barrier_p95 = f64::NAN;
        let mut pipelined_p95 = f64::NAN;
        for mode in modes {
            let mut rng = Rng::new(0x5EE5 ^ (rho * 100.0) as u64);
            let r = simulate_serving_open(
                &model, &p, n, method, scenario, mode, rate, arrivals, None, &mut rng,
            )?;
            if mode == ServeSimMode::Barrier {
                barrier_p95 = r.p95();
            } else if mode == ServeSimMode::Pipelined {
                pipelined_p95 = r.p95();
                if !(r.p95() <= barrier_p95 * (1.0 + 1e-9)) {
                    gate_ok = false;
                }
            }
            table.row(vec![
                format!("{rho:.2}"),
                r.mode.to_string(),
                fmt_secs(r.p50()),
                fmt_secs(r.p95()),
                fmt_secs(r.p99()),
                fmt_secs(r.mean()),
            ]);
            json.set(
                &format!("load{:02.0}_{}", rho * 100.0, r.mode),
                Json::obj(vec![
                    ("rate_rps", Json::Num(rate)),
                    ("p50_s", Json::Num(r.p50())),
                    ("p95_s", Json::Num(r.p95())),
                    ("p99_s", Json::Num(r.p99())),
                    ("mean_s", Json::Num(r.mean())),
                    ("served", Json::Num(r.latencies.len() as f64)),
                ]),
            );
        }
        // Coalescing / worker-slot arms, same seed ⇒ identical draws as
        // the uncoalesced pipelined arm. HARD gate: batching same-layer
        // shards must not lose on p95 at (or beyond) saturation.
        for (label, knobs) in knob_arms {
            let mut rng = Rng::new(0x5EE5 ^ (rho * 100.0) as u64);
            let r = simulate_serving_open_with(
                &model,
                &p,
                n,
                method,
                scenario,
                ServeSimMode::Pipelined,
                rate,
                arrivals,
                None,
                knobs,
                &mut rng,
            )?;
            if knobs.worker_slots <= 1 && !(r.p95() <= pipelined_p95 * (1.0 + 1e-9)) {
                coal_gate_ok = false;
            }
            table.row(vec![
                format!("{rho:.2}"),
                label.to_string(),
                fmt_secs(r.p50()),
                fmt_secs(r.p95()),
                fmt_secs(r.p99()),
                fmt_secs(r.mean()),
            ]);
            json.set(
                &format!("load{:02.0}_{}", rho * 100.0, label),
                Json::obj(vec![
                    ("rate_rps", Json::Num(rate)),
                    ("coalesce", Json::Num(knobs.coalesce as f64)),
                    ("worker_slots", Json::Num(knobs.worker_slots as f64)),
                    ("p50_s", Json::Num(r.p50())),
                    ("p95_s", Json::Num(r.p95())),
                    ("p99_s", Json::Num(r.p99())),
                    ("mean_s", Json::Num(r.mean())),
                    ("served", Json::Num(r.latencies.len() as f64)),
                ]),
            );
        }
    }
    table.print();

    // -- sweep 1c: per-layer scheme selection (`--scheme auto`) -------
    // The selector's contract: never lose to the always-k° MDS plan.
    // Calm and drifted pools tie *bitwise* (the selector keeps the MDS
    // plan, so the rng stream is identical — see
    // `sim::runner::auto_select_delegates_bitwise`); under mass churn
    // (9 of 10 workers lost every round) the LT flip must win outright:
    // a fixed-rate round pays the failure timeout plus a serial
    // re-dispatch chain on the lone survivor, while the rateless round
    // just loses symbols and completes from the bounded local fallback.
    // HARD gate: auto p95 <= k-circ p95 at every swept scenario x load
    // point, with and without the deadline shedder.
    let sel_deadline = 3.0 * service;
    let sel_arms: [(&str, Scenario, Option<f64>); 5] = [
        ("calm", Scenario::None, None),
        ("calm+deadline", Scenario::None, Some(sel_deadline)),
        ("drift", Scenario::Straggling { lambda_tr: 0.5 }, None),
        (
            "drift+deadline",
            Scenario::Straggling { lambda_tr: 0.5 },
            Some(sel_deadline),
        ),
        ("churn9", Scenario::Failures { n_f: 9 }, None),
    ];
    let mut sel_gate_ok = true;
    let mut table = Table::new(
        &format!(
            "Serving — scheme selector: `--scheme auto` vs always-k° MDS \
             (vgg16, n={n}, {arrivals} Poisson arrivals per point)"
        ),
        &["scenario", "offered load", "method", "p50", "p95", "shed", "gate"],
    );
    for (label, sc, dl) in sel_arms {
        for &rho in &rhos {
            let rate = rho / service;
            let mut kcirc_p95 = f64::NAN;
            for method in [MethodSim::CocoiKCirc, MethodSim::AutoSelect] {
                let mut rng = Rng::new(0x5EE5 ^ (rho * 100.0) as u64);
                let r = simulate_serving_open(
                    &model,
                    &p,
                    n,
                    method,
                    sc,
                    ServeSimMode::Pipelined,
                    rate,
                    arrivals,
                    dl,
                    &mut rng,
                )?;
                let gate = if method == MethodSim::CocoiKCirc {
                    kcirc_p95 = r.p95();
                    "-".to_string()
                } else {
                    let ok = r.p95() <= kcirc_p95 * (1.0 + 1e-9);
                    if !ok {
                        sel_gate_ok = false;
                    }
                    (if ok { "ok" } else { "LOST" }).to_string()
                };
                table.row(vec![
                    label.to_string(),
                    format!("{rho:.2}"),
                    method.label().to_string(),
                    fmt_secs(r.p50()),
                    fmt_secs(r.p95()),
                    format!("{:.1}%", 100.0 * r.shed_rate()),
                    gate,
                ]);
                json.set(
                    &format!("sel_{label}_load{:02.0}_{}", rho * 100.0, method.label()),
                    Json::obj(vec![
                        ("rate_rps", Json::Num(rate)),
                        ("scenario", Json::Str(sc.label())),
                        ("deadline_s", Json::Num(dl.unwrap_or(0.0))),
                        ("p50_s", Json::Num(r.p50())),
                        ("p95_s", Json::Num(r.p95())),
                        ("p99_s", Json::Num(r.p99())),
                        ("mean_s", Json::Num(r.mean())),
                        ("shed_rate", Json::Num(r.shed_rate())),
                        ("served", Json::Num(r.latencies.len() as f64)),
                    ]),
                );
            }
        }
    }
    table.print();

    // -- sweep 1d: two-tenant starvation (the multi-tenant gate) ------
    // A trickle "victim" tenant (0.25x capacity, weight 16) shares the
    // box with a flooding tenant (rho x capacity, weight 1). Per-tenant
    // rng seeds make the victim's arrival/service draws bitwise
    // identical across the isolated, fair, and FIFO arms, so any latency
    // difference is pure scheduling interference. HARD gate: under
    // weighted fair sharing the victim's p95 stays within 1.2x of its
    // isolated p95 at every swept flood level (its guaranteed share is
    // 16/17, so the fluid bound is 1.0625x; 1.2x covers the DRR
    // quantization the live engine adds). The FIFO arm is the
    // pre-tenancy baseline the gate exists to rule out.
    let victim = TenantLoad {
        name: "victim".into(),
        rate: 0.25 / service,
        weight: 16.0,
        seed: 0xF00D1,
    };
    let tenant_horizon = (arrivals as f64 / 2.0) * service;
    let iso = simulate_serving_tenants(
        &model, &p, n, method, scenario, std::slice::from_ref(&victim),
        tenant_horizon, None, true,
    )?;
    json.set(
        "tenant_isolated_victim",
        Json::obj(vec![
            ("rate_rps", Json::Num(victim.rate)),
            ("arrivals", Json::Num(iso[0].arrivals as f64)),
            ("p50_s", Json::Num(iso[0].p50())),
            ("p95_s", Json::Num(iso[0].p95())),
            ("mean_s", Json::Num(iso[0].mean())),
        ]),
    );
    let mut starve_gate_ok = true;
    let mut table = Table::new(
        &format!(
            "Serving — two-tenant starvation: victim (0.25x, weight 16) vs \
             flooder (weight 1), isolated victim p95 {} ({} victim arrivals)",
            fmt_secs(iso[0].p95()),
            iso[0].arrivals
        ),
        &["flood load", "arm", "victim p50", "victim p95", "vs isolated", "gate"],
    );
    for &rho in &rhos {
        let flooder = TenantLoad {
            name: "flooder".into(),
            rate: rho / service,
            weight: 1.0,
            seed: 0xF00D2,
        };
        for (arm, fair) in [("fair", true), ("fifo", false)] {
            let out = simulate_serving_tenants(
                &model, &p, n, method, scenario,
                &[victim.clone(), flooder.clone()],
                tenant_horizon, None, fair,
            )?;
            let ratio = out[0].p95() / iso[0].p95();
            let gate = if fair {
                let ok = ratio <= 1.2;
                if !ok {
                    starve_gate_ok = false;
                }
                (if ok { "ok" } else { "STARVED" }).to_string()
            } else {
                "-".to_string()
            };
            table.row(vec![
                format!("{rho:.2}"),
                arm.to_string(),
                fmt_secs(out[0].p50()),
                fmt_secs(out[0].p95()),
                format!("{ratio:.2}x"),
                gate,
            ]);
            json.set(
                &format!("tenant_flood{:02.0}_{arm}", rho * 100.0),
                Json::obj(vec![
                    ("flood_rate_rps", Json::Num(flooder.rate)),
                    ("victim_p50_s", Json::Num(out[0].p50())),
                    ("victim_p95_s", Json::Num(out[0].p95())),
                    ("victim_vs_isolated", Json::Num(ratio)),
                    ("flooder_p50_s", Json::Num(out[1].p50())),
                    ("flooder_p95_s", Json::Num(out[1].p95())),
                    ("flooder_arrivals", Json::Num(out[1].arrivals as f64)),
                ]),
            );
        }
    }
    table.print();

    // -- sweep 1b: watchdog hedging under a chronic straggler ---------
    // Hedging is the reliability layer's latency mechanism. The regime
    // where it is the *only* defense: the uncoded method (needs every
    // shard) with one worker computing 3x slow — every round waits on
    // the straggler's shard unless the fitted-quantile backup races
    // past it. HARD gate: hedged p95 <= unhedged p95 at every swept
    // load, same seed per point.
    let chronic = Scenario::FailuresPlusStraggler {
        n_f: 0,
        slowdown: 3.0,
    };
    let chronic_service = {
        let mut rng = Rng::new(0x5E22);
        let r = simulate_serving_open(
            &model, &p, n, MethodSim::Uncoded, chronic,
            ServeSimMode::Barrier, 1e-9, 16, None, &mut rng,
        )?;
        r.latencies.iter().sum::<f64>() / r.latencies.len() as f64
    };
    let mut hedge_gate_ok = true;
    let mut table = Table::new(
        &format!(
            "Serving — watchdog hedging: uncoded under {} ({arrivals} Poisson \
             arrivals per point)",
            chronic.label()
        ),
        &["offered load", "mode", "p50", "p95", "p99", "mean"],
    );
    for &rho in &rhos {
        let rate = rho / chronic_service;
        let mut plain_p95 = f64::NAN;
        for (label, q) in [("pipelined", 0.0), ("pipelined+hedge.95", 0.95)] {
            let mut rng = Rng::new(0x5EE5 ^ (rho * 100.0) as u64);
            let r = simulate_serving_open_with(
                &model,
                &p,
                n,
                MethodSim::Uncoded,
                chronic,
                ServeSimMode::Pipelined,
                rate,
                arrivals,
                None,
                ServeKnobs {
                    hedge_quantile: q,
                    ..ServeKnobs::default()
                },
                &mut rng,
            )?;
            if q == 0.0 {
                plain_p95 = r.p95();
            } else if !(r.p95() <= plain_p95 * (1.0 + 1e-9)) {
                hedge_gate_ok = false;
            }
            table.row(vec![
                format!("{rho:.2}"),
                label.to_string(),
                fmt_secs(r.p50()),
                fmt_secs(r.p95()),
                fmt_secs(r.p99()),
                fmt_secs(r.mean()),
            ]);
            json.set(
                &format!("straggler{:02.0}_{label}", rho * 100.0),
                Json::obj(vec![
                    ("rate_rps", Json::Num(rate)),
                    ("hedge_quantile", Json::Num(q)),
                    ("p50_s", Json::Num(r.p50())),
                    ("p95_s", Json::Num(r.p95())),
                    ("p99_s", Json::Num(r.p99())),
                    ("mean_s", Json::Num(r.mean())),
                    ("served", Json::Num(r.latencies.len() as f64)),
                ]),
            );
        }
    }
    table.print();

    // -- sweep 2: deadline shedding in overload -----------------------
    let deadline = 3.0 * service;
    let rate = 1.2 / service; // past the barrier's capacity: sheds must kick in
    let mut table = Table::new(
        &format!(
            "Serving — deadline {}: shed rate at offered load 1.20 \
             ({arrivals} arrivals)",
            fmt_secs(deadline)
        ),
        &["mode", "served p50", "served p95", "shed"],
    );
    for mode in modes {
        let mut rng = Rng::new(0xDEAD11);
        let r = simulate_serving_open(
            &model, &p, n, method, scenario, mode, rate, arrivals,
            Some(deadline), &mut rng,
        )?;
        table.row(vec![
            r.mode.to_string(),
            fmt_secs(r.p50()),
            fmt_secs(r.p95()),
            format!("{:.1}%", 100.0 * r.shed_rate()),
        ]);
        json.set(
            &format!("deadline_{}", r.mode),
            Json::obj(vec![
                ("deadline_s", Json::Num(deadline)),
                ("rate_rps", Json::Num(rate)),
                ("p50_s", Json::Num(r.p50())),
                ("p95_s", Json::Num(r.p95())),
                ("shed_rate", Json::Num(r.shed_rate())),
                ("served", Json::Num(r.latencies.len() as f64)),
            ]),
        );
    }
    table.print();

    // -- sweep 3: live traced run through the real serving stack ------
    // Everything above is the event-driven sim; this drives the actual
    // LocalCluster + InferenceServer with the span recorder attached and
    // faults injected, so the hedge-win / hedge-loss / fallback latency
    // percentiles below come from the obs::hist histograms the engine
    // fills on a real run. Two runs share the load:
    //   A) one black-hole worker → the watchdog hedges every round
    //      (hedge_win/hedge_loss samples, full span trees, the scrape);
    //   B) total pool stall with hedging off → the master-local decode
    //      fallback (fallback_latency samples). Its histograms are
    //      MERGED into A's — the property that makes them aggregable
    //      across masters.
    // Artifacts land next to BENCH_serving.json: TRACE_serving.json
    // (Chrome trace-event JSON, Perfetto-loadable) and
    // SCRAPE_serving.prom (Prometheus text, schema-checked here).
    let live = {
        use crate::conv::Tensor;
        use crate::coordinator::{
            ExecMode, InferenceRequest, InferenceServer, LocalCluster, MasterConfig, PoolOptions,
            SchemeKind, ServerConfig, WorkerFaults,
        };
        use crate::model::graph::forward_local;
        use crate::model::WeightStore;
        use crate::obs::trace::TraceHandle;
        use crate::planner::SplitPolicy;
        use crate::runtime::FallbackProvider;
        use std::sync::Arc;

        let live_model = zoo::model("tinyvgg")?;
        let weights = WeightStore::generate(&live_model, 42)?;
        let n_req = (scale.trials / 4).clamp(4, 8);
        let mut rng = Rng::new(0x0B5E);
        let inputs: Vec<Tensor> = (0..n_req)
            .map(|_| {
                let mut t = Tensor::zeros(live_model.input.0, live_model.input.1, live_model.input.2);
                rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
                t
            })
            .collect();
        let refs: Vec<Tensor> = inputs
            .iter()
            .map(|i| forward_local(&live_model, &weights, i))
            .collect::<Result<_>>()?;

        // Run A: uncoded n=3, worker 0 stalls forever → every round is
        // completed by a hedge racing past the fitted-quantile watchdog.
        let trace = TraceHandle::new(16_384);
        let mut faults: Vec<WorkerFaults> = (0..3).map(|_| WorkerFaults::none()).collect();
        faults[0] = WorkerFaults::none().stalls_in(0..4096);
        let cluster = LocalCluster::spawn_with(
            "tinyvgg",
            3,
            MasterConfig {
                scheme: SchemeKind::Uncoded,
                policy: SplitPolicy::Fixed(3),
                mode: ExecMode::Pipelined,
                trace: Some(trace.clone()),
                ..Default::default()
            },
            Arc::new(FallbackProvider::new()),
            faults,
            PoolOptions { worker_slots: 1 },
        )?;
        let (master, workers) = cluster.into_parts();
        let hub = master.metrics_hub();
        let server = InferenceServer::start(master, ServerConfig::default());
        let handles: Vec<_> = inputs
            .iter()
            .map(|i| server.submit(InferenceRequest::new(i.clone())))
            .collect::<std::result::Result<_, _>>()?;
        for (h, want) in handles.into_iter().zip(&refs) {
            let (out, _m) = h.wait()?;
            // Uncoded shards are bitwise-reproducible on any worker, and
            // tracing must not perturb the numerics.
            anyhow::ensure!(
                out.data == want.data,
                "traced live run diverged from local inference"
            );
        }
        let prom = server.scrape().to_prometheus();
        let master = server.shutdown()?;
        master.shutdown();
        workers.join()?;
        let mut hub = hub.snapshot();

        // Run B: every worker stalls, hedging off → only the master's
        // local decode fallback can finish the request.
        let cluster = LocalCluster::spawn_with(
            "tinyvgg",
            3,
            MasterConfig {
                scheme: SchemeKind::Uncoded,
                policy: SplitPolicy::Fixed(3),
                mode: ExecMode::Pipelined,
                hedge_quantile: 0.0,
                ..Default::default()
            },
            Arc::new(FallbackProvider::new()),
            (0..3).map(|_| WorkerFaults::none().stalls_in(0..4096)).collect(),
            PoolOptions { worker_slots: 1 },
        )?;
        let (master, workers) = cluster.into_parts();
        let hub_b = master.metrics_hub();
        let server = InferenceServer::start(master, ServerConfig::default());
        let h = server.submit(InferenceRequest::new(inputs[0].clone()))?;
        let (out, _m) = h.wait()?;
        anyhow::ensure!(out.data == refs[0].data, "fallback live run diverged from local");
        let master = server.shutdown()?;
        master.shutdown();
        workers.join()?;
        let hub_b = hub_b.snapshot();
        hub.fallback_latency.merge(&hub_b.fallback_latency);
        hub.sojourn.merge(&hub_b.sojourn);
        hub.gauges.fallbacks += hub_b.gauges.fallbacks;

        // Hard gates: the observability surface must actually have seen
        // the reliability machinery fire, the span trees must be
        // well-formed, and the scrape must pass the schema check.
        anyhow::ensure!(hub.gauges.hedges >= 1, "live run fired no hedges");
        anyhow::ensure!(
            hub.hedge_win.count() + hub.hedge_loss.count() >= 1,
            "no hedge outcome latency was recorded"
        );
        anyhow::ensure!(hub.gauges.fallbacks >= 1, "live run took no local fallback");
        anyhow::ensure!(hub.fallback_latency.count() >= 1, "no fallback latency was recorded");
        let viol = trace.violations();
        anyhow::ensure!(viol.is_empty(), "trace invariant violations: {viol:?}");
        let families = crate::obs::export::check_exposition(&prom)?;
        // 6 server + 19 hub + 5 tenant-labelled (requests flowed, so the
        // per-tenant families are present).
        anyhow::ensure!(
            families == 30,
            "serving scrape schema drifted: {families} families, expected 30"
        );

        let out_dir =
            std::path::PathBuf::from(std::env::var("COCOI_BENCH_OUT").unwrap_or_else(|_| ".".into()));
        let trace_path = out_dir.join("TRACE_serving.json");
        trace.export_chrome().write_file(&trace_path)?;
        let scrape_path = out_dir.join("SCRAPE_serving.prom");
        std::fs::write(&scrape_path, &prom)?;

        let mut table = Table::new(
            &format!(
                "Serving — live traced run (tinyvgg, {n_req}+1 requests): latency \
                 percentiles from the mergeable obs::hist histograms"
            ),
            &["histogram", "count", "p50", "p95", "p99"],
        );
        for (label, hist) in [
            ("queue_wait", &hub.queue_wait),
            ("sojourn", &hub.sojourn),
            ("hedge_win", &hub.hedge_win),
            ("hedge_loss", &hub.hedge_loss),
            ("fallback", &hub.fallback_latency),
        ] {
            table.row(vec![
                label.to_string(),
                format!("{}", hist.count()),
                fmt_secs(hist.quantile(0.50)),
                fmt_secs(hist.quantile(0.95)),
                fmt_secs(hist.quantile(0.99)),
            ]);
        }
        table.print();
        println!(
            "(live artifacts: trace -> {} [{} request trees, {} dropped], \
             scrape -> {} [{families} families])",
            trace_path.display(),
            trace.requests().len(),
            trace.dropped_requests(),
            scrape_path.display(),
        );

        Json::obj(vec![
            ("requests", Json::Num((n_req + 1) as f64)),
            ("hedges", Json::Num(hub.gauges.hedges as f64)),
            ("fallbacks", Json::Num(hub.gauges.fallbacks as f64)),
            ("queue_wait_s", hub.queue_wait.to_json()),
            ("sojourn_s", hub.sojourn.to_json()),
            ("hedge_win_s", hub.hedge_win.to_json()),
            ("hedge_loss_s", hub.hedge_loss.to_json()),
            ("fallback_s", hub.fallback_latency.to_json()),
        ])
    };
    json.set("live_traced", live);

    json.set("gate_pipelined_p95_le_barrier", Json::Bool(gate_ok));
    json.set("gate_coalesced_p95_le_uncoalesced", Json::Bool(coal_gate_ok));
    json.set("gate_hedged_p95_le_unhedged", Json::Bool(hedge_gate_ok));
    json.set("gate_auto_p95_le_kcirc", Json::Bool(sel_gate_ok));
    json.set("gate_starvation", Json::Bool(starve_gate_ok));
    let path = json.write()?;
    println!(
        "(open-loop Poisson arrivals through the serving stack; gates: pipelined \
         p95 <= barrier p95 — {} — coalesced p95 <= uncoalesced pipelined \
         p95 — {} — hedged p95 <= unhedged p95 under the chronic \
         straggler — {} — `--scheme auto` p95 <= always-k° p95 across \
         the selector sweep — {} — and fair-shared victim p95 <= 1.2x its \
         isolated p95 under tenant flooding — {} — at every swept point) \
         results -> {}",
        if gate_ok { "PASS" } else { "FAIL" },
        if coal_gate_ok { "PASS" } else { "FAIL" },
        if hedge_gate_ok { "PASS" } else { "FAIL" },
        if sel_gate_ok { "PASS" } else { "FAIL" },
        if starve_gate_ok { "PASS" } else { "FAIL" },
        path.display()
    );
    anyhow::ensure!(
        gate_ok,
        "pipelined serving lost to the barrier on p95 at equal offered load"
    );
    anyhow::ensure!(
        coal_gate_ok,
        "coalesced serving lost to the uncoalesced pipelined engine on p95"
    );
    anyhow::ensure!(
        hedge_gate_ok,
        "hedged dispatch lost to the unhedged engine on p95 under the chronic straggler"
    );
    anyhow::ensure!(
        sel_gate_ok,
        "`--scheme auto` lost to the always-k-circ plan on p95 in the selector sweep"
    );
    anyhow::ensure!(
        starve_gate_ok,
        "fair sharing failed to protect the victim tenant from the flooder \
         (victim p95 > 1.2x isolated p95)"
    );
    Ok(())
}

// ====================================================================
// §Telemetry: adaptive replanning vs the static calibrated plan under
// drifting worker capacities. Emits BENCH_adaptive.json and *fails* if
// the adaptive plan regresses the static baseline on the no-drift
// scenario (the CI sanity gate: hysteresis must prevent plan thrash).
// ====================================================================
pub fn adaptive(scale: Scale) -> Result<()> {
    use crate::sim::{simulate_adaptive, DriftScenario};
    use crate::telemetry::EventKind;
    use crate::util::json::Json;

    let model = zoo::model("vgg16")?;
    let p = SystemProfile::paper_default();
    let n = 10;
    let n_req = 32;
    let drift_at = 8;
    let measure_from = 16; // post-drift, post-adaptation window
    let seeds: u64 = if scale.trials <= 8 { 2 } else { 4 };

    let scenarios: [DriftScenario; 4] = [
        DriftScenario::None,
        DriftScenario::ComputeSlowdown { m: 3, factor: 3.0, at: drift_at },
        DriftScenario::DieAndReturn { worker: 2, down_at: 6, up_at: 18 },
        DriftScenario::TransmissionCongestion { factor: 30.0, at: drift_at },
    ];
    let mut table = Table::new(
        &format!("Adaptive replanning — vgg16 sim, n={n}, {n_req} requests, drift at {drift_at}"),
        &["scenario", "static", "adaptive", "ratio", "switches", "quarantines", "reintegrations"],
    );
    let mut json = BenchJson::new("adaptive");
    json.set_num("n_workers", n as f64);
    json.set_num("n_requests", n_req as f64);
    json.set_num("seeds", seeds as f64);
    let mut no_drift_ratio = 1.0;
    let mut drift_ratio = 1.0;
    for drift in scenarios {
        // Same seed for both policies: common random numbers, so the
        // difference is the plan, not sampling noise.
        let mut stat_mean = 0.0;
        let mut adap_mean = 0.0;
        let mut switches = 0u64;
        let mut quarantines = 0usize;
        let mut reintegrations = 0usize;
        for seed in 0..seeds {
            let mut rng = Rng::new(0xADA7 ^ seed);
            let stat = simulate_adaptive(&model, &p, n, drift, n_req, false, 4, &mut rng)?;
            let mut rng = Rng::new(0xADA7 ^ seed);
            let adap = simulate_adaptive(&model, &p, n, drift, n_req, true, 4, &mut rng)?;
            stat_mean += stat.mean_from(measure_from) / seeds as f64;
            adap_mean += adap.mean_from(measure_from) / seeds as f64;
            switches += adap.switches;
            quarantines += adap
                .events
                .iter()
                .filter(|e| e.kind != EventKind::Reintegrate)
                .count();
            reintegrations += adap
                .events
                .iter()
                .filter(|e| e.kind == EventKind::Reintegrate)
                .count();
        }
        let ratio = adap_mean / stat_mean;
        match drift {
            DriftScenario::None => no_drift_ratio = ratio,
            DriftScenario::ComputeSlowdown { .. } => drift_ratio = ratio,
            _ => {}
        }
        table.row(vec![
            drift.label(),
            fmt_secs(stat_mean),
            fmt_secs(adap_mean),
            format!("{ratio:.3}"),
            format!("{switches}"),
            format!("{quarantines}"),
            format!("{reintegrations}"),
        ]);
        json.set(
            &drift.label(),
            Json::obj(vec![
                ("static_mean_s", Json::Num(stat_mean)),
                ("adaptive_mean_s", Json::Num(adap_mean)),
                ("ratio", Json::Num(ratio)),
                ("plan_switches", Json::Num(switches as f64)),
                ("quarantines", Json::Num(quarantines as f64)),
                ("reintegrations", Json::Num(reintegrations as f64)),
            ]),
        );
    }
    table.print();
    json.set_num("no_drift_ratio", no_drift_ratio);
    json.set_num("drift_ratio", drift_ratio);
    let path = json.write()?;
    println!(
        "no-drift adaptive/static = {no_drift_ratio:.3} (gate: <= 1.02); \
         drift adaptive/static = {drift_ratio:.3} (want < 1); results -> {}",
        path.display()
    );
    anyhow::ensure!(
        no_drift_ratio <= 1.02,
        "adaptive plan regressed the static baseline with no drift \
         (ratio {no_drift_ratio:.3} > 1.02): hysteresis failed to prevent thrash"
    );
    anyhow::ensure!(
        drift_ratio < 1.0,
        "adaptive plan did not beat static under drift (ratio {drift_ratio:.3})"
    );
    Ok(())
}

// ====================================================================
// §IV-C theory check: Prop. 2's ~21% at n=20, R=1 + margins.
// ====================================================================
pub fn theory() -> Result<()> {
    use crate::latency::approx::{
        coded_margin_expectation, prop2_k_sub, uncoded_margin_expectation, TheoryConsts,
    };
    let dims = LayerDims::new(crate::conv::ConvSpec::new(128, 128, 3, 1, 1), 112, 112);
    let c = TheoryConsts::new(&dims);
    let mut table = Table::new(
        "Props. 2–3 — theoretical coded-vs-uncoded margin",
        &["n", "R", "k_sub*", "E[Tc]", "E[Tu]", "reduction"],
    );
    for n in [10usize, 15, 20] {
        for r_target in [0.5, 1.0] {
            let mut p = SystemProfile::paper_default();
            let ratio = r_target * c.h3(&p) / c.h2(&p);
            p.theta_rec *= ratio;
            p.theta_sen *= ratio;
            p.theta_cmp *= ratio;
            let k_sub = prop2_k_sub(n);
            let coded = coded_margin_expectation(&c, &p, n, k_sub);
            let uncoded = uncoded_margin_expectation(&c, &p, n);
            table.row(vec![
                format!("{n}"),
                format!("{r_target}"),
                format!("{k_sub:.2}"),
                fmt_secs(coded),
                fmt_secs(uncoded),
                format!("{:.1}%", 100.0 * (1.0 - coded / uncoded)),
            ]);
        }
    }
    table.print();
    println!("(paper §IV-C: n=20, R=1 ⇒ ≈21% reduction)");
    Ok(())
}
