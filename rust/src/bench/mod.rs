//! Shared experiment drivers: the code behind both `cargo bench` targets
//! (one per paper table/figure) and the `cocoi experiment` CLI.

pub mod harness;
pub mod experiments;

pub use harness::{BenchTimer, Table};
