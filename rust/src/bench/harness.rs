//! Tiny bench harness (no criterion in the offline vendor set): warmup +
//! timed iterations with mean/σ/min, an aligned-table printer used by
//! every experiment driver, and [`BenchJson`] — the machine-readable
//! `BENCH_<name>.json` emitter that accumulates the repo's perf
//! trajectory run over run.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Measure a closure: `warmup` untimed runs, then `iters` timed runs.
pub struct BenchTimer {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchTimer {
    fn default() -> Self {
        BenchTimer {
            warmup: 2,
            iters: 10,
        }
    }
}

impl BenchTimer {
    pub fn new(warmup: usize, iters: usize) -> BenchTimer {
        BenchTimer { warmup, iters }
    }

    /// Returns per-iteration seconds.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.push(t0.elapsed().as_secs_f64());
        }
        s
    }

    /// Report line in a criterion-ish format.
    pub fn report(&self, name: &str, s: &Summary) {
        println!(
            "{name:<44} {:>10.3} ms ± {:>8.3} (min {:.3}, n={})",
            s.mean() * 1e3,
            s.std() * 1e3,
            s.min() * 1e3,
            s.len()
        );
    }
}

/// Aligned text table (the "same rows the paper reports" printer).
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn new_owned(title: &str, header: Vec<String>) -> Table {
        Table {
            title: title.to_string(),
            header,
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Machine-readable benchmark record. Every bench/experiment driver can
/// dump its numbers as `BENCH_<name>.json` next to the human-readable
/// table, so perf changes are diffable run over run (CI uploads the
/// files as workflow artifacts).
///
/// Output directory: `$COCOI_BENCH_OUT` if set, else the current dir.
pub struct BenchJson {
    name: String,
    fields: BTreeMap<String, Json>,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        let mut fields = BTreeMap::new();
        fields.insert("bench".to_string(), Json::Str(name.to_string()));
        fields.insert("schema_version".to_string(), Json::Num(1.0));
        fields.insert(
            "unix_time".to_string(),
            Json::Num(
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_secs() as f64)
                    .unwrap_or(0.0),
            ),
        );
        fields.insert(
            "host_threads".to_string(),
            Json::Num(crate::util::threads::default_threads() as f64),
        );
        BenchJson {
            name: name.to_string(),
            fields,
        }
    }

    /// Record an arbitrary value under `key` (last write wins).
    pub fn set(&mut self, key: &str, value: Json) {
        self.fields.insert(key.to_string(), value);
    }

    pub fn set_num(&mut self, key: &str, x: f64) {
        self.set(key, Json::Num(x));
    }

    /// Record a timing summary (seconds) under `key`.
    pub fn summary_json(s: &Summary) -> Json {
        Json::obj(vec![
            ("mean_s", Json::Num(s.mean())),
            ("std_s", Json::Num(s.std())),
            ("min_s", Json::Num(s.min())),
            ("n", Json::Num(s.len() as f64)),
        ])
    }

    /// Where [`BenchJson::write`] will put the file.
    pub fn path(&self) -> PathBuf {
        let dir = std::env::var("COCOI_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
        PathBuf::from(dir).join(format!("BENCH_{}.json", self.name))
    }

    /// Write `BENCH_<name>.json` into `$COCOI_BENCH_OUT` (or the current
    /// dir); returns the path written.
    pub fn write(&self) -> anyhow::Result<PathBuf> {
        self.write_to(&self.path())
    }

    /// Write to an explicit path (tests use this so they never have to
    /// mutate the process-global environment).
    pub fn write_to(&self, path: &std::path::Path) -> anyhow::Result<PathBuf> {
        Json::Obj(self.fields.clone()).write_file(path)?;
        Ok(path.to_path_buf())
    }
}

/// Format seconds with adaptive precision.
pub fn fmt_secs(t: f64) -> String {
    if t >= 100.0 {
        format!("{t:.0}s")
    } else if t >= 1.0 {
        format!("{t:.1}s")
    } else {
        format!("{:.1}ms", t * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_counts_iters() {
        let mut count = 0;
        let s = BenchTimer::new(1, 5).run(|| count += 1);
        assert_eq!(count, 6);
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: no panic
        assert_eq!(fmt_secs(0.0123), "12.3ms");
        assert_eq!(fmt_secs(12.3), "12.3s");
    }

    #[test]
    fn bench_json_roundtrips() {
        use crate::util::json::Json;
        let mut bj = BenchJson::new("selftest");
        bj.set_num("speedup", 2.5);
        bj.set("case", BenchJson::summary_json(&Summary::from_slice(&[0.5, 1.5])));
        // Explicit target path: no process-global env mutation in tests.
        let dir = std::env::temp_dir().join("cocoi_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = bj.write_to(&dir.join("BENCH_selftest.json")).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_selftest.json");
        let v = Json::parse_file(&path).unwrap();
        assert_eq!(v.req_str("bench").unwrap(), "selftest");
        assert!((v.req_f64("speedup").unwrap() - 2.5).abs() < 1e-12);
        assert!((v.get("case").req_f64("mean_s").unwrap() - 1.0).abs() < 1e-12);
        assert!(bj.path().file_name().unwrap().to_str().unwrap().starts_with("BENCH_"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
