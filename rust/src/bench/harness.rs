//! Tiny bench harness (no criterion in the offline vendor set): warmup +
//! timed iterations with mean/σ/min, plus an aligned-table printer used by
//! every experiment driver.

use std::time::Instant;

use crate::util::stats::Summary;

/// Measure a closure: `warmup` untimed runs, then `iters` timed runs.
pub struct BenchTimer {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchTimer {
    fn default() -> Self {
        BenchTimer {
            warmup: 2,
            iters: 10,
        }
    }
}

impl BenchTimer {
    pub fn new(warmup: usize, iters: usize) -> BenchTimer {
        BenchTimer { warmup, iters }
    }

    /// Returns per-iteration seconds.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.push(t0.elapsed().as_secs_f64());
        }
        s
    }

    /// Report line in a criterion-ish format.
    pub fn report(&self, name: &str, s: &Summary) {
        println!(
            "{name:<44} {:>10.3} ms ± {:>8.3} (min {:.3}, n={})",
            s.mean() * 1e3,
            s.std() * 1e3,
            s.min() * 1e3,
            s.len()
        );
    }
}

/// Aligned text table (the "same rows the paper reports" printer).
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn new_owned(title: &str, header: Vec<String>) -> Table {
        Table {
            title: title.to_string(),
            header,
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Format seconds with adaptive precision.
pub fn fmt_secs(t: f64) -> String {
    if t >= 100.0 {
        format!("{t:.0}s")
    } else if t >= 1.0 {
        format!("{t:.1}s")
    } else {
        format!("{:.1}ms", t * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_counts_iters() {
        let mut count = 0;
        let s = BenchTimer::new(1, 5).run(|| count += 1);
        assert_eq!(count, 6);
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: no panic
        assert_eq!(fmt_secs(0.0123), "12.3ms");
        assert_eq!(fmt_secs(12.3), "12.3s");
    }
}
