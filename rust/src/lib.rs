//! # CoCoI — Distributed Coded Inference for Straggler Mitigation
//!
//! Reproduction of *"CoCoI: Distributed Coded Inference System for
//! Straggler Mitigation"* (Liu, Huang, Tang; CS.DC 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time, python)** — Pallas conv/GEMM kernels inside a
//!   JAX model, AOT-lowered to HLO text artifacts (`python/compile/`).
//! * **Layer 3 (this crate)** — the CoCoI coordinator: width-wise input
//!   splitting of 2D conv layers (eqs. 1–2 of the paper), `(n, k)`-MDS
//!   encoding of input partitions (eq. 3), dispatch to `n` workers, decode
//!   from the first `k` encoded outputs (eq. 4), plus the optimal-splitting
//!   planner built on the shift-exponential latency model (§III–IV).
//!
//! Python never runs on the request path: the rust binary loads the AOT
//! artifacts through PJRT (`runtime`) and coordinates everything itself.
//!
//! Crate map (one module per subsystem; see `DESIGN.md` for the inventory):
//!
//! * [`util`] — PRNG, statistics, JSON, logging, property-test substrate.
//! * [`coding`] — MDS / LT / replication / uncoded redundancy schemes.
//! * [`conv`] — NCHW tensors, conv-layer math, width splitting, im2col.
//! * [`model`] — CNN graph representation, VGG16/ResNet18 zoo, weights.
//! * [`latency`] — shift-exponential model, order statistics, `L(k)`.
//! * [`planner`] — `k°`/`k*` solvers, sensitivity + theory (Props. 1–3).
//! * [`runtime`] — PJRT executable cache + pure-rust fallback provider.
//! * [`transport`] — in-proc and TCP transports with a binary codec.
//! * [`coordinator`] — the master/worker pipeline with fault injection.
//! * [`telemetry`] — online capacity estimation + adaptive replanning.
//! * [`obs`] — span tracing, mergeable histograms, metrics scrape.
//! * [`sim`] — calibrated discrete-event simulator for the paper figures.
//! * [`bench`] — shared experiment drivers for `cargo bench` targets.

pub mod bench;
pub mod coding;
pub mod conv;
pub mod coordinator;
pub mod latency;
pub mod model;
pub mod obs;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod transport;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
