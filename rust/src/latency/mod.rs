//! The paper's latency model (§III): shift-exponential phase latencies
//! (Def. 1), order-statistics expectations, the per-phase FLOP/byte
//! scalings (eqs. 8–12), and the approximate objective `L(k)` (eq. 16)
//! with the App. C/F theory quantities.

pub mod approx;
pub mod order_stats;
pub mod phases;
pub mod shift_exp;

pub use phases::{LayerDims, SystemProfile};
pub use shift_exp::ShiftExp;
