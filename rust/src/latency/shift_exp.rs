//! Shift-exponential latency distribution (paper Definition 1) + MLE fit.
//!
//! `T ~ SE(μ, θ, N)`:  `F(t) = 1 − exp(−(μ/N)(t − Nθ))` for `t ≥ Nθ`.
//! `N` is the operation scale (FLOPs or bytes), `θ` the per-unit minimum
//! time, `μ` the straggler parameter (smaller μ ⇒ heavier tail). Mean is
//! `N(θ + 1/μ)`.

use crate::util::Rng;

/// A shift-exponential distribution with explicit scale `n` (`N` in the
/// paper; named `n_scale` here to avoid clashing with worker count `n`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShiftExp {
    /// Straggler parameter μ (> 0); smaller ⇒ stronger straggling.
    pub mu: f64,
    /// Shift coefficient θ (≥ 0): minimum per-unit completion time.
    pub theta: f64,
    /// Operation scale `N` (FLOPs / bytes).
    pub n_scale: f64,
}

impl ShiftExp {
    pub fn new(mu: f64, theta: f64, n_scale: f64) -> ShiftExp {
        assert!(mu > 0.0 && theta >= 0.0 && n_scale >= 0.0);
        ShiftExp { mu, theta, n_scale }
    }

    /// Minimum possible value `Nθ`.
    pub fn shift(&self) -> f64 {
        self.n_scale * self.theta
    }

    /// CDF (eq. 7).
    pub fn cdf(&self, t: f64) -> f64 {
        if self.n_scale == 0.0 {
            return if t >= 0.0 { 1.0 } else { 0.0 };
        }
        if t < self.shift() {
            0.0
        } else {
            1.0 - (-(self.mu / self.n_scale) * (t - self.shift())).exp()
        }
    }

    /// Mean `N(θ + 1/μ)`.
    pub fn mean(&self) -> f64 {
        self.n_scale * (self.theta + 1.0 / self.mu)
    }

    /// Quantile (inverse CDF): `t_q = Nθ + (N/μ)·ln(1/(1−q))` for
    /// `q ∈ [0, 1)`. The hedging watchdog uses this as "if the subtask
    /// isn't back by the fitted p-q point, speculate". Zero-scale
    /// distributions are instant at every quantile.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..1.0).contains(&q), "quantile requires q in [0, 1)");
        if self.n_scale == 0.0 {
            return 0.0;
        }
        self.shift() + (self.n_scale / self.mu) * (1.0 / (1.0 - q)).ln()
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        if self.n_scale == 0.0 {
            return 0.0;
        }
        self.shift() + rng.exponential(self.mu / self.n_scale)
    }

    /// The μ returned for degenerate windows (no spread information): a
    /// practically-deterministic distribution with negligible tail.
    pub const MU_DEGENERATE: f64 = 1e12;

    /// MLE fit given samples of an operation with known scale `n_scale`:
    /// `θ̂ = min(x)/N`, `μ̂ = N / mean(x − min)`. This is what the paper's
    /// "prior test and fitting" step produces (App. B).
    ///
    /// Degenerate inputs are routine for the online estimator (tiny
    /// telemetry windows) and get a documented fallback instead of a
    /// panic or NaN: an empty sample fits a zero-shift near-deterministic
    /// distribution, and a singleton or all-equal sample fits a pure
    /// shift at the observed value with [`ShiftExp::MU_DEGENERATE`].
    pub fn fit(samples: &[f64], n_scale: f64) -> ShiftExp {
        if samples.is_empty() {
            return ShiftExp::new(ShiftExp::MU_DEGENERATE, 0.0, n_scale);
        }
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let theta = (min / n_scale).max(0.0);
        if samples.len() == 1 {
            return ShiftExp::new(ShiftExp::MU_DEGENERATE, theta, n_scale);
        }
        let mean_excess =
            samples.iter().map(|x| x - min).sum::<f64>() / samples.len() as f64;
        // All-equal samples carry no spread information.
        let mu = if mean_excess > 0.0 {
            n_scale / mean_excess
        } else {
            ShiftExp::MU_DEGENERATE
        };
        ShiftExp::new(mu, theta, n_scale)
    }

    /// Robust fit with the top `trim_frac` of samples treated as
    /// *censored* (type-II) rather than discarded: each dropped sample
    /// contributes the largest kept excess to the exponential mean. For
    /// an exponential tail this keeps `μ̂` consistent for the underlying
    /// distribution (a plain trimmed mean would overestimate μ by
    /// ~1/(1−trim)·ln-factor), while scheduler spikes on virtualized
    /// hosts — far above the bulk — still cannot drag the estimate.
    pub fn fit_trimmed(samples: &[f64], n_scale: f64, trim_frac: f64) -> ShiftExp {
        if samples.len() < 2 {
            return ShiftExp::fit(samples, n_scale);
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let keep = (((s.len() as f64) * (1.0 - trim_frac)).ceil() as usize).clamp(2, s.len());
        let min = s[0];
        let tail_excess = s[keep - 1] - min;
        let censored_sum: f64 = s[..keep].iter().map(|x| x - min).sum::<f64>()
            + (s.len() - keep) as f64 * tail_excess;
        let mean_excess = censored_sum / keep as f64;
        let mu = if mean_excess > 0.0 {
            n_scale / mean_excess
        } else {
            ShiftExp::MU_DEGENERATE
        };
        ShiftExp::new(mu, (min / n_scale).max(0.0), n_scale)
    }

    /// Kolmogorov–Smirnov statistic vs an empirical sample (fit quality,
    /// used by the Fig. 8 reproduction).
    pub fn ks_statistic(&self, samples: &[f64]) -> f64 {
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len() as f64;
        s.iter()
            .enumerate()
            .map(|(i, &x)| {
                let f = self.cdf(x);
                let lo = i as f64 / n;
                let hi = (i + 1) as f64 / n;
                (f - lo).abs().max((f - hi).abs())
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_properties() {
        let d = ShiftExp::new(2.0, 0.5, 10.0);
        assert_eq!(d.cdf(4.9), 0.0); // below shift Nθ = 5
        assert!(d.cdf(5.0).abs() < 1e-12);
        assert!(d.cdf(1e9) > 0.999_999);
        // Median above shift: shift + ln2 * N/μ.
        let median = 5.0 + (10.0 / 2.0) * std::f64::consts::LN_2;
        assert!((d.cdf(median) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let d = ShiftExp::new(2.0, 0.5, 10.0);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99] {
            let t = d.quantile(q);
            assert!((d.cdf(t) - q).abs() < 1e-9, "q={q} t={t}");
            assert!(t >= d.shift());
        }
        // Degenerate fit: quantile collapses to (almost exactly) the shift.
        let f = ShiftExp::fit(&[4.0], 8.0);
        assert!((f.quantile(0.99) - 4.0).abs() < 1e-6);
        // Zero scale: instant.
        assert_eq!(ShiftExp::new(1.0, 1.0, 0.0).quantile(0.99), 0.0);
    }

    #[test]
    fn sample_mean_matches() {
        let d = ShiftExp::new(4.0, 0.25, 8.0);
        let mut rng = Rng::new(17);
        let m: f64 = (0..100_000).map(|_| d.sample(&mut rng)).sum::<f64>() / 100_000.0;
        assert!((m - d.mean()).abs() / d.mean() < 0.02, "m={m} vs {}", d.mean());
    }

    #[test]
    fn fit_recovers_parameters() {
        let truth = ShiftExp::new(5.0, 0.1, 100.0);
        let mut rng = Rng::new(23);
        let samples: Vec<f64> = (0..20_000).map(|_| truth.sample(&mut rng)).collect();
        let fit = ShiftExp::fit(&samples, 100.0);
        assert!((fit.theta - truth.theta).abs() / truth.theta < 0.05, "theta={}", fit.theta);
        assert!((fit.mu - truth.mu).abs() / truth.mu < 0.05, "mu={}", fit.mu);
        // Good fit => small KS statistic.
        assert!(fit.ks_statistic(&samples) < 0.02);
    }

    #[test]
    fn fit_trimmed_recovers_parameters() {
        // The censored-tail correction keeps μ̂ consistent even though 10%
        // of the sample is withheld from the mean.
        let truth = ShiftExp::new(5.0, 0.1, 100.0);
        let mut rng = Rng::new(31);
        let samples: Vec<f64> = (0..20_000).map(|_| truth.sample(&mut rng)).collect();
        let fit = ShiftExp::fit_trimmed(&samples, 100.0, 0.10);
        assert!((fit.theta - truth.theta).abs() / truth.theta < 0.05, "theta={}", fit.theta);
        assert!((fit.mu - truth.mu).abs() / truth.mu < 0.05, "mu={}", fit.mu);
    }

    #[test]
    fn fit_degenerate_inputs_fall_back() {
        // Empty: zero-shift, near-deterministic.
        let f = ShiftExp::fit(&[], 10.0);
        assert_eq!(f.mu, ShiftExp::MU_DEGENERATE);
        assert_eq!(f.theta, 0.0);
        // Singleton: pure shift at the observed value.
        let f = ShiftExp::fit(&[4.0], 8.0);
        assert_eq!(f.mu, ShiftExp::MU_DEGENERATE);
        assert!((f.theta - 0.5).abs() < 1e-12);
        assert!((f.mean() - 4.0).abs() < 1e-3, "mean={}", f.mean());
        // All-equal: pure shift, no NaN/div-by-zero.
        let f = ShiftExp::fit(&[2.0, 2.0, 2.0], 4.0);
        assert_eq!(f.mu, ShiftExp::MU_DEGENERATE);
        assert!((f.theta - 0.5).abs() < 1e-12);
        assert!(f.mean().is_finite());
        // Trimmed fit on tiny windows must not panic either.
        let f = ShiftExp::fit_trimmed(&[3.0], 3.0, 0.1);
        assert_eq!(f.mu, ShiftExp::MU_DEGENERATE);
        let f = ShiftExp::fit_trimmed(&[], 1.0, 0.1);
        assert_eq!(f.theta, 0.0);
        // Negative raw samples (clock skew) clamp θ at 0.
        let f = ShiftExp::fit(&[-1.0, 1.0], 1.0);
        assert_eq!(f.theta, 0.0);
        assert!(f.mu.is_finite() && f.mu > 0.0);
    }

    #[test]
    fn zero_scale_is_instant() {
        let d = ShiftExp::new(1.0, 1.0, 0.0);
        let mut rng = Rng::new(1);
        assert_eq!(d.sample(&mut rng), 0.0);
        assert_eq!(d.mean(), 0.0);
    }
}
