//! Per-phase latency scalings (paper §III-B, eqs. 8–12) and the system
//! latency profile (the μ/θ coefficients of Def. 1 for every phase).

use std::path::Path;

use anyhow::Result;

use crate::conv::ConvSpec;
use crate::util::json::Json;

use super::shift_exp::ShiftExp;

/// The dimensions a type-1 layer presents to the latency model: conv spec
/// plus the *padded* input and output feature-map geometry.
#[derive(Clone, Copy, Debug)]
pub struct LayerDims {
    pub spec: ConvSpec,
    /// Padded input height/width (`H_I`, `W_I`).
    pub h_i: usize,
    pub w_i: usize,
    /// Output height/width (`H_O`, `W_O`).
    pub h_o: usize,
    pub w_o: usize,
}

impl LayerDims {
    pub fn new(spec: ConvSpec, in_h: usize, in_w: usize) -> LayerDims {
        let h_i = in_h + 2 * spec.pad;
        let w_i = in_w + 2 * spec.pad;
        LayerDims {
            spec,
            h_i,
            w_i,
            h_o: spec.out_dim_padded(h_i),
            w_o: spec.out_dim_padded(w_i),
        }
    }

    /// Relaxed piece widths (real-valued `k`, floor dropped — the
    /// relaxation behind eq. 16). `W_O^p = W_O/k`, `W_I^p` per eq. (1).
    pub fn w_o_p(&self, k: f64) -> f64 {
        self.w_o as f64 / k
    }

    pub fn w_i_p(&self, k: f64) -> f64 {
        self.spec.k_w as f64 + (self.w_o_p(k) - 1.0) * self.spec.s_w as f64
    }

    /// eq. (8): encode FLOPs `2·k·n·C_I·H_I·W_I^p(k)`.
    pub fn n_enc(&self, n: usize, k: f64) -> f64 {
        2.0 * k * n as f64 * (self.spec.c_in * self.h_i) as f64 * self.w_i_p(k)
    }

    /// eq. (9): per-subtask compute FLOPs `2·C_O·H_O·W_O^p·C_I·K²`.
    pub fn n_cmp(&self, k: f64) -> f64 {
        (self.spec.c_out * self.h_o) as f64
            * self.w_o_p(k)
            * 2.0
            * (self.spec.c_in * self.spec.k_w * self.spec.k_w) as f64
    }

    /// eq. (10): input-partition bytes `4·C_I·H_I·W_I^p(k)`.
    pub fn n_rec(&self, k: f64) -> f64 {
        4.0 * (self.spec.c_in * self.h_i) as f64 * self.w_i_p(k)
    }

    /// eq. (11): output-partition bytes `4·C_O·H_O·W_O^p(k)`.
    pub fn n_sen(&self, k: f64) -> f64 {
        4.0 * (self.spec.c_out * self.h_o) as f64 * self.w_o_p(k)
    }

    /// eq. (12): decode FLOPs `2·k²·C_O·H_O·W_O^p(k)`.
    pub fn n_dec(&self, k: f64) -> f64 {
        2.0 * k * k * (self.spec.c_out * self.h_o) as f64 * self.w_o_p(k)
    }

    /// Full-layer conv FLOPs (uncoded local execution).
    pub fn full_flops(&self) -> f64 {
        self.spec.flops(self.h_o, self.w_o)
    }
}

/// System latency profile: the eight μ/θ coefficients of §III-B.
///
/// Units: θ in seconds *per scale unit* (per FLOP for compute phases, per
/// byte for transmission), μ dimensionless-per-scale as in Def. 1 (mean
/// excess latency of an operation of scale `N` is `N/μ`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SystemProfile {
    /// Master compute (encode/decode): μ^m, θ^m.
    pub mu_m: f64,
    pub theta_m: f64,
    /// Worker conv compute: μ^cmp, θ^cmp.
    pub mu_cmp: f64,
    pub theta_cmp: f64,
    /// Worker input receive: μ^rec, θ^rec.
    pub mu_rec: f64,
    pub theta_rec: f64,
    /// Worker output send: μ^sen, θ^sen.
    pub mu_sen: f64,
    pub theta_sen: f64,
    /// Fixed per-message overhead (seconds, per direction): WiFi MAC/RTT
    /// floor that does not scale with payload bytes. Not part of the
    /// paper's eq. 7 model (their N is bytes only) but present on any
    /// real link; it is what makes the finest-grained `LtCoI-k_l`
    /// "excessive transmission overhead" (§V-C) show up in simulation.
    pub theta_msg: f64,
}

impl SystemProfile {
    /// Default profile calibrated to the paper's testbed scale: Raspberry
    /// Pi 4B-class compute (≈0.6 GFLOP/s effective conv throughput — VGG16
    /// ≈ 30.7 GFLOP taking ≈50 s) and ≈100 Mbit/s WiFi (≈8 ns/byte).
    /// Natural (un-injected) straggling is mild — homogeneous devices:
    /// compute mean excess ≈ 20% of the deterministic part, transmission
    /// ≈ 50% (WiFi jitter) — so the scenario-1 injection, not the
    /// baseline, drives the straggle sweeps, as on the paper's testbed.
    /// Fig. 9 sweeps μ over 10⁶–10¹⁰ around these magnitudes.
    pub fn paper_default() -> SystemProfile {
        SystemProfile {
            mu_m: 5e9,
            theta_m: 1.0 / 2.0e9,
            mu_cmp: 1.7e9,
            theta_cmp: 1.0 / 0.6e9,
            // 100 Mbit/s WiFi *shared* through one AP by ~10 devices with
            // protocol overhead: ≈3 MB/s effective per worker during the
            // scatter/gather bursts (θ = 3.3e-7 s/byte), with ≈80% jitter.
            mu_rec: 3.8e6,
            theta_rec: 3.3e-7,
            mu_sen: 3.8e6,
            theta_sen: 3.3e-7,
            theta_msg: 4.0e-3,
        }
    }

    /// Per-model calibration: scale θ_cmp (keeping the μ·θ straggle ratio)
    /// so the model's total conv FLOPs reproduce a measured single-device
    /// latency — the App. B "prior test and fitting" step. The paper's
    /// measurements: VGG16 50.8 s, ResNet18 89.8 s (ResNet's small
    /// channel counts run far below peak on an RPi, so per-FLOP time is
    /// model-dependent).
    pub fn calibrated_for(&self, conv_flops: f64, measured_local_secs: f64) -> SystemProfile {
        let mut p = *self;
        let ratio = 1.0 / (self.mu_cmp * self.theta_cmp);
        p.theta_cmp = measured_local_secs / conv_flops / (1.0 + ratio);
        p.mu_cmp = 1.0 / (ratio * p.theta_cmp);
        p
    }

    // ---- per-phase distributions for a given layer/(n, k) --------------

    pub fn enc_dist(&self, dims: &LayerDims, n: usize, k: usize) -> ShiftExp {
        ShiftExp::new(self.mu_m, self.theta_m, dims.n_enc(n, k as f64))
    }

    pub fn dec_dist(&self, dims: &LayerDims, k: usize) -> ShiftExp {
        ShiftExp::new(self.mu_m, self.theta_m, dims.n_dec(k as f64))
    }

    pub fn cmp_dist(&self, dims: &LayerDims, k: usize) -> ShiftExp {
        ShiftExp::new(self.mu_cmp, self.theta_cmp, dims.n_cmp(k as f64))
    }

    pub fn rec_dist(&self, dims: &LayerDims, k: usize) -> ShiftExp {
        ShiftExp::new(self.mu_rec, self.theta_rec, dims.n_rec(k as f64))
    }

    pub fn sen_dist(&self, dims: &LayerDims, k: usize) -> ShiftExp {
        ShiftExp::new(self.mu_sen, self.theta_sen, dims.n_sen(k as f64))
    }

    /// Master-local compute distribution for an arbitrary FLOP count
    /// (encode/decode-class matmul work).
    pub fn master_dist(&self, flops: f64) -> ShiftExp {
        ShiftExp::new(self.mu_m, self.theta_m, flops)
    }

    /// Local *convolution* execution on a single device (type-2 layers,
    /// remainder pieces, and the App. A single-device baseline): the
    /// master is the same device class as the workers, so conv work runs
    /// at the θ_cmp/μ_cmp rate, not the matmul-encode rate.
    pub fn local_conv_dist(&self, flops: f64) -> ShiftExp {
        ShiftExp::new(self.mu_cmp, self.theta_cmp, flops)
    }

    // ---- (de)serialization ---------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mu_m", Json::Num(self.mu_m)),
            ("theta_m", Json::Num(self.theta_m)),
            ("mu_cmp", Json::Num(self.mu_cmp)),
            ("theta_cmp", Json::Num(self.theta_cmp)),
            ("mu_rec", Json::Num(self.mu_rec)),
            ("theta_rec", Json::Num(self.theta_rec)),
            ("mu_sen", Json::Num(self.mu_sen)),
            ("theta_sen", Json::Num(self.theta_sen)),
            ("theta_msg", Json::Num(self.theta_msg)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SystemProfile> {
        Ok(SystemProfile {
            mu_m: j.req_f64("mu_m")?,
            theta_m: j.req_f64("theta_m")?,
            mu_cmp: j.req_f64("mu_cmp")?,
            theta_cmp: j.req_f64("theta_cmp")?,
            mu_rec: j.req_f64("mu_rec")?,
            theta_rec: j.req_f64("theta_rec")?,
            mu_sen: j.req_f64("mu_sen")?,
            theta_sen: j.req_f64("theta_sen")?,
            // Optional for profiles written before the field existed.
            theta_msg: j.get("theta_msg").as_f64().unwrap_or(0.0),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    pub fn load(path: &Path) -> Result<SystemProfile> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vgg_conv3() -> LayerDims {
        // VGG16 conv3 block: 128ch 3x3 s1 p1 on 112x112.
        LayerDims::new(ConvSpec::new(128, 128, 3, 1, 1), 112, 112)
    }

    #[test]
    fn geometry() {
        let d = vgg_conv3();
        assert_eq!((d.h_i, d.w_i), (114, 114));
        assert_eq!((d.h_o, d.w_o), (112, 112));
    }

    #[test]
    fn scalings_match_paper_formulas() {
        let d = vgg_conv3();
        let (n, k) = (10, 4usize);
        let kf = k as f64;
        let w_o_p = 112.0 / kf;
        let w_i_p = 3.0 + (w_o_p - 1.0) * 1.0;
        assert!((d.n_enc(n, kf) - 2.0 * kf * 10.0 * 128.0 * 114.0 * w_i_p).abs() < 1e-6);
        assert!((d.n_cmp(kf) - 128.0 * 112.0 * w_o_p * 2.0 * 128.0 * 9.0).abs() < 1e-6);
        assert!((d.n_rec(kf) - 4.0 * 128.0 * 114.0 * w_i_p).abs() < 1e-6);
        assert!((d.n_sen(kf) - 4.0 * 128.0 * 112.0 * w_o_p).abs() < 1e-6);
        assert!((d.n_dec(kf) - 2.0 * kf * kf * 128.0 * 112.0 * w_o_p).abs() < 1e-6);
    }

    #[test]
    fn profile_json_roundtrip() {
        let p = SystemProfile::paper_default();
        let j = p.to_json();
        let q = SystemProfile::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn default_profile_magnitudes_sane() {
        // A VGG16 conv subtask at k=10 should take O(0.1–10 s) on the
        // RPi-class default profile — same ballpark as the paper.
        let d = vgg_conv3();
        let p = SystemProfile::paper_default();
        let mean_cmp = p.cmp_dist(&d, 10).mean();
        assert!(mean_cmp > 0.05 && mean_cmp < 10.0, "mean_cmp={mean_cmp}");
        let mean_rec = p.rec_dist(&d, 10).mean();
        assert!(mean_rec > 0.005 && mean_rec < 5.0, "mean_rec={mean_rec}");
    }
}
