//! Order statistics of shift-exponential samples (David & Nagaraja [25]).
//!
//! For `n` iid `SE(μ, θ, N)` variables, the k-th smallest has expectation
//! `Nθ + (N/μ)(H_n − H_{n−k})` — the Rényi representation sums `n − i + 1`
//! scaled spacings. The paper's `L(k)` replaces `H_n − H_{n−k}` with
//! `ln(n/(n−k))`; both forms live here so the approximation error is
//! testable.

use super::shift_exp::ShiftExp;
use crate::util::harmonic;

/// Exact expectation of the k-th order statistic of `n` iid draws.
pub fn expected_kth(dist: &ShiftExp, n: usize, k: usize) -> f64 {
    assert!(k >= 1 && k <= n);
    dist.shift() + (dist.n_scale / dist.mu) * (harmonic(n) - harmonic(n - k))
}

/// The paper's log approximation of `H_n − H_{n−k}` (diverges at `k = n`).
pub fn log_factor(n: usize, k: usize) -> f64 {
    assert!(k >= 1 && k < n);
    ((n as f64) / ((n - k) as f64)).ln()
}

/// Exact harmonic factor `H_n − H_{n−k}` (finite for `k = n`).
pub fn harmonic_factor(n: usize, k: usize) -> f64 {
    assert!(k >= 1 && k <= n);
    harmonic(n) - harmonic(n - k)
}

/// Variance factor of the k-th order statistic under the Rényi
/// representation: the k-th smallest of `n` iid exponentials is a sum of
/// `k` independent scaled spacings `E_i/(n−i)`, so its variance (in the
/// same normalized units as [`harmonic_factor`]) is
/// `Σ_{i=n−k+1..n} 1/i²`. The deadline-redundancy rule uses
/// `mean + z·sqrt(var)` as a tail-quantile surrogate.
pub fn harmonic_variance(n: usize, k: usize) -> f64 {
    assert!(k >= 1 && k <= n);
    (n - k + 1..=n).map(|i| 1.0 / (i as f64 * i as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn expected_kth_matches_simulation() {
        let dist = ShiftExp::new(2.0, 0.3, 5.0);
        let (n, k) = (10, 7);
        let mut rng = Rng::new(31);
        let trials = 60_000;
        let mut total = 0.0;
        let mut buf = vec![0.0f64; n];
        for _ in 0..trials {
            for b in buf.iter_mut() {
                *b = dist.sample(&mut rng);
            }
            buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
            total += buf[k - 1];
        }
        let mc = total / trials as f64;
        let exact = expected_kth(&dist, n, k);
        assert!((mc - exact).abs() / exact < 0.01, "mc={mc} exact={exact}");
    }

    #[test]
    fn log_approximates_harmonic() {
        // H_n − H_{n−k} is a right Riemann sum of ∫_{n−k}^{n} dx/x, so it
        // *underestimates* ln(n/(n−k)), by less than 1/(n−k) − 1/n.
        for n in [10usize, 20, 50] {
            for k in 1..n {
                let lg = log_factor(n, k);
                let hm = harmonic_factor(n, k);
                assert!(hm <= lg + 1e-12, "harmonic must underestimate log");
                assert!(lg - hm < 1.0 / (n - k) as f64 - 1.0 / n as f64 + 1e-9);
            }
        }
    }

    #[test]
    fn harmonic_variance_matches_renyi_sum() {
        // k = 1: min of n exps has variance 1/n². k = n: max has
        // Σ_{i=1..n} 1/i². Monotone in k (adding spacings adds variance).
        let n = 12;
        assert!((harmonic_variance(n, 1) - 1.0 / (n * n) as f64).abs() < 1e-12);
        let full: f64 = (1..=n).map(|i| 1.0 / (i * i) as f64).sum();
        assert!((harmonic_variance(n, n) - full).abs() < 1e-12);
        for k in 1..n {
            assert!(harmonic_variance(n, k) < harmonic_variance(n, k + 1));
        }
    }

    #[test]
    fn min_and_max_special_cases() {
        let dist = ShiftExp::new(1.0, 0.0, 1.0);
        // Min of n exps(1): 1/n.
        let e_min = expected_kth(&dist, 8, 1);
        assert!((e_min - 1.0 / 8.0).abs() < 1e-12);
        // Max of n: H_n.
        let e_max = expected_kth(&dist, 8, 8);
        assert!((e_max - harmonic(8)).abs() < 1e-12);
    }
}
