//! The approximate expected-latency objective `L(k)` (paper eq. 16), its
//! canonical form `P(k)` (App. C, eq. 18), the uncoded expectation
//! `E[T^u(n)]` (App. F, eq. 20), and the `h1..h5` / `R` theory quantities
//! behind Lemma 1 and Propositions 1–3.

use super::order_stats::{harmonic_factor, harmonic_variance};
use super::phases::{LayerDims, SystemProfile};

/// The layer/profile constants of App. C:
/// `I_ov = C_I·H_I·(K−S)`, `I_W = C_I·H_I·W_O·S`, `O = C_O·H_O·W_O`,
/// `N_t^cmp = 2·C_O·H_O·C_I·K²·W_O`.
#[derive(Clone, Copy, Debug)]
pub struct TheoryConsts {
    pub i_ov: f64,
    pub i_w: f64,
    pub o: f64,
    pub n_t_cmp: f64,
}

impl TheoryConsts {
    pub fn new(d: &LayerDims) -> TheoryConsts {
        let ci_hi = (d.spec.c_in * d.h_i) as f64;
        TheoryConsts {
            // K − S can be negative for stride > kernel; clamp at 0 (no
            // overlap) which matches the geometry.
            i_ov: ci_hi * (d.spec.k_w as f64 - d.spec.s_w as f64).max(0.0),
            i_w: ci_hi * d.w_o as f64 * d.spec.s_w as f64,
            o: (d.spec.c_out * d.h_o) as f64 * d.w_o as f64,
            n_t_cmp: 2.0
                * (d.spec.c_out * d.h_o) as f64
                * (d.spec.c_in * d.spec.k_w * d.spec.k_w) as f64
                * d.w_o as f64,
        }
    }

    /// `h1 = 2(1/μ_m + θ_m)(n·I_ov + O)` — the master-side `k` coefficient.
    pub fn h1(&self, p: &SystemProfile, n: usize) -> f64 {
        2.0 * (1.0 / p.mu_m + p.theta_m) * (n as f64 * self.i_ov + self.o)
    }

    /// `h2 = 4·I_W·θ_rec + 4·O·θ_sen + N_t·θ_cmp` — the `1/k` coefficient.
    pub fn h2(&self, p: &SystemProfile) -> f64 {
        4.0 * self.i_w * p.theta_rec + 4.0 * self.o * p.theta_sen + self.n_t_cmp * p.theta_cmp
    }

    /// `h3 = 4·I_W/μ_rec + 4·O/μ_sen + N_t/μ_cmp` — the `(1/k)·ln` coeff.
    pub fn h3(&self, p: &SystemProfile) -> f64 {
        4.0 * self.i_w / p.mu_rec + 4.0 * self.o / p.mu_sen + self.n_t_cmp / p.mu_cmp
    }

    /// `h4 = 4·I_ov/μ_rec` — the `ln` coefficient.
    pub fn h4(&self, p: &SystemProfile) -> f64 {
        4.0 * self.i_ov / p.mu_rec
    }

    /// `h5 = 4·I_ov·θ_rec` — the constant in `E[T^u]`.
    pub fn h5(&self, p: &SystemProfile) -> f64 {
        4.0 * self.i_ov * p.theta_rec
    }

    /// The straggling-degree ratio `R` of §IV-C:
    /// `R = h2 / h3` (smaller ⇒ stronger straggling).
    pub fn straggle_ratio(&self, p: &SystemProfile) -> f64 {
        self.h2(p) / self.h3(p)
    }
}

/// `L(k)` (eq. 16) for **real** `k ∈ [1, n)`, using `ln(n/(n−k))` — the
/// form whose convexity Lemma 1 proves.
pub fn l_relaxed(dims: &LayerDims, p: &SystemProfile, n: usize, k: f64) -> f64 {
    assert!(k >= 1.0 && (k as usize) < n.max(2), "relaxed k in [1, n)");
    let enc_dec = (dims.n_enc(n, k) + dims.n_dec(k)) * (1.0 / p.mu_m + p.theta_m);
    let theta_sum =
        dims.n_rec(k) * p.theta_rec + dims.n_cmp(k) * p.theta_cmp + dims.n_sen(k) * p.theta_sen;
    let mu_sum =
        dims.n_rec(k) / p.mu_rec + dims.n_cmp(k) / p.mu_cmp + dims.n_sen(k) / p.mu_sen;
    enc_dec + theta_sum + mu_sum * ((n as f64) / (n as f64 - k)).ln()
}

/// `L(k)` for **integer** `k ∈ [1, n]`, with the exact harmonic factor
/// `H_n − H_{n−k}` so `k = n` stays finite (it equals the uncoded order
/// factor). This is what the integer solver minimizes.
pub fn l_integer(dims: &LayerDims, p: &SystemProfile, n: usize, k: usize) -> f64 {
    assert!(k >= 1 && k <= n);
    let kf = k as f64;
    let enc_dec = (dims.n_enc(n, kf) + dims.n_dec(kf)) * (1.0 / p.mu_m + p.theta_m);
    let theta_sum =
        dims.n_rec(kf) * p.theta_rec + dims.n_cmp(kf) * p.theta_cmp + dims.n_sen(kf) * p.theta_sen;
    let mu_sum =
        dims.n_rec(kf) / p.mu_rec + dims.n_cmp(kf) / p.mu_cmp + dims.n_sen(kf) / p.mu_sen;
    enc_dec + theta_sum + mu_sum * harmonic_factor(n, k)
}

/// Tail-quantile analogue of [`l_integer`]: the same per-phase means,
/// but the worker order factor is `mean + z·sd` of the k-th order
/// statistic (Rényi representation: mean `H_n − H_{n−k}`, variance
/// `Σ_{i=n−k+1..n} 1/i²`). `z` is a normal-style quantile score (1.65 ≈
/// p95). This is what the deadline-redundancy rule compares against a
/// request's remaining slack (Dutta-style "coded convolution within a
/// deadline"): pick the largest k — least redundancy — whose *tail*,
/// not just whose mean, still fits.
pub fn l_tail_quantile(dims: &LayerDims, p: &SystemProfile, n: usize, k: usize, z: f64) -> f64 {
    assert!(k >= 1 && k <= n);
    let kf = k as f64;
    let enc_dec = (dims.n_enc(n, kf) + dims.n_dec(kf)) * (1.0 / p.mu_m + p.theta_m);
    let theta_sum =
        dims.n_rec(kf) * p.theta_rec + dims.n_cmp(kf) * p.theta_cmp + dims.n_sen(kf) * p.theta_sen;
    let mu_sum =
        dims.n_rec(kf) / p.mu_rec + dims.n_cmp(kf) / p.mu_cmp + dims.n_sen(kf) / p.mu_sen;
    let order = harmonic_factor(n, k) + z.max(0.0) * harmonic_variance(n, k).sqrt();
    enc_dec + theta_sum + mu_sum * order
}

/// Canonical `P(k)` (App. C eq. 18): `L(k)` minus its k-independent
/// constant, expressed through `h1..h4`. Used by the Lemma-1 tests.
pub fn p_canonical(c: &TheoryConsts, p: &SystemProfile, n: usize, k: f64) -> f64 {
    let lg = ((n as f64) / (n as f64 - k)).ln();
    c.h1(p, n) * k + c.h2(p) / k + c.h3(p) * lg / k + c.h4(p) * lg
}

/// `E[T^u(n)]` (eq. 20): uncoded expectation — all `n` outputs needed, so
/// the order factor is `H_n` (paper writes `ln n`; we keep the exact form).
pub fn uncoded_expectation(dims: &LayerDims, p: &SystemProfile, n: usize) -> f64 {
    let c = TheoryConsts::new(dims);
    let hn = harmonic_factor(n, n);
    c.h2(p) / n as f64 + c.h3(p) * hn / n as f64 + c.h4(p) * hn + c.h5(p)
}

/// The *margin-form* comparison of Prop. 2: coded beats uncoded iff
/// `R < max_k h(n,k)` where `h(n,k) = (k·ln n − n·ln(n/(n−k)))·(n−k)`
/// … (the proof's normalized objective). Exposed for tests/benches.
pub fn prop2_h(n: usize, k: f64) -> f64 {
    let nf = n as f64;
    (k * nf.ln() - nf * (nf / (nf - k)).ln()) * (nf - k) / (nf * nf.ln())
}

/// Prop. 2's interior optimum `k_sub* = n − e`.
pub fn prop2_k_sub(n: usize) -> f64 {
    n as f64 - std::f64::consts::E
}

/// Simplified coded expectation used in the §IV-C comparison (encode/
/// decode and `h4` terms dropped, as in App. F):
/// `E[T_m^c(n,k)] = h2/k + h3·ln(n/(n−k))/k`.
pub fn coded_margin_expectation(c: &TheoryConsts, p: &SystemProfile, n: usize, k: f64) -> f64 {
    c.h2(p) / k + c.h3(p) * ((n as f64) / (n as f64 - k)).ln() / k
}

/// Matching simplified uncoded expectation: `E[T_m^u(n)] = h2/n + h3·H_n/n`.
pub fn uncoded_margin_expectation(c: &TheoryConsts, p: &SystemProfile, n: usize) -> f64 {
    c.h2(p) / n as f64 + c.h3(p) * harmonic_factor(n, n) / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;

    fn dims() -> LayerDims {
        LayerDims::new(ConvSpec::new(128, 128, 3, 1, 1), 112, 112)
    }

    #[test]
    fn l_integer_close_to_relaxed_inside() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let n = 10;
        for k in 1..n {
            let li = l_integer(&d, &p, n, k);
            let lr = l_relaxed(&d, &p, n, k as f64);
            // Harmonic vs log factor differ by O(1/(n-k)); scaled by
            // mu_sum this stays a small relative error.
            assert!((li - lr).abs() / li < 0.25, "k={k}: {li} vs {lr}");
            assert!(li <= lr, "harmonic factor underestimates log factor");
        }
    }

    #[test]
    fn tail_quantile_dominates_mean_and_grows_with_z() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let n = 10;
        for k in 1..=n {
            let mean = l_integer(&d, &p, n, k);
            let q0 = l_tail_quantile(&d, &p, n, k, 0.0);
            let q95 = l_tail_quantile(&d, &p, n, k, 1.65);
            let q99 = l_tail_quantile(&d, &p, n, k, 2.33);
            assert!((q0 - mean).abs() / mean < 1e-12, "z=0 must equal the mean");
            assert!(q95 > mean && q99 > q95, "k={k}: {mean} {q95} {q99}");
        }
    }

    /// Lemma 1: L(k) is convex on [1, n) for n >= 3 — checked numerically
    /// via second differences of the canonical P(k).
    #[test]
    fn lemma1_convexity_numeric() {
        let d = dims();
        let c = TheoryConsts::new(&d);
        for n in [3usize, 5, 10, 20] {
            // Also check under several profiles.
            for scale in [0.1, 1.0, 10.0] {
                let mut p = SystemProfile::paper_default();
                p.mu_cmp *= scale;
                p.mu_rec /= scale;
                let eps = 1e-4;
                let mut k = 1.0 + eps;
                while k < n as f64 - 1.0 {
                    let f0 = p_canonical(&c, &p, n, k - eps);
                    let f1 = p_canonical(&c, &p, n, k);
                    let f2 = p_canonical(&c, &p, n, k + eps);
                    let second = f0 - 2.0 * f1 + f2;
                    assert!(
                        second > -1e-7 * f1.abs().max(1.0),
                        "non-convex at n={n} k={k}: d2={second}"
                    );
                    k += 0.37;
                }
            }
        }
    }

    #[test]
    fn uncoded_equals_l_at_k_n_without_master_terms() {
        // E[T^u(n)] must match the worker part of L_int(n) (k = n means no
        // redundancy; uncoded has no encode/decode).
        let d = dims();
        let p = SystemProfile::paper_default();
        let n = 10;
        let kf = n as f64;
        let worker_part = d.n_rec(kf) * p.theta_rec
            + d.n_cmp(kf) * p.theta_cmp
            + d.n_sen(kf) * p.theta_sen
            + (d.n_rec(kf) / p.mu_rec + d.n_cmp(kf) / p.mu_sen.min(p.mu_rec).max(p.mu_cmp))
                * 0.0; // (only θ terms compared exactly below)
        let u = uncoded_expectation(&d, &p, n);
        // θ-part of eq. 20 = h2/n + h5; compare that component.
        let c = TheoryConsts::new(&d);
        let theta_part = c.h2(&p) / n as f64 + c.h5(&p);
        let l_theta = d.n_rec(kf) * p.theta_rec
            + d.n_cmp(kf) * p.theta_cmp
            + d.n_sen(kf) * p.theta_sen;
        assert!(
            (theta_part - l_theta).abs() / l_theta < 1e-9,
            "{theta_part} vs {l_theta}"
        );
        assert!(u > worker_part);
    }

    #[test]
    fn prop2_example_from_paper() {
        // §IV-C: "when n = 20 and R = 1, our approach reduces the latency
        // by around 21%". With R = h2/h3 = 1 the normalized margin at
        // k_sub* = n − e is h(n) = n/(e·ln n) (paper's form); check the
        // latency reduction lands near 21%.
        let n = 20usize;
        let d = dims();
        let c = TheoryConsts::new(&d);
        // Build a profile with R = 1: scale θ's so h2 == h3.
        let mut p = SystemProfile::paper_default();
        let ratio = c.h3(&p) / c.h2(&p);
        p.theta_rec *= ratio;
        p.theta_sen *= ratio;
        p.theta_cmp *= ratio;
        let r = c.straggle_ratio(&p);
        assert!((r - 1.0).abs() < 1e-9, "R={r}");
        let k_sub = prop2_k_sub(n);
        let coded = coded_margin_expectation(&c, &p, n, k_sub);
        let uncoded = uncoded_margin_expectation(&c, &p, n);
        let reduction = 1.0 - coded / uncoded;
        assert!(
            (0.15..0.27).contains(&reduction),
            "reduction = {reduction} (paper: ~21%)"
        );
    }

    #[test]
    fn prop2_margin_positive_for_severe_straggling() {
        // Prop. 2: R <= 1 and n >= 10 ⇒ coded strictly better at k_sub*.
        let d = dims();
        let c = TheoryConsts::new(&d);
        for n in [10usize, 12, 16, 20] {
            for r_target in [0.2, 0.5, 1.0] {
                let mut p = SystemProfile::paper_default();
                let ratio = r_target * c.h3(&p) / c.h2(&p);
                p.theta_rec *= ratio;
                p.theta_sen *= ratio;
                p.theta_cmp *= ratio;
                let k_sub = prop2_k_sub(n);
                let coded = coded_margin_expectation(&c, &p, n, k_sub);
                let uncoded = uncoded_margin_expectation(&c, &p, n);
                assert!(
                    coded < uncoded,
                    "n={n} R={r_target}: coded {coded} !< uncoded {uncoded}"
                );
            }
        }
    }
}
