//! Bounded sliding sample window with EWMA decay.
//!
//! The online estimator keeps two views of each worker's recent samples:
//! the raw bounded window (fed to `ShiftExp::fit_trimmed`, which needs
//! actual observations) and a bias-corrected exponentially-weighted mean
//! (the cheap "how fast is this worker *right now*" signal that drives
//! the straggler score). The EWMA reacts within a half-life of new
//! samples; the window turns over in `cap` samples.

/// A bounded FIFO of `f64` samples plus a bias-corrected EWMA.
#[derive(Clone, Debug)]
pub struct SlidingWindow {
    cap: usize,
    buf: Vec<f64>,
    /// Decay per sample: `0.5^(1/half_life)`.
    lambda: f64,
    /// EWMA numerator/denominator (bias-corrected form: `ewma = num/den`
    /// is exact from the first sample, no zero-initialization bias).
    num: f64,
    den: f64,
    /// Lifetime sample count (not capped).
    total: u64,
}

impl SlidingWindow {
    /// `cap` bounds the stored window; `half_life` (in samples) sets the
    /// EWMA decay.
    pub fn new(cap: usize, half_life: f64) -> SlidingWindow {
        assert!(cap >= 2 && half_life > 0.0);
        SlidingWindow {
            cap,
            buf: Vec::with_capacity(cap),
            lambda: 0.5f64.powf(1.0 / half_life),
            num: 0.0,
            den: 0.0,
            total: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            // cap is small (O(100)); the shift is cheaper than a ring's
            // bookkeeping at this size and keeps `samples()` a plain slice.
            self.buf.remove(0);
        }
        self.buf.push(x);
        self.num = x + self.lambda * self.num;
        self.den = 1.0 + self.lambda * self.den;
        self.total += 1;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Decayed mean; `NaN` when empty.
    pub fn ewma(&self) -> f64 {
        if self.den > 0.0 {
            self.num / self.den
        } else {
            f64::NAN
        }
    }

    /// The raw bounded window, oldest first.
    pub fn samples(&self) -> &[f64] {
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_bounds_and_order() {
        let mut w = SlidingWindow::new(3, 2.0);
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.samples(), &[2.0, 3.0, 4.0]);
        assert_eq!(w.len(), 3);
        assert_eq!(w.total(), 4);
    }

    #[test]
    fn ewma_tracks_level_shift() {
        let mut w = SlidingWindow::new(64, 8.0);
        for _ in 0..32 {
            w.push(1.0);
        }
        assert!((w.ewma() - 1.0).abs() < 1e-9);
        for _ in 0..32 {
            w.push(3.0);
        }
        // After 4 half-lives the EWMA has closed ~94% of the gap.
        assert!(w.ewma() > 2.8, "ewma={}", w.ewma());
        assert!(w.ewma() < 3.0);
    }

    #[test]
    fn ewma_unbiased_from_first_sample() {
        let mut w = SlidingWindow::new(8, 4.0);
        w.push(5.0);
        assert!((w.ewma() - 5.0).abs() < 1e-12);
        assert!(SlidingWindow::new(4, 1.0).ewma().is_nan());
    }

    #[test]
    fn recent_samples_weigh_more() {
        let mut w = SlidingWindow::new(16, 4.0);
        w.push(0.0);
        w.push(10.0);
        // Plain mean would be 5; EWMA must lean toward the newer sample.
        assert!(w.ewma() > 5.0);
    }
}
