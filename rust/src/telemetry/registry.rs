//! The capacity registry: per-worker online shift-exponential estimation
//! plus straggler quarantine with probe-based reintegration.
//!
//! Every completed subtask yields one timing sample per phase class:
//!
//! * **execution** — worker-measured conv wall time, normalized by the
//!   subtask's FLOPs into seconds-per-FLOP. For a `SE(μ, θ, N)` worker
//!   the normalized sample is distributed exactly `SE(μ, θ, 1)` (the
//!   exponential excess scales as `N/μ`, so dividing by `N` yields rate
//!   `μ`), which is what makes samples from different layers and split
//!   factors poolable in one window.
//! * **transmission** — (dispatch→reply wall time − execution),
//!   normalized by the subtask's total wire bytes (input partition +
//!   output partition). This conflates link time with worker queueing,
//!   which is the honest observable a master actually has.
//!
//! Samples accumulate in bounded [`SlidingWindow`]s with EWMA decay; the
//! fits come from `ShiftExp::fit_trimmed` (robust to scheduler spikes),
//! with staleness-aware widening of `θ` for workers that have gone
//! quiet. A worker whose EWMA execution rate drifts far above the pool
//! median — or that fails several subtasks in a row — is *quarantined*:
//! excluded from dispatch except for a periodic probe subtask whose
//! sample can reintegrate it once it recovers.

use std::collections::BTreeMap;

use crate::latency::{ShiftExp, SystemProfile};
use crate::planner::hetero::WorkerSpeed;
use crate::util::json::Json;

use super::window::SlidingWindow;

/// Tuning knobs for collection + quarantine. Defaults are sized for
/// rounds that arrive a few times per second or slower.
#[derive(Clone, Copy, Debug)]
pub struct TelemetryConfig {
    /// Bounded sample window per worker per phase class.
    pub window: usize,
    /// EWMA half-life in samples.
    pub half_life: f64,
    /// Trim fraction handed to `ShiftExp::fit_trimmed`.
    pub trim_frac: f64,
    /// Samples required before a fit (or a straggler score) is trusted.
    pub min_samples: usize,
    /// Quarantine when EWMA per-FLOP time exceeds this multiple of the
    /// pool median.
    pub quarantine_score: f64,
    /// Reintegrate a quarantined worker when its score drops below this
    /// (kept below `quarantine_score` for hysteresis).
    pub reintegrate_score: f64,
    /// Quarantine after this many *consecutive* failed subtasks.
    pub quarantine_failures: usize,
    /// Rounds between probe subtasks sent to a quarantined worker.
    pub probe_every: u64,
    /// Rounds of silence after which a worker's fit starts widening.
    pub stale_after: u64,
    /// θ widening per `stale_after` interval of additional silence.
    pub stale_widen: f64,
}

impl Default for TelemetryConfig {
    fn default() -> TelemetryConfig {
        TelemetryConfig {
            window: 128,
            half_life: 32.0,
            trim_frac: 0.05,
            min_samples: 8,
            quarantine_score: 2.2,
            reintegrate_score: 1.8,
            quarantine_failures: 3,
            probe_every: 8,
            stale_after: 96,
            stale_widen: 0.5,
        }
    }
}

/// Quarantine/reintegration/membership log entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// EWMA execution rate drifted past the quarantine score.
    QuarantineSlow,
    /// Too many consecutive failures.
    QuarantineFail,
    /// A probe sample brought the worker back under the threshold.
    Reintegrate,
    /// Membership: the worker joined the pool (at startup or at runtime).
    Joined,
    /// Membership: the worker's link died or its heartbeat deadline
    /// lapsed — removed involuntarily.
    Evicted,
    /// Membership: the worker drained its in-flight subtasks and left
    /// gracefully.
    Retired,
    /// Reliability: an outstanding subtask on this worker exceeded its
    /// fitted completion quantile and was speculatively re-dispatched
    /// (hedged) to another worker.
    Hedged,
    /// Reliability: the master computed this worker's undelivered shard
    /// locally to complete a decode (pool collapse / retries exhausted /
    /// deadline pressure).
    LocalFallback,
    /// Reliability: a hedge raced against this worker and the worker
    /// *won* — its own reply beat the speculative backup. The hedge was
    /// wasted work; the worker redeemed itself.
    HedgeWon,
    /// Reliability: a hedge raced against this worker and the worker
    /// *lost* — the backup replied first. Chronic losses are the
    /// straggler signal EWMA timing can miss (a stalled worker produces
    /// no samples at all), so they feed [`CapacityRegistry::straggler_score`].
    HedgeLost,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TelemetryEvent {
    pub kind: EventKind,
    pub worker: usize,
    pub round: u64,
}

/// One worker's fitted capacity estimate (per-unit scales: `n_scale = 1`,
/// i.e. seconds-per-FLOP for `cmp`, seconds-per-byte for `tr`).
#[derive(Clone, Copy, Debug)]
pub struct WorkerEstimate {
    pub cmp: ShiftExp,
    pub tr: ShiftExp,
    pub samples: usize,
    pub stale_rounds: u64,
}

#[derive(Clone, Debug)]
struct WorkerState {
    cmp: SlidingWindow,
    tr: SlidingWindow,
    last_round: u64,
    last_failure_round: u64,
    consecutive_failures: usize,
    total_failures: u64,
    /// Hedge races this worker won (its reply beat the backup).
    hedge_wins: u64,
    /// Hedge races this worker lost (the backup beat it).
    hedge_losses: u64,
    quarantined: bool,
    /// Next round at (or after) which a quarantined worker gets a probe.
    next_probe: u64,
}

impl WorkerState {
    fn fresh(cfg: &TelemetryConfig) -> WorkerState {
        WorkerState {
            cmp: SlidingWindow::new(cfg.window, cfg.half_life),
            tr: SlidingWindow::new(cfg.window, cfg.half_life),
            last_round: 0,
            last_failure_round: 0,
            consecutive_failures: 0,
            total_failures: 0,
            hedge_wins: 0,
            hedge_losses: 0,
            quarantined: false,
            next_probe: 0,
        }
    }
}

/// Median via the shared stats substrate (interpolated quantile: mean of
/// the two middles for even counts); `NaN` when empty — every caller
/// guards with a `> 0.0` / finiteness check.
fn median(xs: Vec<f64>) -> f64 {
    crate::util::stats::Summary::from_slice(&xs).median()
}

/// Per-worker capacity telemetry for one worker pool, keyed by *stable
/// worker id* (ids survive churn; a rejoining worker gets a fresh id and
/// a fresh window). Record/query calls for absent ids are graceful
/// no-ops — stale replies from an evicted worker must not panic the
/// master.
#[derive(Clone, Debug)]
pub struct CapacityRegistry {
    cfg: TelemetryConfig,
    workers: BTreeMap<usize, WorkerState>,
    /// Latest observed round (monotone).
    round: u64,
    events: Vec<TelemetryEvent>,
}

impl CapacityRegistry {
    /// A registry seeded with workers `0..n_workers`. `n_workers` may be
    /// zero (an elastic master starts empty and admits at runtime).
    pub fn new(n_workers: usize, cfg: TelemetryConfig) -> CapacityRegistry {
        CapacityRegistry {
            cfg,
            workers: (0..n_workers).map(|i| (i, WorkerState::fresh(&cfg))).collect(),
            round: 0,
            events: Vec::new(),
        }
    }

    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn contains(&self, worker: usize) -> bool {
        self.workers.contains_key(&worker)
    }

    /// Current member ids, ascending.
    pub fn worker_ids(&self) -> Vec<usize> {
        self.workers.keys().copied().collect()
    }

    /// Execution samples currently windowed for one worker (0 if absent).
    pub fn samples_of(&self, worker: usize) -> usize {
        self.workers.get(&worker).map_or(0, |w| w.cmp.len())
    }

    /// True when at least one member has a trusted fit.
    pub fn any_estimate(&self) -> bool {
        self.workers.keys().any(|&i| self.estimate(i).is_some())
    }

    /// Admit a worker under a (new) stable id with a fresh sample window.
    /// Admitting an existing id is a no-op (re-admission keeps history).
    pub fn admit(&mut self, worker: usize) {
        if self.workers.contains_key(&worker) {
            return;
        }
        self.workers.insert(worker, WorkerState::fresh(&self.cfg));
        self.events.push(TelemetryEvent {
            kind: EventKind::Joined,
            worker,
            round: self.round,
        });
    }

    /// Remove a worker involuntarily (link death / heartbeat lapse).
    /// No-op when absent — link-death events can race and double-fire.
    pub fn evict(&mut self, worker: usize) {
        if self.workers.remove(&worker).is_some() {
            self.events.push(TelemetryEvent {
                kind: EventKind::Evicted,
                worker,
                round: self.round,
            });
        }
    }

    /// Remove a worker that drained gracefully.
    pub fn retire(&mut self, worker: usize) {
        if self.workers.remove(&worker).is_some() {
            self.events.push(TelemetryEvent {
                kind: EventKind::Retired,
                worker,
                round: self.round,
            });
        }
    }

    pub fn events(&self) -> &[TelemetryEvent] {
        &self.events
    }

    /// Log a reliability event (hedge fired / hedge resolved / local
    /// fallback computed a shard) against the worker that held the
    /// shard. Absent ids are logged too: the interesting case — a
    /// fallback for a shard whose holder was already evicted — must not
    /// vanish from the record. Hedge *outcomes* additionally feed the
    /// per-worker win/loss counters the straggler score reads.
    pub fn note_reliability(&mut self, kind: EventKind, worker: usize, round: u64) {
        debug_assert!(matches!(
            kind,
            EventKind::Hedged
                | EventKind::LocalFallback
                | EventKind::HedgeWon
                | EventKind::HedgeLost
        ));
        self.round = self.round.max(round);
        if let Some(w) = self.workers.get_mut(&worker) {
            match kind {
                EventKind::HedgeWon => w.hedge_wins += 1,
                EventKind::HedgeLost => w.hedge_losses += 1,
                _ => {}
            }
        }
        self.events.push(TelemetryEvent { kind, worker, round });
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    /// Record a completed subtask: `flops`/`bytes` are the subtask's
    /// scales, `exec_secs` the worker-measured execution time, and
    /// `trans_secs` the remaining dispatch→reply time.
    pub fn record_success(
        &mut self,
        worker: usize,
        flops: f64,
        bytes: f64,
        exec_secs: f64,
        trans_secs: f64,
        round: u64,
    ) {
        self.round = self.round.max(round);
        let Some(w) = self.workers.get_mut(&worker) else {
            return; // stale reply from an evicted/retired worker
        };
        // A *late* reply for an old round is still a capacity sample —
        // push it — but it must not rewind the staleness clock or wipe a
        // failure streak accumulated in newer rounds.
        if flops > 0.0 {
            w.cmp.push((exec_secs / flops).max(0.0));
        }
        if bytes > 0.0 {
            w.tr.push((trans_secs / bytes).max(0.0));
        }
        w.last_round = w.last_round.max(round);
        if round >= w.last_failure_round {
            w.consecutive_failures = 0;
        }
        let score = self.straggler_score(worker);
        let w = self.workers.get_mut(&worker).expect("present above");
        if w.quarantined && score < self.cfg.reintegrate_score {
            w.quarantined = false;
            self.events.push(TelemetryEvent {
                kind: EventKind::Reintegrate,
                worker,
                round,
            });
        } else if !w.quarantined && score > self.cfg.quarantine_score {
            w.quarantined = true;
            w.next_probe = round + self.cfg.probe_every;
            self.events.push(TelemetryEvent {
                kind: EventKind::QuarantineSlow,
                worker,
                round,
            });
        }
    }

    /// Record a failed/timed-out subtask.
    pub fn record_failure(&mut self, worker: usize, round: u64) {
        self.round = self.round.max(round);
        let cfg = self.cfg;
        let Some(w) = self.workers.get_mut(&worker) else {
            return; // stale failure from an evicted/retired worker
        };
        w.consecutive_failures += 1;
        w.total_failures += 1;
        w.last_failure_round = w.last_failure_round.max(round);
        // A Failed reply is still a sign of life: staleness widening is
        // for workers that have gone *quiet*, not ones actively failing
        // (quarantine handles those).
        w.last_round = w.last_round.max(round);
        if !w.quarantined && w.consecutive_failures >= cfg.quarantine_failures {
            w.quarantined = true;
            w.next_probe = round + cfg.probe_every;
            self.events.push(TelemetryEvent {
                kind: EventKind::QuarantineFail,
                worker,
                round,
            });
        }
    }

    /// EWMA per-FLOP execution time relative to the median of the *other*
    /// workers; `1.0` when this worker (or the rest of the pool) has too
    /// little data to judge. Excluding the scored worker keeps the
    /// signal alive even when it (or half the pool) is the slow part —
    /// with a self-inclusive median a slow worker in a 2-pool would
    /// always score exactly 1.0.
    ///
    /// Hedge outcomes multiply in on top: each *net* lost hedge race
    /// (losses minus wins, capped at 8) adds 25% — a worker whose hedges
    /// always lose is a chronic straggler even when it produces too few
    /// timing samples for the EWMA to say so. Six net losses push an
    /// otherwise-nominal worker (`1.0 × 2.5`) past the default
    /// `quarantine_score` of 2.2; balanced win/loss records multiply by
    /// exactly 1.0, leaving the timing-only score untouched.
    pub fn straggler_score(&self, worker: usize) -> f64 {
        let Some(w) = self.workers.get(&worker) else {
            return 1.0;
        };
        let base = if w.cmp.len() < self.cfg.min_samples {
            1.0
        } else {
            let pool: Vec<f64> = self
                .workers
                .iter()
                .filter(|(i, s)| **i != worker && s.cmp.len() >= self.cfg.min_samples)
                .map(|(_, s)| s.cmp.ewma())
                .collect();
            let med = median(pool);
            if med.is_finite() && med > 0.0 {
                w.cmp.ewma() / med
            } else {
                1.0
            }
        };
        let net_losses = w.hedge_losses.saturating_sub(w.hedge_wins).min(8);
        base * (1.0 + 0.25 * net_losses as f64)
    }

    pub fn is_quarantined(&self, worker: usize) -> bool {
        self.workers.get(&worker).is_some_and(|w| w.quarantined)
    }

    /// Workers currently trusted with shards (non-quarantined).
    pub fn healthy_count(&self) -> usize {
        let n = self.workers.values().filter(|w| !w.quarantined).count();
        n.max(1)
    }

    /// The dispatch set for `round`: every non-quarantined member, plus
    /// any quarantined member whose probe is due (its next probe is then
    /// rescheduled). Falls back to the full membership if everyone is
    /// quarantined. Sorted ascending by stable id; empty only when the
    /// pool itself is empty.
    pub fn active_workers(&mut self, round: u64) -> Vec<usize> {
        self.round = self.round.max(round);
        let mut act: Vec<usize> = Vec::with_capacity(self.workers.len());
        for (&i, w) in self.workers.iter_mut() {
            if !w.quarantined {
                act.push(i);
            } else if round >= w.next_probe {
                w.next_probe = round + self.cfg.probe_every;
                act.push(i);
            }
        }
        if act.is_empty() {
            return self.worker_ids();
        }
        act
    }

    /// Fitted per-unit estimate for one worker; `None` below
    /// `min_samples`. Staleness widens θ (and shrinks μ) — a worker not
    /// heard from in a while might have slowed, so the planner should
    /// assume less of it.
    pub fn estimate(&self, worker: usize) -> Option<WorkerEstimate> {
        let w = self.workers.get(&worker)?;
        if w.cmp.len() < self.cfg.min_samples || w.tr.len() < self.cfg.min_samples {
            return None;
        }
        let stale = self.round.saturating_sub(w.last_round);
        let widen = if stale > self.cfg.stale_after {
            1.0 + self.cfg.stale_widen * (stale - self.cfg.stale_after) as f64
                / self.cfg.stale_after as f64
        } else {
            1.0
        };
        let widen_fit = |fit: ShiftExp| -> ShiftExp {
            ShiftExp::new((fit.mu / widen).max(1e-12), fit.theta * widen, fit.n_scale)
        };
        Some(WorkerEstimate {
            cmp: widen_fit(ShiftExp::fit_trimmed(w.cmp.samples(), 1.0, self.cfg.trim_frac)),
            tr: widen_fit(ShiftExp::fit_trimmed(w.tr.samples(), 1.0, self.cfg.trim_frac)),
            samples: w.cmp.len(),
            stale_rounds: stale,
        })
    }

    /// Fitted service-time quantile for one subtask on one worker: the
    /// time by which a dispatch of `flops` FLOPs / `bytes` wire bytes
    /// should have replied with probability `q`, per this worker's
    /// current `SE(μ, θ)` fits. The execution and transmission phases
    /// are summed quantile-by-quantile — an upper bound on the true
    /// sum-distribution quantile, which is the conservative direction
    /// for a hedging watchdog (it only ever fires *later* than the exact
    /// quantile would). `None` below `min_samples` — the caller applies
    /// its own floor for unfitted workers.
    pub fn service_quantile(&self, worker: usize, q: f64, flops: f64, bytes: f64) -> Option<f64> {
        let est = self.estimate(worker)?;
        let at = |fit: ShiftExp, n: f64| ShiftExp::new(fit.mu, fit.theta, n.max(0.0)).quantile(q);
        Some(at(est.cmp, flops) + at(est.tr, bytes))
    }

    /// Pool-level fitted profile for the iid planner (`solve_k_circ`):
    /// median per-unit μ/θ over the healthy workers with enough samples,
    /// falling back to `base` per phase class when nobody qualifies.
    /// Master-side coefficients (μ_m, θ_m, θ_msg) come from `base` — the
    /// registry only observes workers. The transmission fit sets both
    /// directions (rec/sen) to the same value: the master observes only
    /// their sum, and the links are assumed symmetric.
    pub fn fitted_profile(&self, base: &SystemProfile) -> SystemProfile {
        let mut p = *base;
        let fits: Vec<WorkerEstimate> = self
            .workers
            .iter()
            .filter(|(_, w)| !w.quarantined)
            .filter_map(|(&i, _)| self.estimate(i))
            .collect();
        if fits.is_empty() {
            return p;
        }
        p.mu_cmp = median(fits.iter().map(|f| f.cmp.mu).collect());
        p.theta_cmp = median(fits.iter().map(|f| f.cmp.theta).collect());
        p.mu_rec = median(fits.iter().map(|f| f.tr.mu).collect());
        p.mu_sen = p.mu_rec;
        p.theta_rec = median(fits.iter().map(|f| f.tr.theta).collect());
        p.theta_sen = p.theta_rec;
        p
    }

    /// Per-worker relative speed multipliers (1.0 = pool median; larger =
    /// slower) for the heterogeneous planner. Workers without data get
    /// the nominal 1.0.
    pub fn speeds(&self) -> Vec<WorkerSpeed> {
        let med = |pick: fn(&WorkerState) -> &SlidingWindow| -> f64 {
            median(
                self.workers
                    .values()
                    .filter(|w| pick(w).len() >= self.cfg.min_samples)
                    .map(|w| pick(w).ewma())
                    .collect(),
            )
        };
        let med_cmp = med(|w| &w.cmp);
        let med_tr = med(|w| &w.tr);
        self.workers
            .values()
            .map(|w| {
                let ratio = |win: &SlidingWindow, median: f64| -> f64 {
                    if win.len() >= self.cfg.min_samples && median > 0.0 {
                        (win.ewma() / median).max(1e-3)
                    } else {
                        1.0
                    }
                };
                WorkerSpeed {
                    cmp: ratio(&w.cmp, med_cmp),
                    tr: ratio(&w.tr, med_tr),
                }
            })
            .collect()
    }

    /// Telemetry dump (the `--telemetry` CLI flag and the adaptive
    /// experiment both emit this).
    pub fn to_json(&self) -> Json {
        let workers: Vec<Json> = self
            .workers
            .iter()
            .map(|(&i, w)| {
                let mut pairs = vec![
                    ("worker", Json::Num(i as f64)),
                    ("samples", Json::Num(w.cmp.len() as f64)),
                    ("lifetime_samples", Json::Num(w.cmp.total() as f64)),
                    ("ewma_sec_per_flop", Json::Num(w.cmp.ewma())),
                    ("ewma_sec_per_byte", Json::Num(w.tr.ewma())),
                    ("straggler_score", Json::Num(self.straggler_score(i))),
                    ("quarantined", Json::Bool(w.quarantined)),
                    ("consecutive_failures", Json::Num(w.consecutive_failures as f64)),
                    ("total_failures", Json::Num(w.total_failures as f64)),
                    ("hedge_wins", Json::Num(w.hedge_wins as f64)),
                    ("hedge_losses", Json::Num(w.hedge_losses as f64)),
                    ("last_round", Json::Num(w.last_round as f64)),
                ];
                if let Some(est) = self.estimate(i) {
                    pairs.push(("mu_cmp", Json::Num(est.cmp.mu)));
                    pairs.push(("theta_cmp", Json::Num(est.cmp.theta)));
                    pairs.push(("mu_tr", Json::Num(est.tr.mu)));
                    pairs.push(("theta_tr", Json::Num(est.tr.theta)));
                    pairs.push(("stale_rounds", Json::Num(est.stale_rounds as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    (
                        "kind",
                        Json::Str(
                            match e.kind {
                                EventKind::QuarantineSlow => "quarantine-slow",
                                EventKind::QuarantineFail => "quarantine-fail",
                                EventKind::Reintegrate => "reintegrate",
                                EventKind::Joined => "joined",
                                EventKind::Evicted => "evicted",
                                EventKind::Retired => "retired",
                                EventKind::Hedged => "hedged",
                                EventKind::LocalFallback => "local-fallback",
                                EventKind::HedgeWon => "hedge-won",
                                EventKind::HedgeLost => "hedge-lost",
                            }
                            .to_string(),
                        ),
                    ),
                    ("worker", Json::Num(e.worker as f64)),
                    ("round", Json::Num(e.round as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("round", Json::Num(self.round as f64)),
            ("members", Json::Num(self.workers.len() as f64)),
            ("healthy", Json::Num(self.healthy_count() as f64)),
            ("workers", Json::Arr(workers)),
            ("events", Json::Arr(events)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(reg: &mut CapacityRegistry, worker: usize, per_flop: f64, n: usize, round0: u64) {
        for i in 0..n {
            let r = round0 + i as u64;
            reg.record_success(worker, 1e9, 1e6, per_flop * 1e9, 1e-7 * 1e6, r);
        }
    }

    #[test]
    fn normalized_fit_recovers_scales() {
        let mut reg = CapacityRegistry::new(2, TelemetryConfig::default());
        // Worker 0: exactly 2 ns/FLOP deterministic ⇒ pure shift fit.
        feed(&mut reg, 0, 2e-9, 16, 0);
        feed(&mut reg, 1, 2e-9, 16, 0);
        let est = reg.estimate(0).unwrap();
        assert!((est.cmp.theta - 2e-9).abs() / 2e-9 < 1e-9);
        assert_eq!(est.cmp.mu, ShiftExp::MU_DEGENERATE);
        assert!((est.tr.theta - 1e-7).abs() / 1e-7 < 1e-9);
        assert!(reg.estimate(1).is_some());
    }

    #[test]
    fn below_min_samples_no_estimate() {
        let mut reg = CapacityRegistry::new(1, TelemetryConfig::default());
        feed(&mut reg, 0, 1e-9, 3, 0);
        assert!(reg.estimate(0).is_none());
        assert_eq!(reg.straggler_score(0), 1.0);
    }

    #[test]
    fn slow_worker_quarantined_then_probed_then_reintegrated() {
        let cfg = TelemetryConfig::default();
        let mut reg = CapacityRegistry::new(3, cfg);
        feed(&mut reg, 0, 1e-9, 16, 0);
        feed(&mut reg, 1, 1e-9, 16, 0);
        // Worker 2 runs 5x slower than the pool: quarantined once its
        // EWMA crosses the threshold.
        feed(&mut reg, 2, 5e-9, 32, 0);
        assert!(reg.is_quarantined(2), "score={}", reg.straggler_score(2));
        assert_eq!(reg.healthy_count(), 2);
        assert!(reg
            .events()
            .iter()
            .any(|e| e.kind == EventKind::QuarantineSlow && e.worker == 2));

        // The quarantine happened rounds ago, so the first dispatch after
        // it finds the probe overdue and includes worker 2 once...
        let round = reg.round() + 1;
        assert_eq!(reg.active_workers(round), vec![0, 1, 2]);
        // ...then excludes it until the next probe comes due.
        assert_eq!(reg.active_workers(round + 1), vec![0, 1]);
        assert_eq!(reg.active_workers(round + cfg.probe_every - 1), vec![0, 1]);
        let probe_round = round + cfg.probe_every;
        assert_eq!(reg.active_workers(probe_round), vec![0, 1, 2]);
        assert_eq!(reg.active_workers(probe_round + 1), vec![0, 1]);

        // Recovery: fast probe samples drag the EWMA (half-life 32) back
        // under the reintegrate threshold within ~64 samples.
        feed(&mut reg, 2, 1e-9, 64, probe_round);
        assert!(!reg.is_quarantined(2));
        assert!(reg
            .events()
            .iter()
            .any(|e| e.kind == EventKind::Reintegrate && e.worker == 2));
    }

    #[test]
    fn two_pool_straggler_is_still_scored() {
        // Self-exclusive median: in a 2-worker pool the slow worker's
        // score must reflect the fast one, not its own EWMA.
        let mut reg = CapacityRegistry::new(2, TelemetryConfig::default());
        feed(&mut reg, 0, 1e-9, 16, 0);
        feed(&mut reg, 1, 5e-9, 16, 0);
        assert!((reg.straggler_score(1) - 5.0).abs() < 0.3, "{}", reg.straggler_score(1));
        assert!(reg.is_quarantined(1));
        assert!(!reg.is_quarantined(0));
    }

    #[test]
    fn stale_reply_does_not_wipe_failure_streak_or_rewind_clock() {
        let cfg = TelemetryConfig::default();
        let mut reg = CapacityRegistry::new(2, cfg);
        feed(&mut reg, 1, 1e-9, 16, 0);
        // Two live failures at rounds 100, 101...
        reg.record_failure(1, 100);
        reg.record_failure(1, 101);
        // ...then a long-delayed Output for old round 60 arrives.
        reg.record_success(1, 1e9, 1e6, 1.0, 1e-3, 60);
        // The streak survives: the next live failure must quarantine.
        reg.record_failure(1, 102);
        assert!(reg.is_quarantined(1), "stale success wiped the streak");
        // And the staleness clock did not rewind to round 60.
        reg.record_failure(0, 300); // advance the registry clock
        let est = reg.estimate(1).unwrap();
        assert!(est.stale_rounds <= 300 - 101, "stale={}", est.stale_rounds);
        // A success at (or after) the last failure round does clear it.
        let mut reg = CapacityRegistry::new(2, cfg);
        reg.record_failure(1, 10);
        reg.record_failure(1, 11);
        reg.record_success(1, 1e9, 1e6, 1.0, 1e-3, 12);
        reg.record_failure(1, 13);
        assert!(!reg.is_quarantined(1));
    }

    #[test]
    fn median_even_count_averages_middles() {
        // Delegated to util::stats::Summary::median; pin the behavior the
        // scoring logic depends on (true even-count median, NaN on empty).
        assert!(super::median(vec![]).is_nan());
        assert_eq!(super::median(vec![3.0]), 3.0);
        assert_eq!(super::median(vec![4.0, 1.0]), 2.5);
        assert_eq!(super::median(vec![5.0, 1.0, 3.0]), 3.0);
        assert_eq!(super::median(vec![1.0, 2.0, 10.0, 4.0]), 3.0);
    }

    #[test]
    fn consecutive_failures_quarantine() {
        let cfg = TelemetryConfig::default();
        let mut reg = CapacityRegistry::new(2, cfg);
        for r in 0..cfg.quarantine_failures as u64 {
            assert!(!reg.is_quarantined(1));
            reg.record_failure(1, r);
        }
        assert!(reg.is_quarantined(1));
        // A success elsewhere does not unquarantine worker 1.
        reg.record_success(0, 1e9, 1e6, 1.0, 1e-3, 10);
        assert!(reg.is_quarantined(1));
    }

    #[test]
    fn all_quarantined_falls_back_to_full_pool() {
        let cfg = TelemetryConfig::default();
        let mut reg = CapacityRegistry::new(2, cfg);
        for w in 0..2 {
            for r in 0..cfg.quarantine_failures as u64 {
                reg.record_failure(w, r);
            }
        }
        assert_eq!(reg.active_workers(1), vec![0, 1]);
        assert_eq!(reg.healthy_count(), 1); // clamped floor
    }

    #[test]
    fn fitted_profile_falls_back_then_tracks() {
        let base = SystemProfile::paper_default();
        let mut reg = CapacityRegistry::new(2, TelemetryConfig::default());
        assert_eq!(reg.fitted_profile(&base), base);
        // Deterministic 2x the base θ_cmp per FLOP.
        let per_flop = 2.0 * base.theta_cmp;
        feed(&mut reg, 0, per_flop, 16, 0);
        feed(&mut reg, 1, per_flop, 16, 0);
        let fitted = reg.fitted_profile(&base);
        assert!((fitted.theta_cmp - per_flop).abs() / per_flop < 1e-9);
        // Master-side terms untouched.
        assert_eq!(fitted.mu_m, base.mu_m);
        assert_eq!(fitted.theta_msg, base.theta_msg);
    }

    #[test]
    fn speeds_reflect_relative_ewma() {
        let mut reg = CapacityRegistry::new(3, TelemetryConfig::default());
        feed(&mut reg, 0, 1e-9, 16, 0);
        feed(&mut reg, 1, 1e-9, 16, 0);
        feed(&mut reg, 2, 3e-9, 16, 0);
        let speeds = reg.speeds();
        assert!((speeds[0].cmp - 1.0).abs() < 1e-6);
        assert!((speeds[2].cmp - 3.0).abs() < 0.01, "{:?}", speeds[2]);
    }

    #[test]
    fn membership_admit_evict_retire() {
        let cfg = TelemetryConfig::default();
        // Elastic start: empty pool is legal, dispatch set is empty.
        let mut reg = CapacityRegistry::new(0, cfg);
        assert_eq!(reg.n_workers(), 0);
        assert!(reg.active_workers(1).is_empty());
        assert!(!reg.any_estimate());

        reg.admit(7);
        reg.admit(9);
        reg.admit(7); // duplicate admission is a no-op (no second event)
        assert_eq!(reg.worker_ids(), vec![7, 9]);
        assert!(reg.contains(7) && !reg.contains(8));
        assert_eq!(reg.active_workers(2), vec![7, 9]);
        assert_eq!(
            reg.events().iter().filter(|e| e.kind == EventKind::Joined).count(),
            2
        );

        feed(&mut reg, 7, 2e-9, 16, 0);
        assert_eq!(reg.samples_of(7), 16);
        assert!(reg.any_estimate());

        // Eviction removes the worker everywhere; stale records no-op.
        reg.evict(9);
        reg.evict(9); // double link-death event: graceful
        assert_eq!(reg.worker_ids(), vec![7]);
        reg.record_success(9, 1e9, 1e6, 1.0, 1e-3, 50);
        reg.record_failure(9, 51);
        assert_eq!(reg.samples_of(9), 0);
        assert_eq!(reg.straggler_score(9), 1.0);
        assert!(!reg.is_quarantined(9));
        assert!(reg.estimate(9).is_none());
        assert_eq!(
            reg.events().iter().filter(|e| e.kind == EventKind::Evicted).count(),
            1
        );

        reg.retire(7);
        assert!(reg.worker_ids().is_empty());
        assert!(reg
            .events()
            .iter()
            .any(|e| e.kind == EventKind::Retired && e.worker == 7));
    }

    #[test]
    fn service_quantile_scales_with_subtask_size() {
        let mut reg = CapacityRegistry::new(2, TelemetryConfig::default());
        // Below min_samples: no quantile (caller falls back to a floor).
        assert!(reg.service_quantile(0, 0.99, 1e9, 1e6).is_none());
        feed(&mut reg, 0, 2e-9, 16, 0);
        feed(&mut reg, 1, 2e-9, 16, 0);
        // Deterministic 2 ns/FLOP + 100 ns/byte fits are near-pure
        // shifts (μ degenerate ⇒ negligible tail term), so the p99 is
        // within a fraction of a percent of the shift, linear in scale.
        let q = reg.service_quantile(0, 0.99, 1e9, 1e6).unwrap();
        let want = 2e-9 * 1e9 + 1e-7 * 1e6;
        assert!((q - want).abs() / want < 1e-2, "q={q} want={want}");
        let double = reg.service_quantile(0, 0.99, 2e9, 2e6).unwrap();
        assert!((double - 2.0 * q).abs() / q < 1e-9, "quantile not linear in scale");
        // Reliability events land in the log and the JSON dump.
        reg.note_reliability(EventKind::Hedged, 0, 5);
        reg.note_reliability(EventKind::LocalFallback, 1, 6);
        let json = reg.to_json().to_string();
        assert!(json.contains("hedged") && json.contains("local-fallback"));
    }

    #[test]
    fn chronic_hedge_loser_score_rises_and_quarantines() {
        let mut reg = CapacityRegistry::new(3, TelemetryConfig::default());
        feed(&mut reg, 0, 1e-9, 16, 0);
        feed(&mut reg, 1, 1e-9, 16, 0);
        feed(&mut reg, 2, 1e-9, 16, 0);
        let base = reg.straggler_score(2);
        assert!((base - 1.0).abs() < 0.05, "timing-identical pool scores ~1.0");
        // Six hedges fire against worker 2 and the backup wins every one.
        for r in 0..6u64 {
            reg.note_reliability(EventKind::Hedged, 2, 20 + r);
            reg.note_reliability(EventKind::HedgeLost, 2, 20 + r);
        }
        let penalized = reg.straggler_score(2);
        assert!(penalized > base, "losses must raise the score");
        assert!(
            penalized > reg.config().quarantine_score,
            "six net losses cross the threshold: {penalized}"
        );
        // The next timing sample lets the quarantine transition see it.
        reg.record_success(2, 1e9, 1e6, 1e-9 * 1e9, 1e-7 * 1e6, 30);
        assert!(reg.is_quarantined(2));
        // Balanced outcomes are not punished: a win offsets a loss.
        reg.note_reliability(EventKind::Hedged, 1, 40);
        reg.note_reliability(EventKind::HedgeLost, 1, 40);
        reg.note_reliability(EventKind::Hedged, 1, 41);
        reg.note_reliability(EventKind::HedgeWon, 1, 41);
        assert!((reg.straggler_score(1) - 1.0).abs() < 0.3);
        // Outcomes land in the JSON dump alongside the counters.
        let json = reg.to_json().to_string_compact();
        assert!(json.contains("hedge-lost") && json.contains("hedge_wins"));
    }

    #[test]
    fn staleness_widens_theta() {
        let cfg = TelemetryConfig::default();
        let mut reg = CapacityRegistry::new(2, cfg);
        feed(&mut reg, 0, 2e-9, 16, 0);
        let fresh = reg.estimate(0).unwrap();
        // Advance the registry clock far past stale_after via worker 1
        // while worker 0 stays silent.
        reg.record_success(1, 1e9, 1e6, 2.0, 1e-3, 16 + 3 * cfg.stale_after);
        let stale = reg.estimate(0).unwrap();
        assert!(stale.cmp.theta > 1.5 * fresh.cmp.theta);
        assert!(stale.cmp.mu < fresh.cmp.mu);
        assert!(stale.stale_rounds > cfg.stale_after);
    }
}
