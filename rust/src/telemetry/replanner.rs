//! Closed-loop replanning: periodically re-solve the optimal splitting
//! problem against the fitted [`CapacityRegistry`] and swap the
//! per-layer `(n, k)` plan — with hysteresis, so the plan only moves
//! when the *predicted* improvement is worth the disruption.
//!
//! Two solver paths:
//!
//! * [`Replanner::replan`] — the fast iid path: `solve_k_circ` per layer
//!   against the pool-median fitted profile, with the pool size shrunk
//!   to the non-quarantined worker count. Cheap enough to run between
//!   requests on the live engine.
//! * [`Replanner::plan_hetero`] — the Monte-Carlo heterogeneous
//!   refinement (`planner::hetero::optimize`) over the registry's
//!   per-worker relative speeds: jointly picks the worker *subset* and
//!   `k` for one layer. Too expensive for every round; the adaptive
//!   experiment and examples use it as the offline refinement step.

use crate::coding::{SchemeKind, SchemeSelector};
use crate::latency::approx::l_integer;
use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;
use crate::model::ModelPlan;
use crate::planner::hetero::{self, HeteroPlan};
use crate::planner::solve_k_circ;
use crate::util::Rng;

use super::registry::CapacityRegistry;

#[derive(Clone, Copy, Debug)]
pub struct ReplanConfig {
    /// Rounds between replan attempts on the live engine.
    pub every_rounds: u64,
    /// Relative predicted-latency improvement required before the plan
    /// is swapped (`L_new < (1 − hysteresis) · L_current`). Prevents
    /// plan thrash from estimation noise: near the optimum `L(k)` is
    /// flat, so noise-induced ±1 moves in `k` never clear the bar.
    pub hysteresis: f64,
}

impl Default for ReplanConfig {
    fn default() -> ReplanConfig {
        ReplanConfig {
            every_rounds: 24,
            hysteresis: 0.05,
        }
    }
}

/// Outcome of one replan attempt (for logs/telemetry dumps).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplanOutcome {
    pub swapped: bool,
    /// Predicted end-to-end distributed-layer latency of the plan in
    /// force after this attempt, under the fitted profile.
    pub predicted: f64,
    /// Predicted latency of the incumbent plan under the fitted profile.
    pub incumbent: f64,
}

#[derive(Clone, Debug)]
pub struct Replanner {
    cfg: ReplanConfig,
    last_attempt_round: u64,
    /// Total plan swaps performed (telemetry).
    pub switches: u64,
}

impl Replanner {
    pub fn new(cfg: ReplanConfig) -> Replanner {
        Replanner {
            cfg,
            last_attempt_round: 0,
            switches: 0,
        }
    }

    pub fn config(&self) -> &ReplanConfig {
        &self.cfg
    }

    /// Is a replan attempt due at `round`?
    pub fn due(&self, round: u64) -> bool {
        round >= self.last_attempt_round + self.cfg.every_rounds
    }

    /// Make the next `due` check pass as early as the cadence allows:
    /// membership changed (join/evict/retire), so the (n, k) split
    /// solved for the old pool is stale. Resets the attempt clock to 0 —
    /// for very young clusters (`round < every_rounds`) the attempt
    /// still waits for the cadence floor.
    pub fn force(&mut self) {
        self.last_attempt_round = 0;
    }

    /// Re-solve `k` for every distributed layer of `plan` against the
    /// registry's fitted profile and the current healthy pool size;
    /// mutate the plan in place iff the predicted improvement beats the
    /// hysteresis. Layer type-1/type-2 classification is left alone —
    /// re-deciding *whether* to distribute mid-stream would change
    /// output numerics, not just latency.
    pub fn replan(
        &mut self,
        plan: &mut ModelPlan,
        registry: &CapacityRegistry,
        base: &SystemProfile,
        round: u64,
    ) -> ReplanOutcome {
        self.last_attempt_round = round;
        let fitted = registry.fitted_profile(base);
        let n_active = registry.healthy_count();
        let mut l_new = 0.0;
        let mut l_cur = 0.0;
        let mut new_ks: Vec<(usize, usize)> = Vec::new(); // (conv index, k)
        for (i, c) in plan.convs.iter().enumerate() {
            if !c.distributed {
                continue;
            }
            let k_new = solve_k_circ(&c.dims, &fitted, n_active)
                .k
                .clamp(1, n_active.min(c.dims.w_o));
            let k_cur = c.k.clamp(1, n_active.min(c.dims.w_o));
            l_new += l_integer(&c.dims, &fitted, n_active, k_new);
            l_cur += l_integer(&c.dims, &fitted, n_active, k_cur);
            new_ks.push((i, k_new));
        }
        if l_new < (1.0 - self.cfg.hysteresis) * l_cur {
            for (i, k) in new_ks {
                let c = &mut plan.convs[i];
                c.k = k;
                c.est_distributed = l_integer(&c.dims, &fitted, n_active, k);
            }
            self.switches += 1;
            log::info!(
                "replan at round {round}: swapped plan (predicted {l_new:.3}s vs \
                 incumbent {l_cur:.3}s, n_active={n_active})"
            );
            ReplanOutcome {
                swapped: true,
                predicted: l_new,
                incumbent: l_cur,
            }
        } else {
            ReplanOutcome {
                swapped: false,
                predicted: l_cur,
                incumbent: l_cur,
            }
        }
    }

    /// The `--scheme auto` replan path: re-solve `k` per layer as
    /// [`Replanner::replan`] does, then let the [`SchemeSelector`] rank
    /// schemes at that split under the fitted profile (and the master's
    /// recent churn count). The same hysteresis bar gates the swap —
    /// scheme churn is plan thrash too, so a marginally-better
    /// replication prediction does not evict a working MDS plan. The
    /// incumbent's cost is scored with the *same* selector predictor so
    /// the comparison is apples-to-apples.
    pub fn replan_auto(
        &mut self,
        plan: &mut ModelPlan,
        registry: &CapacityRegistry,
        base: &SystemProfile,
        round: u64,
        selector: &SchemeSelector,
        churn_events: usize,
    ) -> ReplanOutcome {
        self.last_attempt_round = round;
        let fitted = registry.fitted_profile(base);
        let n_active = registry.healthy_count();
        if n_active == 0 {
            return ReplanOutcome {
                swapped: false,
                predicted: 0.0,
                incumbent: 0.0,
            };
        }
        let mut l_new = 0.0;
        let mut l_cur = 0.0;
        let mut picks: Vec<(usize, SchemeKind, usize)> = Vec::new();
        for (i, c) in plan.convs.iter().enumerate() {
            if !c.distributed {
                continue;
            }
            let k_solved = solve_k_circ(&c.dims, &fitted, n_active)
                .k
                .clamp(1, n_active.min(c.dims.w_o));
            let choice =
                selector.choose(&c.dims, &fitted, n_active, k_solved, None, churn_events);
            let k_cur = c.k.clamp(1, n_active.min(c.dims.w_o).max(1));
            l_new += choice.predicted;
            l_cur += selector.predict(c.scheme, &c.dims, &fitted, n_active, k_cur);
            picks.push((i, choice.kind, choice.k));
        }
        if l_new < (1.0 - self.cfg.hysteresis) * l_cur {
            for (i, kind, k) in picks {
                let c = &mut plan.convs[i];
                c.scheme = kind;
                c.k = k;
                c.est_distributed = selector.predict(kind, &c.dims, &fitted, n_active, k);
            }
            self.switches += 1;
            log::info!(
                "replan(auto) at round {round}: swapped plan (predicted {l_new:.3}s vs \
                 incumbent {l_cur:.3}s, n_active={n_active}, churn={churn_events})"
            );
            ReplanOutcome {
                swapped: true,
                predicted: l_new,
                incumbent: l_cur,
            }
        } else {
            ReplanOutcome {
                swapped: false,
                predicted: l_cur,
                incumbent: l_cur,
            }
        }
    }

    /// Monte-Carlo heterogeneous refinement for one layer: jointly pick
    /// the worker subset and `k` from the registry's fitted per-worker
    /// speeds (see `planner::hetero`).
    pub fn plan_hetero(
        &self,
        registry: &CapacityRegistry,
        dims: &LayerDims,
        base: &SystemProfile,
        samples: usize,
        rng: &mut Rng,
    ) -> HeteroPlan {
        let fitted = registry.fitted_profile(base);
        hetero::optimize(dims, &fitted, &registry.speeds(), samples, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::planner::SplitPolicy;
    use crate::telemetry::TelemetryConfig;

    fn vgg_plan(p: &SystemProfile) -> ModelPlan {
        let model = zoo::model("vgg16").unwrap();
        let mut rng = Rng::new(1);
        ModelPlan::build(&model, p, 10, SplitPolicy::KCircle, &mut rng).unwrap()
    }

    /// Feed the registry samples that exactly reproduce `profile`'s mean
    /// worker behaviour (deterministic per-unit times).
    fn feed_profile(reg: &mut CapacityRegistry, p: &SystemProfile, n: usize, rounds: u64) {
        let per_flop = p.theta_cmp + 1.0 / p.mu_cmp;
        let per_byte = p.theta_rec + 1.0 / p.mu_rec;
        for r in 0..rounds {
            for w in 0..n {
                reg.record_success(w, 1e9, 1e6, per_flop * 1e9, per_byte * 1e6, r);
            }
        }
    }

    #[test]
    fn stable_pool_does_not_thrash() {
        let base = SystemProfile::paper_default();
        let mut plan = vgg_plan(&base);
        let ks_before: Vec<usize> = plan.convs.iter().map(|c| c.k).collect();
        let mut reg = CapacityRegistry::new(10, TelemetryConfig::default());
        feed_profile(&mut reg, &base, 10, 32);
        let mut rp = Replanner::new(ReplanConfig::default());
        let out = rp.replan(&mut plan, &reg, &base, 32);
        // Deterministic samples fit a pure shift with the same mean; the
        // re-solved k may differ slightly, but hysteresis must hold the
        // incumbent unless the predicted gain is real.
        let ks_after: Vec<usize> = plan.convs.iter().map(|c| c.k).collect();
        if !out.swapped {
            assert_eq!(ks_before, ks_after);
        }
        assert!(out.predicted <= out.incumbent * (1.0 + 1e-12));
        assert_eq!(rp.switches, u64::from(out.swapped));
    }

    #[test]
    fn auto_replan_is_stable_on_a_calm_fitted_pool() {
        let base = SystemProfile::paper_default();
        let mut plan = vgg_plan(&base);
        let mut reg = CapacityRegistry::new(10, TelemetryConfig::default());
        feed_profile(&mut reg, &base, 10, 32);
        let mut rp = Replanner::new(ReplanConfig::default());
        let selector = SchemeSelector::default();
        let out = rp.replan_auto(&mut plan, &reg, &base, 32, &selector, 0);
        assert!(out.predicted <= out.incumbent * (1.0 + 1e-12));
        // Calm pool, no churn, no deadline: the selector ranks MDS
        // against replication under the fitted profile, and under the
        // paper profile MDS wins at every VGG layer (replication's
        // k = n/2 doubles per-shard transmission while MDS encode is
        // cheap on the 5 GFLOPS master). The plan must hold MDS — a
        // swap here would be scheme thrash on a stable pool.
        for c in plan.convs.iter().filter(|c| c.distributed) {
            assert_eq!(
                c.scheme,
                SchemeKind::Mds,
                "calm auto replan drifted off MDS on {}",
                c.node_id
            );
        }
    }

    #[test]
    fn due_respects_cadence() {
        let mut rp = Replanner::new(ReplanConfig {
            every_rounds: 10,
            hysteresis: 0.05,
        });
        assert!(rp.due(10));
        assert!(!rp.due(9));
        rp.last_attempt_round = 10;
        assert!(!rp.due(19));
        assert!(rp.due(20));
    }

    #[test]
    fn strong_transmission_straggling_forces_lower_k() {
        // The structural case from the solver tests: heavy transmission
        // straggling pushes k° down. Feed the registry samples whose
        // *excess* is 30x the base profile's and check the replanner
        // actually swaps to smaller k.
        let base = SystemProfile::paper_default();
        let mut plan = vgg_plan(&base);
        let k_before: usize = plan
            .convs
            .iter()
            .find(|c| c.distributed)
            .map(|c| c.k)
            .unwrap();
        let mut congested = base;
        congested.mu_rec /= 30.0;
        congested.mu_sen /= 30.0;

        // Noisy samples from the congested profile (deterministic seed).
        let mut rng = Rng::new(42);
        let mut reg = CapacityRegistry::new(10, TelemetryConfig::default());
        for r in 0..40u64 {
            for w in 0..10 {
                let exec = 1e9 * congested.theta_cmp + rng.exponential(congested.mu_cmp / 1e9);
                let tr = 1e6 * congested.theta_rec + rng.exponential(congested.mu_rec / 1e6);
                reg.record_success(w, 1e9, 1e6, exec, tr, r);
            }
        }
        let mut rp = Replanner::new(ReplanConfig::default());
        let out = rp.replan(&mut plan, &reg, &base, 40);
        assert!(out.swapped, "expected a swap: {out:?}");
        let k_after: usize = plan
            .convs
            .iter()
            .find(|c| c.distributed)
            .map(|c| c.k)
            .unwrap();
        assert!(
            k_after < k_before,
            "congestion should lower k: {k_after} !< {k_before}"
        );
    }
}
