//! Online telemetry & adaptive replanning (the closed loop the paper
//! leaves open: §III calibrates the shift-exponential profile *offline*,
//! but device capacities are "time-varying and possibly unknown").
//!
//! Three stages, wired through the coordinator and the simulator:
//!
//! 1. **Collection** — every subtask reply carries the worker-measured
//!    execution time ([`crate::coordinator::messages::FromWorker::Output`]);
//!    the master subtracts it from the dispatch→reply wall time to split
//!    each sample into transmission vs execution, normalizes by the
//!    subtask's FLOPs/bytes, and feeds bounded EWMA-decayed
//!    [`SlidingWindow`]s per worker.
//! 2. **Estimation** — [`CapacityRegistry`] fits per-worker
//!    shift-exponential parameters online (`ShiftExp::fit_trimmed`, with
//!    staleness-aware widening), scores stragglers against the pool
//!    median, quarantines chronic stragglers/failures, and probes them
//!    back in when they recover.
//! 3. **Replanning** — [`Replanner`] periodically re-solves the optimal
//!    splitting problem (`solve_k_circ`, or the Monte-Carlo hetero
//!    planner) against the fitted profile and swaps the per-layer
//!    `(n, k)` plan between requests, with hysteresis against thrash.
//!
//! Validated deterministically by `sim::adaptive` (drifting-capacity
//! scenarios) and measured by `cocoi experiment adaptive`
//! (`BENCH_adaptive.json`).

pub mod registry;
pub mod replanner;
pub mod window;

pub use registry::{
    CapacityRegistry, EventKind, TelemetryConfig, TelemetryEvent, WorkerEstimate,
};
pub use replanner::{ReplanConfig, Replanner, ReplanOutcome};
pub use window::SlidingWindow;
