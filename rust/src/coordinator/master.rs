//! The CoCoI master: tracks inference, splits + encodes type-1 conv
//! layers, dispatches encoded subtasks, decodes from the first `k`
//! results, handles failure re-dispatch, and executes type-2 work
//! locally (paper §II).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coding::{RedundancyScheme, SchemeSelector};
use crate::conv::{SplitPlan, Tensor};
use crate::latency::SystemProfile;
use crate::model::graph::execute_simple_op;
use crate::model::{zoo, ModelPlan, ModelSpec, Node, Op, WeightStore};
use crate::obs::trace::TraceHandle;
use crate::obs::MetricsHub;
use crate::planner::SplitPolicy;
use crate::runtime::ConvProvider;
use crate::telemetry::{CapacityRegistry, EventKind, ReplanConfig, Replanner, TelemetryConfig};
use crate::transport::LinkPair;
use crate::util::json::Json;
use crate::util::Rng;

use super::messages::{FromWorker, ToWorker, WorkOrder};
use super::metrics::{InferenceMetrics, LayerMetrics, WorkerPhase};

/// Everything the master's single event channel can carry: worker
/// replies (stamped with the reader-thread arrival instant), membership
/// transitions (a handshake thread admitting a joiner, a reader thread
/// reporting link death), and — when an
/// [`super::server::InferenceServer`] front-end is attached — request
/// submissions and the drain signal. Multiplexing everything into the
/// same channel is what lets the engine's run loop block on *one*
/// receiver and wake for a finished subtask, a new request, or churn.
pub(super) enum MasterEvent {
    Reply(usize, FromWorker, Instant),
    Submit(super::server::ServerRequest),
    Drain,
    /// A worker completed the join handshake (Join → JoinAck → prepack →
    /// Ready); its send half arrives here. The handshake thread sends
    /// this *before* spawning the reader, so in the FIFO channel `Joined`
    /// always precedes any `Reply` from the same id.
    Joined {
        id: usize,
        name: String,
        tx: Box<dyn crate::transport::FrameTx>,
    },
    /// A worker's link died or its heartbeat deadline lapsed (the reader
    /// thread exited) — may fire more than once per id; handlers are
    /// idempotent.
    LinkDown(usize),
}

/// One pool member: its send half plus membership state, keyed in
/// [`Master::workers`] by *stable worker id* (never reused; a rejoining
/// worker gets a fresh id).
pub(super) struct WorkerLink {
    pub(super) tx: Box<dyn crate::transport::FrameTx>,
    pub(super) name: String,
    /// Graceful retirement in progress: excluded from new dispatches,
    /// removed once its in-flight subtasks drain.
    pub(super) retiring: bool,
    /// Highest heartbeat `seq` seen from this worker. A beat at or
    /// below it is a *regressed* beacon — a zombie half-open link (or a
    /// replayed frame) that must not keep resetting the liveness
    /// deadline — and takes a strike (log + telemetry counter).
    pub(super) last_hb_seq: u64,
}

// The scheme enum + selection policy moved to `coding::select` so the
// model plan and the replanner can reason about schemes without a
// coordinator dependency; re-exported here so `coordinator::SchemeKind`
// keeps resolving for existing callers.
pub use crate::coding::select::SchemeKind;

/// How the master schedules coded rounds over the worker pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Strict round barrier (the paper's workflow): one request at a
    /// time; layer ℓ fully decodes before layer ℓ+1 dispatches.
    #[default]
    RoundBarrier,
    /// Pipelined engine (`coordinator::engine`): multiple requests in
    /// flight over the shared pool, per-round straggler cancellation,
    /// decode overlapped with other requests' worker execution.
    Pipelined,
}

/// Master configuration.
#[derive(Clone, Debug)]
pub struct MasterConfig {
    pub scheme: SchemeKind,
    pub policy: SplitPolicy,
    pub profile: SystemProfile,
    pub weight_seed: u64,
    pub seed: u64,
    /// Per-round receive timeout before declaring the cluster wedged.
    pub recv_timeout: Duration,
    /// Execution engine (see [`ExecMode`]); benchmarks toggle this to
    /// compare the pipeline against the round barrier.
    pub mode: ExecMode,
    /// Close the telemetry loop: dispatch only to the registry's active
    /// (non-quarantined) workers, probe quarantined ones back in, and
    /// let the replanner swap the per-layer k between requests. Timing
    /// samples are collected either way; `adaptive` only controls
    /// whether they steer dispatch + planning.
    pub adaptive: bool,
    /// Telemetry collection/quarantine tuning.
    pub telemetry: TelemetryConfig,
    /// Replan cadence + hysteresis.
    pub replan: ReplanConfig,
    /// Cross-request shard coalescing (pipelined engine only): the max
    /// number of concurrent requests whose same-layer rounds are merged
    /// into one multi-payload dispatch (a worker then runs ONE
    /// prepacked-weight pass spanning all of them). `0` or `1` disables
    /// coalescing; the uncoded decode stays bitwise identical either
    /// way (`rust/tests/coalesce.rs`).
    pub coalesce: usize,
    /// Heartbeat deadline for runtime-joined (TCP) workers: their reader
    /// threads arm a read timeout of this much, and the `JoinAck` tells
    /// the worker to beacon at a third of it. Silence past the deadline
    /// evicts the worker.
    pub heartbeat: Duration,
    /// Hedged dispatch (pipelined engine): once an outstanding subtask
    /// has been out longer than this quantile of its holder's fitted
    /// service-time distribution (see
    /// [`CapacityRegistry::service_quantile`]), the watchdog
    /// speculatively re-dispatches the same shard to the least-loaded
    /// other worker — first reply wins, the loser is cancelled. Must be
    /// in `[0, 1)`; unfitted workers use a fixed floor ([`HEDGE_FLOOR`]),
    /// and fitted delays are floored there too so millisecond-scale
    /// jitter never triggers speculation. `0.0` disables hedging.
    pub hedge_quantile: f64,
    /// Per-round re-dispatch budget (failure re-dispatches + hedges).
    /// Exceeding it no longer fails the request: the round stops burning
    /// the pool and escalates to the master-local decode fallback.
    pub retry_budget: usize,
    /// Complete decodes on the master when the pool cannot: compute the
    /// missing shards locally through the master's own provider (conv
    /// linearity makes an encoded payload convolve to the corresponding
    /// encoded output, so this works for every scheme). On by default —
    /// the serving contract is that an admitted request never errors;
    /// turning it off restores the old fail-fast behavior.
    pub local_fallback: bool,
    /// Opt-in span tracing (`cocoi infer --trace out.json`): when set,
    /// the engine records a span tree per request plus pool-level events
    /// into this bounded recorder. `None` (the default) costs one branch
    /// per would-be emit site and allocates nothing — outputs are
    /// bitwise identical either way (`rust/tests/obs.rs`).
    pub trace: Option<TraceHandle>,
    /// Trace sampling (`--trace-sample N`): record the full span tree of
    /// one admitted request in every `N`. Sampled-out requests allocate
    /// zero spans — their root span is never created, and every
    /// per-request emit site is gated on it — while pool-level events
    /// (join/evict/retire) are always recorded. `0`/`1` trace every
    /// request (the old behavior).
    pub trace_sample: usize,
    /// Concurrency cap of the master-local decode fallback: at most this
    /// many of a round's missing shards are convolved at once (scoped
    /// threads sharing the master's provider). Keeps a worst-case
    /// fallback — every shard missing on a wide round — from fanning out
    /// unbounded CPU work next to the engine's event loop. `0` and `1`
    /// both mean serial.
    pub fallback_concurrency: usize,
    /// Per-tenant DRR weights for the pipelined engine's admission
    /// scheduler (`--tenant-weight a=2,b=1`): a backlogged tenant is
    /// admitted in proportion to its weight per round-robin round.
    /// Tenants not listed get weight 1; empty (the default) means every
    /// tenant — including the implicit single default tenant — weighs 1,
    /// which reproduces the old global-heap admission order exactly.
    pub tenant_weights: Vec<(String, f64)>,
}

impl Default for MasterConfig {
    fn default() -> Self {
        MasterConfig {
            scheme: SchemeKind::Mds,
            policy: SplitPolicy::KCircle,
            profile: SystemProfile::paper_default(),
            weight_seed: 42,
            seed: 7,
            recv_timeout: Duration::from_secs(120),
            mode: ExecMode::RoundBarrier,
            adaptive: false,
            telemetry: TelemetryConfig::default(),
            replan: ReplanConfig::default(),
            coalesce: 1,
            heartbeat: Duration::from_secs(10),
            hedge_quantile: 0.99,
            retry_budget: 4,
            local_fallback: true,
            trace: None,
            trace_sample: 1,
            fallback_concurrency: 4,
            tenant_weights: Vec::new(),
        }
    }
}

/// Minimum (and unfitted-worker default) hedge watchdog delay. Keeps the
/// watchdog from speculating on ordinary scheduling jitter: tasks in the
/// test models complete in milliseconds, so a healthy pool never crosses
/// this, while a stalled shard always does.
pub(super) const HEDGE_FLOOR: Duration = Duration::from_millis(500);

/// Dispatch bookkeeping for one coded round, kept (bounded) *after* the
/// round decodes so late straggler replies — the samples that matter
/// most for capacity estimation — still produce telemetry instead of
/// being dropped as stale.
pub(super) struct RoundTelemetry {
    pub(super) flops_per_task: f64,
    pub(super) bytes_per_task: f64,
    /// task id -> last dispatch instant.
    pub(super) dispatched_at: Vec<Instant>,
    /// Decoded/finished; only done rounds are eligible for eviction
    /// (the pipelined engine can hold more than `ROUND_LOG_CAP` rounds
    /// in flight on a large batch).
    pub(super) done: bool,
}

/// How many recently-dispatched rounds keep telemetry bookkeeping.
const ROUND_LOG_CAP: usize = 64;

/// How many rounds back a membership event still counts as "recent
/// churn" for the scheme selector.
const CHURN_WINDOW: u64 = 48;

/// The master device.
pub struct Master {
    pub(super) model: ModelSpec,
    /// The zoo name of [`Master::model`] — echoed in `JoinAck` so a
    /// runtime joiner prepacks the right weights.
    pub(super) model_name: String,
    pub(super) weights: WeightStore,
    pub(super) plan: ModelPlan,
    pub(super) config: MasterConfig,
    pub(super) provider: std::sync::Arc<dyn ConvProvider>,
    /// The pool, keyed by stable worker id (see [`WorkerLink`]).
    pub(super) workers: BTreeMap<usize, WorkerLink>,
    /// Id allocator for runtime joiners; shared with handshake threads.
    next_worker_id: Arc<AtomicUsize>,
    /// Replies arrive tagged with the reader-thread arrival instant, so
    /// transmission telemetry measures the wire, not however long the
    /// master took to get back to the channel. Server submissions,
    /// membership transitions, and the drain signal are multiplexed
    /// into the same stream.
    pub(super) events: mpsc::Receiver<MasterEvent>,
    /// A sender into [`Master::events`]; the serving front-end clones it
    /// for its submission path, handshake/reader threads for membership
    /// events. Keeping one here also means the channel never disconnects
    /// while the master lives.
    event_tx: mpsc::Sender<MasterEvent>,
    pub(super) round: u64,
    pub(super) rng: Rng,
    /// Per-worker capacity telemetry (always collected; steers dispatch
    /// and replanning only when `config.adaptive`).
    pub(super) registry: CapacityRegistry,
    pub(super) replanner: Replanner,
    /// The per-layer scheme policy (consulted only under
    /// [`SchemeKind::Auto`]; see [`Master::choose_scheme`]).
    pub(super) selector: SchemeSelector,
    /// Rounds at which membership changed (join/evict/retire), bounded
    /// to the recent [`CHURN_WINDOW`] — the selector flips churning
    /// pools to rateless LT.
    churn_rounds: Vec<u64>,
    /// Recent rounds' dispatch bookkeeping (see [`RoundTelemetry`]).
    pub(super) round_log: std::collections::BTreeMap<u64, RoundTelemetry>,
    /// Always-on latency histograms + pool gauges, shared with the
    /// serving front-end's scrape (see [`Master::metrics_hub`]).
    pub(super) hub: MetricsHub,
}

/// Forward one link's frames into the shared event channel, tagging the
/// stable worker id and the arrival instant; on exit (peer closed, bad
/// frame, recv error — including a lapsed heartbeat read-timeout) emit
/// `LinkDown` so the membership path fires. Detached: lives exactly as
/// long as its link.
fn spawn_reader(
    id: usize,
    mut rx: Box<dyn crate::transport::FrameRx>,
    agg: mpsc::Sender<MasterEvent>,
) {
    let _ = std::thread::Builder::new()
        .name(format!("rx-worker-{id}"))
        .spawn(move || {
            loop {
                match rx.recv() {
                    Ok(Some(frame)) => match FromWorker::decode(&frame) {
                        Ok(msg) => {
                            // Arrival stamp here, not at processing
                            // time: the master may be busy for a while
                            // before it drains the channel.
                            let ev = MasterEvent::Reply(id, msg, Instant::now());
                            if agg.send(ev).is_err() {
                                return;
                            }
                        }
                        Err(e) => {
                            log::error!("worker {id}: bad frame: {e:#}");
                            break;
                        }
                    },
                    Ok(None) => break,
                    Err(e) => {
                        log::warn!("worker {id}: recv failed (dead link or lapsed heartbeat): {e:#}");
                        break;
                    }
                }
            }
            let _ = agg.send(MasterEvent::LinkDown(id));
        });
}

/// One join handshake, run on its own thread per accepted connection:
/// `Join` → validate protocol+model → `JoinAck{id, model, seed,
/// heartbeat}` → the worker prepacks and sends `Ready` → arm the
/// heartbeat read-timeout, hand the send half to the master
/// (`MasterEvent::Joined`), and start the reader.
fn handshake(
    stream: std::net::TcpStream,
    event_tx: mpsc::Sender<MasterEvent>,
    next_id: Arc<AtomicUsize>,
    model: String,
    weight_seed: u64,
    heartbeat: Duration,
) -> Result<()> {
    use crate::transport::tcp::TcpLink;
    use crate::transport::Link;
    let mut link = TcpLink::from_stream(stream);
    // Bound the handshake so a silent dialer can't pin this thread.
    link.set_read_timeout(Some(Duration::from_secs(30)))?;
    let frame = link.recv()?.context("peer closed before Join")?;
    let (name, protocol, model_hint) = match FromWorker::decode(&frame)? {
        FromWorker::Join {
            name,
            protocol,
            model,
        } => (name, protocol, model),
        other => bail!("expected Join, got {other:?}"),
    };
    if protocol != super::messages::PROTOCOL_VERSION {
        let reason = format!(
            "protocol {protocol} != master's {}",
            super::messages::PROTOCOL_VERSION
        );
        let _ = link.send(&ToWorker::JoinReject { reason: reason.clone() }.encode());
        bail!("rejected join from {name}: {reason}");
    }
    if !model_hint.is_empty() && model_hint != model {
        let reason = format!("model {model_hint:?} != master's {model:?}");
        let _ = link.send(&ToWorker::JoinReject { reason: reason.clone() }.encode());
        bail!("rejected join from {name}: {reason}");
    }
    let id = next_id.fetch_add(1, Ordering::SeqCst);
    let heartbeat_ms = ((heartbeat.as_millis() / 3) as u32).max(1);
    link.send(
        &ToWorker::JoinAck {
            worker_id: id as u64,
            model,
            weight_seed,
            heartbeat_ms,
        }
        .encode(),
    )?;
    // The joiner now regenerates + prepacks the weights; allow it time.
    link.set_read_timeout(Some(Duration::from_secs(120)))?;
    loop {
        let frame = link.recv()?.context("peer closed during prepack")?;
        match FromWorker::decode(&frame)? {
            FromWorker::Ready => break,
            FromWorker::Heartbeat { .. } => continue, // early beacons are fine
            other => bail!("worker {id} ({name}): expected Ready, got {other:?}"),
        }
    }
    // From here on the heartbeat deadline polices the link.
    let (tx, rx) = crate::transport::split::split_tcp(link.into_stream())?;
    rx.set_read_timeout(Some(heartbeat))?;
    log::info!("worker {id} ({name}) completed join handshake");
    // Joined must precede any Reply(id) in the FIFO channel, so send it
    // BEFORE the reader starts.
    event_tx
        .send(MasterEvent::Joined {
            id,
            name,
            tx: Box::new(tx),
        })
        .map_err(|_| anyhow::anyhow!("master gone during join"))?;
    spawn_reader(id, Box::new(rx), event_tx);
    Ok(())
}

/// One request's slice of a [`PreparedRound`]: its id, its master-local
/// remainder piece, and its own per-layer metrics (each coalesced
/// request reports the round in its own latency breakdown).
pub(super) struct PreparedPart {
    pub(super) request: u64,
    /// Master-local remainder slice (footnote 2); convolved *after*
    /// dispatch so workers start first.
    pub(super) remainder_input: Option<Tensor>,
    pub(super) lm: LayerMetrics,
}

/// A distributed layer round after split + encode, frames ready to send.
/// Shared between the round-barrier path and the pipelined engine so the
/// two produce identical encodings (and therefore identical outputs).
/// Carries one [`PreparedPart`] per coalesced request (exactly one on
/// the barrier path and whenever coalescing is off); the dispatch
/// frames interleave every part's shard `i` into one multi-payload
/// `WorkOrder`.
pub(super) struct PreparedRound {
    pub(super) round: u64,
    pub(super) scheme: Box<dyn RedundancyScheme>,
    /// Pre-encoded dispatch frames, one per subtask; re-dispatch after a
    /// failure reuses the same bytes.
    pub(super) frames: Vec<Vec<u8>>,
    /// Per-request slices, in payload order.
    pub(super) parts: Vec<PreparedPart>,
    pub(super) params: crate::model::LayerParams,
    pub(super) c_out: usize,
    pub(super) h_o: usize,
    pub(super) w_o_p: usize,
    /// Telemetry normalization scales of one subtask of this round,
    /// summed over every coalesced payload — a batched conv reports one
    /// `exec_secs` for ALL payloads, so normalizing by the coalesced
    /// FLOPs/bytes keeps the per-FLOP shift-exp fits unbiased.
    pub(super) flops_per_task: f64,
    pub(super) bytes_per_task: f64,
}

impl PreparedRound {
    /// Flattened length of one request's decoded subtask output.
    pub(super) fn part_elems(&self) -> usize {
        self.c_out * self.h_o * self.w_o_p
    }
}

/// Decode results + remainder -> the layer's output tensor.
pub(super) fn assemble_output(
    pr: &PreparedRound,
    decoded: Vec<Vec<f32>>,
    remainder: Option<Tensor>,
    relu: bool,
) -> Result<Tensor> {
    let mut pieces: Vec<Tensor> = decoded
        .into_iter()
        .map(|flat| Tensor::from_flat(pr.c_out, pr.h_o, pr.w_o_p, flat))
        .collect::<Result<_>>()?;
    if let Some(rem) = remainder {
        pieces.push(rem);
    }
    let mut out = Tensor::concat_w(&pieces)?;
    out.add_bias_inplace(&pr.params.bias);
    if relu {
        out.relu_inplace();
    }
    Ok(out)
}

/// Seed a freshly built plan's per-layer schemes from the selector (the
/// `--scheme auto` start state, before any telemetry exists): each
/// distributed layer gets the scheme + split the selector predicts
/// cheapest under the base profile on an `n`-worker pool. The replanner
/// (`Replanner::replan_auto`) revisits these against fitted profiles.
fn seed_auto_plan(
    plan: &mut ModelPlan,
    selector: &SchemeSelector,
    profile: &SystemProfile,
    n_workers: usize,
) {
    for c in plan.convs.iter_mut().filter(|c| c.distributed) {
        let choice = selector.choose(&c.dims, profile, n_workers, c.k, None, 0);
        c.scheme = choice.kind;
        c.k = choice.k;
        c.est_distributed = choice.predicted;
    }
}

impl Master {
    /// Connect to `links` workers, load `model_name`, and plan splits.
    pub fn new(
        model_name: &str,
        config: MasterConfig,
        links: Vec<LinkPair>,
        provider: std::sync::Arc<dyn ConvProvider>,
    ) -> Result<Master> {
        anyhow::ensure!(!links.is_empty(), "need at least one worker");
        let model = zoo::model(model_name)?;
        let weights = WeightStore::generate(&model, config.weight_seed)?;
        let mut rng = Rng::new(config.seed);
        let selector = SchemeSelector::default();
        let mut plan = ModelPlan::build(
            &model,
            &config.profile,
            links.len(),
            config.policy,
            &mut rng,
        )?;
        if config.scheme == SchemeKind::Auto {
            seed_auto_plan(&mut plan, &selector, &config.profile, links.len());
        }

        // One reader thread per worker feeding a single channel.
        let (agg_tx, events) = mpsc::channel();
        let mut workers: BTreeMap<usize, WorkerLink> = BTreeMap::new();
        for (i, (tx, rx)) in links.into_iter().enumerate() {
            workers.insert(
                i,
                WorkerLink {
                    tx,
                    name: format!("worker-{i}"),
                    retiring: false,
                    last_hb_seq: 0,
                },
            );
            spawn_reader(i, rx, agg_tx.clone());
        }

        let n_workers = workers.len();
        let registry = CapacityRegistry::new(n_workers, config.telemetry);
        let replanner = Replanner::new(config.replan);
        let mut master = Master {
            model,
            model_name: model_name.to_string(),
            weights,
            plan,
            config,
            provider,
            workers,
            next_worker_id: Arc::new(AtomicUsize::new(n_workers)),
            events,
            event_tx: agg_tx,
            round: 0,
            rng,
            registry,
            replanner,
            selector,
            churn_rounds: Vec::new(),
            round_log: std::collections::BTreeMap::new(),
            hub: MetricsHub::new(),
        };
        master.setup_workers(model_name)?;
        master.refresh_pool_gauges();
        Ok(master)
    }

    /// An *elastic* master: starts with zero workers and admits them at
    /// runtime via [`Master::listen`]. `planned_workers` (≥ 1) sizes the
    /// initial split plan — once real workers join, the replanner
    /// (under `adaptive`) re-solves against the measured pool. Forces
    /// [`ExecMode::Pipelined`]: the engine's event loop is the only path
    /// that can react to membership churn mid-stream.
    pub fn new_elastic(
        model_name: &str,
        mut config: MasterConfig,
        planned_workers: usize,
        provider: std::sync::Arc<dyn ConvProvider>,
    ) -> Result<Master> {
        anyhow::ensure!(planned_workers >= 1, "planned_workers must be >= 1");
        config.mode = ExecMode::Pipelined;
        let model = zoo::model(model_name)?;
        let weights = WeightStore::generate(&model, config.weight_seed)?;
        let mut rng = Rng::new(config.seed);
        let selector = SchemeSelector::default();
        let mut plan = ModelPlan::build(
            &model,
            &config.profile,
            planned_workers,
            config.policy,
            &mut rng,
        )?;
        if config.scheme == SchemeKind::Auto {
            seed_auto_plan(&mut plan, &selector, &config.profile, planned_workers);
        }
        let (agg_tx, events) = mpsc::channel();
        let registry = CapacityRegistry::new(0, config.telemetry);
        let replanner = Replanner::new(config.replan);
        Ok(Master {
            model,
            model_name: model_name.to_string(),
            weights,
            plan,
            config,
            provider,
            workers: BTreeMap::new(),
            next_worker_id: Arc::new(AtomicUsize::new(0)),
            events,
            event_tx: agg_tx,
            round: 0,
            rng,
            registry,
            replanner,
            selector,
            churn_rounds: Vec::new(),
            round_log: std::collections::BTreeMap::new(),
            hub: MetricsHub::new(),
        })
    }

    /// Start accepting worker joins on `addr` (`"host:port"`; port 0
    /// picks a free one). Returns the bound address. Each connection
    /// runs the join handshake on its own thread, so a slow or hostile
    /// dialer never blocks other joiners; admitted workers surface as
    /// `MasterEvent::Joined` on the event channel, which the engine's
    /// run loop folds into the pool. Works on any master (elastic or
    /// fixed-seed) — ids continue past the initial pool.
    pub fn listen(&mut self, addr: &str) -> Result<std::net::SocketAddr> {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding membership listener on {addr}"))?;
        let local = listener.local_addr()?;
        let event_tx = self.event_tx.clone();
        let next_id = Arc::clone(&self.next_worker_id);
        let model = self.model_name.clone();
        let weight_seed = self.config.weight_seed;
        let heartbeat = self.config.heartbeat;
        std::thread::Builder::new()
            .name("membership-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    let stream = match stream {
                        Ok(s) => s,
                        Err(e) => {
                            log::warn!("membership accept failed: {e}");
                            continue;
                        }
                    };
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".into());
                    let event_tx = event_tx.clone();
                    let next_id = Arc::clone(&next_id);
                    let model = model.clone();
                    let spawned = std::thread::Builder::new()
                        .name(format!("join-{peer}"))
                        .spawn(move || {
                            if let Err(e) = handshake(
                                stream, event_tx, next_id, model, weight_seed, heartbeat,
                            ) {
                                log::warn!("join handshake with {peer} failed: {e:#}");
                            }
                        });
                    if let Err(e) = spawned {
                        log::error!("spawning join handshake thread: {e}");
                    }
                }
            })?;
        log::info!("master accepting worker joins on {local}");
        Ok(local)
    }

    pub(super) fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stable ids of current members still accepting new work (i.e. not
    /// retiring), ascending.
    pub(super) fn live_worker_ids(&self) -> Vec<usize> {
        self.workers
            .iter()
            .filter(|(_, w)| !w.retiring)
            .map(|(&id, _)| id)
            .collect()
    }

    /// Send a frame to one worker by stable id. Absent ids are a no-op
    /// (the worker was already evicted; the caller's redispatch path
    /// recovers the subtask). A send failure queues `LinkDown` instead
    /// of erroring: the event handler owns removal, keeping every
    /// membership transition on one code path.
    pub(super) fn send_to(&mut self, id: usize, frame: &[u8]) {
        let Some(w) = self.workers.get_mut(&id) else {
            return;
        };
        if let Err(e) = w.tx.send(frame) {
            log::warn!("worker {id}: send failed ({e:#}); scheduling link-down");
            let _ = self.event_tx.send(MasterEvent::LinkDown(id));
        }
    }

    /// Admit a joined worker into the pool + registry and invalidate the
    /// current plan's pool-size assumption.
    pub(super) fn admit_worker(
        &mut self,
        id: usize,
        name: String,
        tx: Box<dyn crate::transport::FrameTx>,
    ) {
        log::info!("worker {id} ({name}) admitted to the pool");
        self.workers.insert(
            id,
            WorkerLink {
                tx,
                name,
                retiring: false,
                last_hb_seq: 0,
            },
        );
        self.registry.admit(id);
        self.replanner.force();
        self.note_churn();
        if let Some(tr) = &self.config.trace {
            tr.pool_instant("joined", Some(id), Instant::now());
        }
        self.refresh_pool_gauges();
    }

    /// Fold one heartbeat into the worker's liveness state. The `seq` a
    /// worker beacons is strictly increasing on a healthy link; a beat
    /// at or below the last-seen seq is a replayed/stale beacon from a
    /// zombie half-open link and takes a strike (warn + the
    /// `cocoi_heartbeat_regressions_total` counter) instead of silently
    /// refreshing the liveness deadline's good name. Beats from unknown
    /// ids (evicted while the frame was in flight) are ignored.
    pub(super) fn note_heartbeat(&mut self, id: usize, seq: u64) {
        let Some(w) = self.workers.get_mut(&id) else {
            return;
        };
        if seq <= w.last_hb_seq {
            log::warn!(
                "worker {id} ({}): heartbeat seq regressed ({seq} <= {}) — \
                 stale beacon replay on a half-open link",
                w.name,
                w.last_hb_seq
            );
            self.hub.lock().gauges.hb_regressions += 1;
        } else {
            w.last_hb_seq = seq;
        }
    }

    /// Evict a worker whose link died. Idempotent (link-death events can
    /// double-fire: reader exit + send failure). Returns whether the
    /// worker was still a member.
    pub(super) fn drop_worker(&mut self, id: usize) -> bool {
        if self.workers.remove(&id).is_none() {
            return false;
        }
        log::warn!("worker {id}: link down; evicted from pool");
        self.registry.evict(id);
        self.replanner.force();
        self.note_churn();
        if let Some(tr) = &self.config.trace {
            tr.pool_instant("evicted", Some(id), Instant::now());
        }
        self.refresh_pool_gauges();
        true
    }

    /// Begin graceful retirement: the worker stops receiving new
    /// subtasks and is removed (with a Shutdown) once its in-flight ones
    /// drain — see [`Master::finalize_retiring`].
    pub fn retire_worker(&mut self, id: usize) {
        if let Some(w) = self.workers.get_mut(&id) {
            if !w.retiring {
                log::info!("worker {id} ({}) retiring: draining in-flight subtasks", w.name);
                w.retiring = true;
            }
        }
    }

    /// Finish retirement for every retiring worker not in `busy` (the
    /// set of ids with outstanding subtasks): send Shutdown, remove from
    /// the pool, log the transition.
    pub(super) fn finalize_retiring(&mut self, busy: &BTreeSet<usize>) {
        let done: Vec<usize> = self
            .workers
            .iter()
            .filter(|(id, w)| w.retiring && !busy.contains(id))
            .map(|(&id, _)| id)
            .collect();
        for id in done {
            if let Some(mut w) = self.workers.remove(&id) {
                let _ = w.tx.send(&ToWorker::Shutdown.encode());
                log::info!("worker {id} ({}) retired", w.name);
            }
            self.registry.retire(id);
            self.replanner.force();
            self.note_churn();
            if let Some(tr) = &self.config.trace {
                tr.pool_instant("retired", Some(id), Instant::now());
            }
        }
        self.refresh_pool_gauges();
    }

    /// Record one membership event at the current round and trim the
    /// window (see [`CHURN_WINDOW`]).
    fn note_churn(&mut self) {
        let now = self.round;
        self.churn_rounds.push(now);
        self.churn_rounds
            .retain(|&r| now.saturating_sub(r) <= CHURN_WINDOW);
    }

    /// Membership events within the last [`CHURN_WINDOW`] rounds — the
    /// selector's churn signal.
    pub(super) fn churn_events(&self) -> usize {
        self.churn_rounds
            .iter()
            .filter(|&&r| self.round.saturating_sub(r) <= CHURN_WINDOW)
            .count()
    }

    /// A sender into the master's event channel — the serving
    /// front-end's non-blocking submission path.
    pub(super) fn event_sender(&self) -> mpsc::Sender<MasterEvent> {
        self.event_tx.clone()
    }

    pub fn config(&self) -> &MasterConfig {
        &self.config
    }

    pub fn plan(&self) -> &ModelPlan {
        &self.plan
    }

    /// The live capacity registry (telemetry dumps, tests).
    pub fn registry(&self) -> &CapacityRegistry {
        &self.registry
    }

    /// A clone of the always-on metrics hub. The serving front-end grabs
    /// one before the master moves onto the engine thread, so `scrape()`
    /// reads the same histograms the engine records into.
    pub fn metrics_hub(&self) -> MetricsHub {
        self.hub.clone()
    }

    /// Mirror pool membership + round progress into the hub's gauges.
    pub(super) fn refresh_pool_gauges(&self) {
        let mut h = self.hub.lock();
        h.gauges.members = self.workers.len();
        h.gauges.healthy = if self.workers.is_empty() {
            0
        } else {
            self.registry.healthy_count().min(self.workers.len())
        };
        h.gauges.round = self.round;
        h.gauges.plan_switches = self.replanner.switches as u64;
    }

    /// Telemetry dump: fitted per-worker capacities, quarantine log,
    /// plan-swap count, and the per-layer k currently in force.
    pub fn telemetry_json(&self) -> Json {
        let plan: Vec<Json> = self
            .plan
            .convs
            .iter()
            .filter(|c| c.distributed)
            .map(|c| {
                Json::obj(vec![
                    ("layer", Json::Str(c.node_id.clone())),
                    ("k", Json::Num(c.k as f64)),
                    ("scheme", Json::Str(c.scheme.name().to_string())),
                ])
            })
            .collect();
        let members: Vec<Json> = self
            .workers
            .iter()
            .map(|(&id, w)| {
                Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("name", Json::Str(w.name.clone())),
                    ("retiring", Json::Bool(w.retiring)),
                ])
            })
            .collect();
        let count = |kind: EventKind| {
            self.registry.events().iter().filter(|e| e.kind == kind).count() as f64
        };
        // Per-tenant meters, each with its full sojourn histogram — the
        // scrape only carries a labelled p95 gauge per tenant (labelled
        // histograms would break the exposition's per-family bucket
        // checks), so the JSON dump is where whole distributions live.
        let hub = self.hub.snapshot();
        let tenants = Json::obj(
            hub.tenants
                .iter()
                .map(|(t, s)| {
                    (
                        t.as_str(),
                        Json::obj(vec![
                            ("submitted", Json::Num(s.submitted as f64)),
                            ("completed", Json::Num(s.completed as f64)),
                            ("quota_rejections", Json::Num(s.quota_rejections as f64)),
                            ("open", Json::Num(s.open as f64)),
                            ("sojourn", s.sojourn.to_json()),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("adaptive", Json::Bool(self.config.adaptive)),
            ("plan_switches", Json::Num(self.replanner.switches as f64)),
            ("hedges", Json::Num(count(EventKind::Hedged))),
            ("hedge_wins", Json::Num(count(EventKind::HedgeWon))),
            ("hedge_losses", Json::Num(count(EventKind::HedgeLost))),
            ("fallbacks", Json::Num(count(EventKind::LocalFallback))),
            (
                "heartbeat_regressions",
                Json::Num(hub.gauges.hb_regressions as f64),
            ),
            ("tenants", tenants),
            ("plan", Json::Arr(plan)),
            ("members", Json::Arr(members)),
            ("registry", self.registry.to_json()),
        ])
    }

    /// How long the hedge watchdog lets a subtask of `flops`/`bytes`
    /// scale stay outstanding on `worker` before speculating: the fitted
    /// `hedge_quantile` of the worker's service-time prediction, floored
    /// at [`HEDGE_FLOOR`] (also the delay when the worker is unfitted)
    /// and capped at `recv_timeout` so hedging always beats the old
    /// wedge diagnosis.
    pub(super) fn hedge_delay(&self, worker: usize, flops: f64, bytes: f64) -> Duration {
        let fitted = self
            .registry
            .service_quantile(worker, self.config.hedge_quantile, flops, bytes)
            .map(Duration::from_secs_f64);
        fitted
            .map_or(HEDGE_FLOOR, |d| d.max(HEDGE_FLOOR))
            .min(self.config.recv_timeout)
    }

    /// Master-local compute of one dispatched subtask — the decode
    /// fallback's workhorse. Decodes the round's cached dispatch frame
    /// and runs each payload through the master's own provider with the
    /// round's weights. Conv linearity means an *encoded* payload
    /// convolves to the corresponding encoded output, so the chunks feed
    /// the decoders exactly as a worker reply would — for every scheme,
    /// with no systematic-shard special-casing. Returns one flattened
    /// output chunk per coalesced payload, in payload order.
    pub(super) fn compute_task_locally(
        &self,
        pr: &PreparedRound,
        task_id: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let frame = pr.frames.get(task_id).with_context(|| {
            format!("local fallback: round {} has no task {task_id}", pr.round)
        })?;
        let order = match ToWorker::decode(frame)? {
            ToWorker::Work(order) => order,
            other => bail!("local fallback: cached frame for task {task_id} is {other:?}"),
        };
        let spec = order.spec();
        let mut chunks = Vec::with_capacity(order.payloads.len());
        for i in 0..order.payloads.len() {
            let input = order.input_tensor(i)?;
            let out = self.provider.conv(&spec, &input, &pr.params.weights)?;
            chunks.push(out.flatten());
        }
        Ok(chunks)
    }

    /// [`Master::compute_task_locally`] over several shards, at most
    /// `config.fallback_concurrency` at a time: frames decode on the
    /// caller's thread (cheap), the convolutions stride over scoped
    /// worker threads sharing the master's provider. Results come back
    /// in `task_ids` order. Bounding the fan-out keeps a worst-case
    /// fallback (every shard of a wide round missing) from saturating
    /// the host the engine's event loop runs on.
    pub(super) fn compute_tasks_locally(
        &self,
        pr: &PreparedRound,
        task_ids: &[usize],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let mut orders = Vec::with_capacity(task_ids.len());
        for &t in task_ids {
            let frame = pr.frames.get(t).with_context(|| {
                format!("local fallback: round {} has no task {t}", pr.round)
            })?;
            match ToWorker::decode(frame)? {
                ToWorker::Work(order) => orders.push(order),
                other => bail!("local fallback: cached frame for task {t} is {other:?}"),
            }
        }
        let provider: &dyn ConvProvider = &*self.provider;
        let weights = &pr.params.weights;
        let compute_one = move |order: &WorkOrder| -> Result<Vec<Vec<f32>>> {
            let spec = order.spec();
            let mut chunks = Vec::with_capacity(order.payloads.len());
            for i in 0..order.payloads.len() {
                let input = order.input_tensor(i)?;
                chunks.push(provider.conv(&spec, &input, weights)?.flatten());
            }
            Ok(chunks)
        };
        let cap = self.config.fallback_concurrency.max(1).min(orders.len());
        let mut merged: Vec<Option<Vec<Vec<f32>>>> =
            (0..orders.len()).map(|_| None).collect();
        if cap <= 1 {
            for (slot, order) in merged.iter_mut().zip(&orders) {
                *slot = Some(compute_one(order)?);
            }
        } else {
            // The master itself is not Sync (it owns an mpsc receiver),
            // so the lanes capture only the Sync pieces: the provider,
            // the weights, and the decoded orders.
            let lanes: Vec<Vec<(usize, Result<Vec<Vec<f32>>>)>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..cap)
                        .map(|lane| {
                            let orders = &orders;
                            let compute_one = &compute_one;
                            s.spawn(move || {
                                orders
                                    .iter()
                                    .enumerate()
                                    .skip(lane)
                                    .step_by(cap)
                                    .map(|(i, o)| (i, compute_one(o)))
                                    .collect()
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("fallback lane panicked"))
                        .collect()
                });
            for lane in lanes {
                for (i, r) in lane {
                    merged[i] = Some(r?);
                }
            }
        }
        Ok(merged
            .into_iter()
            .map(|m| m.expect("every fallback shard computed"))
            .collect())
    }

    /// The dispatch set for the upcoming round, by stable worker id:
    /// the registry's active workers under the adaptive policy, every
    /// pool member otherwise — minus retiring workers either way. Empty
    /// when no live workers exist (the elastic engine then parks staged
    /// requests until someone joins).
    pub(super) fn dispatch_targets(&mut self) -> Vec<usize> {
        let candidates = if self.config.adaptive {
            self.registry.active_workers(self.round + 1)
        } else {
            self.workers.keys().copied().collect()
        };
        candidates
            .into_iter()
            .filter(|id| self.workers.get(id).is_some_and(|w| !w.retiring))
            .collect()
    }

    /// Run a replan attempt if one is due (no-op unless adaptive).
    /// Under `--scheme auto` the attempt also re-ranks each layer's
    /// scheme (`Replanner::replan_auto`); fixed-scheme configs keep the
    /// k-only path.
    pub(super) fn maybe_replan(&mut self) {
        if !self.config.adaptive || !self.replanner.due(self.round) {
            return;
        }
        if self.config.scheme == SchemeKind::Auto {
            let churn = self.churn_events();
            self.replanner.replan_auto(
                &mut self.plan,
                &self.registry,
                &self.config.profile,
                self.round,
                &self.selector,
                churn,
            );
        } else {
            self.replanner.replan(
                &mut self.plan,
                &self.registry,
                &self.config.profile,
                self.round,
            );
        }
    }

    /// Predicted end-to-end service seconds of one request under the
    /// telemetry-fitted profile — the deadline-shedding estimate used by
    /// the serving engine. `None` unless the adaptive loop is on *and*
    /// the registry has at least one fitted worker: the base profile is
    /// calibrated to the paper's testbed, and its absolute scale on an
    /// unmeasured host would shed everything (or nothing) meaninglessly.
    pub fn predicted_service_secs(&self) -> Option<f64> {
        if !self.config.adaptive {
            return None;
        }
        if !self.registry.any_estimate() {
            return None;
        }
        let fitted = self.registry.fitted_profile(&self.config.profile);
        let n = self.registry.healthy_count().max(1);
        let mut total = 0.0;
        for c in &self.plan.convs {
            if c.distributed {
                let k = c.k.clamp(1, n.min(c.dims.w_o).max(1));
                total += crate::latency::approx::l_integer(&c.dims, &fitted, n, k);
            } else {
                total += fitted.local_conv_dist(c.dims.full_flops()).mean();
            }
        }
        Some(total)
    }

    /// Register a freshly dispatched round's telemetry bookkeeping; the
    /// bounded log keeps it past decode so *late* straggler replies are
    /// still ingested instead of dropped as stale.
    pub(super) fn log_round(
        &mut self,
        round: u64,
        flops_per_task: f64,
        bytes_per_task: f64,
        dispatched_at: Vec<Instant>,
    ) {
        self.round_log.insert(
            round,
            RoundTelemetry {
                flops_per_task,
                bytes_per_task,
                dispatched_at,
                done: false,
            },
        );
        // Evict oldest *done* rounds only: an in-flight round's entry is
        // load-bearing (re-dispatch timestamps, reply telemetry), so the
        // log may transiently exceed the cap on huge pipelined batches.
        while self.round_log.len() > ROUND_LOG_CAP {
            let oldest_done = self
                .round_log
                .iter()
                .find(|(_, rt)| rt.done)
                .map(|(r, _)| *r);
            match oldest_done {
                Some(r) => {
                    self.round_log.remove(&r);
                }
                None => break,
            }
        }
    }

    /// Mark a round decoded/finished: its log entry stays for late-reply
    /// telemetry but becomes eligible for eviction.
    pub(super) fn retire_round(&mut self, round: u64) {
        if let Some(rt) = self.round_log.get_mut(&round) {
            rt.done = true;
        }
    }

    /// The `k` actually used for a round dispatched to `n_targets`
    /// workers: under the adaptive policy a quarantine-shrunken pool
    /// keeps one parity shard (clamping k to n would yield MDS(n', n')
    /// with zero redundancy exactly when workers misbehave). The sim
    /// (`sim::adaptive`) mirrors this policy.
    pub(super) fn effective_k(&self, k_planned: usize, n_targets: usize) -> usize {
        if self.config.adaptive && n_targets > 1 {
            k_planned.min(n_targets - 1)
        } else {
            k_planned
        }
    }

    /// Resolve the scheme + split for the upcoming round of one layer.
    /// Fixed-scheme configs behave exactly as before: the configured
    /// scheme at [`Master::effective_k`]. Under [`SchemeKind::Auto`] the
    /// plan's per-layer base choice (seeded at build, revisited by
    /// `replan_auto`) is refined for *this* round: recent churn flips to
    /// rateless LT, and a request deadline becomes per-layer slack for
    /// the deadline-redundancy rule (the remaining time split evenly
    /// over the distributed layers still ahead of this one).
    pub(super) fn choose_scheme(
        &self,
        node_id: &str,
        k_planned: usize,
        n_targets: usize,
        deadline: Option<Instant>,
    ) -> (SchemeKind, usize) {
        if self.config.scheme != SchemeKind::Auto {
            return (self.config.scheme, self.effective_k(k_planned, n_targets));
        }
        let Some(c) = self.plan.conv(node_id) else {
            return (SchemeKind::Mds, self.effective_k(k_planned, n_targets));
        };
        let fitted = if self.config.adaptive && self.registry.any_estimate() {
            self.registry.fitted_profile(&self.config.profile)
        } else {
            self.config.profile
        };
        let slack = deadline.map(|d| {
            let idx = self
                .plan
                .convs
                .iter()
                .position(|p| p.node_id == node_id)
                .unwrap_or(0);
            let left = self.plan.convs[idx..]
                .iter()
                .filter(|p| p.distributed)
                .count()
                .max(1);
            d.saturating_duration_since(Instant::now()).as_secs_f64() / left as f64
        });
        let (kind, k) = self.selector.refine(
            c.scheme,
            k_planned,
            &c.dims,
            &fitted,
            n_targets,
            slack,
            self.churn_events(),
        );
        // The quarantine-shrunken-pool parity guard applies to the MDS
        // shape only: LT sizes its own symbol budget, and uncoded /
        // replication derive k from n inside `SchemeKind::make`.
        let k = match kind {
            SchemeKind::Mds => self.effective_k(k, n_targets),
            _ => k,
        };
        (kind, k)
    }

    /// Fold one successful subtask reply (current *or* stale) into the
    /// registry, using the round log's dispatch instant and the reader
    /// thread's arrival instant. Returns the per-task breakdown for the
    /// layer metrics when the round is known.
    pub(super) fn record_output(
        &mut self,
        worker: usize,
        round: u64,
        task_id: usize,
        arrival: Instant,
        exec_secs: f64,
    ) -> Option<WorkerPhase> {
        let rt = self.round_log.get(&round)?;
        let dispatched = *rt.dispatched_at.get(task_id)?;
        let elapsed = arrival.saturating_duration_since(dispatched).as_secs_f64();
        let transmission = (elapsed - exec_secs).max(0.0);
        self.registry.record_success(
            worker,
            rt.flops_per_task,
            rt.bytes_per_task,
            exec_secs,
            transmission,
            round,
        );
        Some(WorkerPhase {
            worker,
            task_id,
            transmission,
            execution: exec_secs,
        })
    }

    /// Fold one failure reply (current or stale) into the registry —
    /// only for rounds this master actually dispatched and still tracks,
    /// keeping success and failure accounting symmetric.
    pub(super) fn record_failed(&mut self, worker: usize, round: u64) {
        if self.round_log.contains_key(&round) {
            self.registry.record_failure(worker, round);
        }
    }

    fn setup_workers(&mut self, model_name: &str) -> Result<()> {
        let setup = ToWorker::Setup {
            model: model_name.to_string(),
            weight_seed: self.config.weight_seed,
        }
        .encode();
        for w in self.workers.values_mut() {
            w.tx.send(&setup)?;
        }
        let mut ready = 0;
        while ready < self.n_workers() {
            match self
                .events
                .recv_timeout(self.config.recv_timeout)
                .context("waiting for worker Ready")?
            {
                MasterEvent::Reply(_, FromWorker::Ready, _) => ready += 1,
                MasterEvent::Reply(w, FromWorker::Heartbeat { seq }, _) => {
                    self.note_heartbeat(w, seq)
                }
                MasterEvent::Reply(i, other, _) => {
                    bail!("worker {i}: unexpected {other:?} during setup")
                }
                MasterEvent::LinkDown(i) => {
                    bail!("worker {i}: link down during setup")
                }
                MasterEvent::Joined { .. } => {
                    bail!("runtime join before worker setup finished")
                }
                MasterEvent::Submit(_) | MasterEvent::Drain => {
                    bail!("serving event before worker setup finished")
                }
            }
        }
        Ok(())
    }

    /// Run a batch of inferences. [`ExecMode::RoundBarrier`] serves them
    /// one at a time (the comparison baseline); [`ExecMode::Pipelined`]
    /// multiplexes all of them over the worker pool by seeding the
    /// engine's admission queue and draining it (`engine::serve_stream`)
    /// — the same submit+wait path [`super::server::InferenceServer`]
    /// drives continuously.
    pub fn infer_batch(
        &mut self,
        inputs: &[Tensor],
    ) -> Result<Vec<(Tensor, InferenceMetrics)>> {
        // Degenerate batch: nothing to admit, nothing to dispatch — the
        // workers see no traffic at all.
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        match self.config.mode {
            ExecMode::RoundBarrier => inputs.iter().map(|i| self.infer_barrier(i)).collect(),
            ExecMode::Pipelined => self.infer_pipelined(inputs),
        }
    }

    /// Run one full inference (a single-request batch through either
    /// engine). Returns the network output and the per-layer latency
    /// breakdown.
    pub fn infer(&mut self, input: &Tensor) -> Result<(Tensor, InferenceMetrics)> {
        let mut out = self.infer_batch(std::slice::from_ref(input))?;
        Ok(out.pop().unwrap())
    }

    /// One blocking round-barrier inference (the paper's workflow).
    fn infer_barrier(&mut self, input: &Tensor) -> Result<(Tensor, InferenceMetrics)> {
        let t_start = Instant::now();
        let mut metrics = InferenceMetrics::default();
        let mut values: std::collections::BTreeMap<String, Tensor> = Default::default();
        values.insert("input".into(), input.clone());

        let nodes = self.model.nodes.clone();
        for node in &nodes {
            let fetched: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|i| values.get(i).cloned().context("missing value"))
                .collect::<Result<_>>()?;
            let out = match &node.op {
                Op::Conv { spec, relu } => {
                    let spec = *spec;
                    let relu = *relu;
                    let dist = self
                        .plan
                        .conv(&node.id)
                        .map(|c| (c.distributed, c.k))
                        .unwrap_or((false, 1));
                    if dist.0 {
                        let (t, lm) = self.run_distributed_conv(
                            &node.id,
                            &spec,
                            relu,
                            dist.1,
                            &fetched[0],
                        )?;
                        metrics.layers.push(lm);
                        t
                    } else {
                        self.run_local_node(node, &fetched, &mut metrics)?
                    }
                }
                _ => self.run_local_node(node, &fetched, &mut metrics)?,
            };
            values.insert(node.id.clone(), out);
        }
        metrics.total_seconds = t_start.elapsed().as_secs_f64();
        // Between requests: fold the round's telemetry into the plan.
        self.maybe_replan();
        let last = nodes.last().unwrap();
        Ok((values.remove(&last.id).unwrap(), metrics))
    }

    /// Execute one non-distributed node on the master: a local (type-2)
    /// conv with bias/activation, or any simple op. Shared by the
    /// round-barrier path and the pipelined engine so the two cannot
    /// diverge on local-layer semantics.
    pub(super) fn run_local_node(
        &self,
        node: &Node,
        fetched: &[Tensor],
        metrics: &mut InferenceMetrics,
    ) -> Result<Tensor> {
        match &node.op {
            Op::Conv { spec, relu } => {
                let t0 = Instant::now();
                let params = self.weights.get(&node.id)?.clone();
                let padded = fetched[0].pad(spec.pad);
                let mut t = self.provider.conv(spec, &padded, &params.weights)?;
                t.add_bias_inplace(&params.bias);
                if *relu {
                    t.relu_inplace();
                }
                metrics.layers.push(LayerMetrics {
                    node_id: node.id.clone(),
                    k: 1,
                    n_tasks: 0,
                    distributed: false,
                    t_local: t0.elapsed().as_secs_f64(),
                    ..Default::default()
                });
                Ok(t)
            }
            _ => {
                let refs: Vec<&Tensor> = fetched.iter().collect();
                execute_simple_op(node, &refs, &self.weights)
            }
        }
    }

    /// Split + encode one distributed layer into a [`PreparedRound`].
    /// `requests` is one `(id, input)` per coalesced request — all with
    /// identical input shapes (the engine only groups same-layer
    /// same-shape requests; the barrier path always passes one) — and
    /// `n_tasks` is the number of workers that will receive shards (the
    /// full pool, or the registry's active set under the adaptive
    /// policy) — the redundancy scheme is sized to it. One scheme
    /// instance encodes every request, and frame `i` interleaves each
    /// request's shard `i` as one multi-payload [`WorkOrder`].
    /// `scheme_kind` is the (already resolved — see
    /// [`Master::choose_scheme`]) redundancy scheme for this round;
    /// passing it per-round is what lets `--scheme auto` vary the code
    /// per layer and per request.
    pub(super) fn prepare_round(
        &mut self,
        requests: &[(u64, &Tensor)],
        node_id: &str,
        spec: &crate::conv::ConvSpec,
        scheme_kind: SchemeKind,
        k_planned: usize,
        n_tasks: usize,
    ) -> Result<PreparedRound> {
        anyhow::ensure!(!requests.is_empty(), "prepare_round with no requests");
        self.round += 1;
        let round = self.round;
        let n = n_tasks.max(1);
        let n_req = requests.len();

        // -- input splitting phase ------------------------------------
        let t0 = Instant::now();
        let padded: Vec<Tensor> = requests.iter().map(|(_, t)| t.pad(spec.pad)).collect();
        let scheme = scheme_kind.make(
            n,
            k_planned,
            spec.out_dim_padded(padded[0].w),
            self.rng.next_u64(),
        );
        let k = scheme.source_count();
        let plan = SplitPlan::new(spec, padded[0].w, k)?;
        let all_sources: Vec<Vec<Vec<f32>>> = padded
            .iter()
            .map(|p| {
                plan.in_ranges
                    .iter()
                    .map(|r| p.slice_w(r.start, r.end).flatten())
                    .collect()
            })
            .collect();
        let t_split = t0.elapsed().as_secs_f64() / n_req as f64;

        // -- encoding phase --------------------------------------------
        let t0 = Instant::now();
        // One scheme instance encodes every coalesced request, so shard
        // `i` of each carries the same coefficients and one decoder per
        // request recovers them from the same received-subtask set.
        let all_tasks: Vec<Vec<crate::coding::EncodedTask>> =
            all_sources.iter().map(|s| scheme.encode(s)).collect();
        let n_tasks_out = all_tasks[0].len();
        let h_i = padded[0].h;
        // Encode each dispatch frame exactly once (§Perf: the payload used
        // to be cloned into a WorkOrder and re-serialized per dispatch);
        // re-dispatch after a failure reuses the same frame bytes.
        let frames: Vec<Vec<u8>> = (0..n_tasks_out)
            .map(|t| {
                debug_assert!(all_tasks.iter().all(|ts| ts[t].id == all_tasks[0][t].id));
                ToWorker::Work(WorkOrder {
                    round,
                    task_id: all_tasks[0][t].id as u32,
                    node_id: node_id.to_string(),
                    c_in: spec.c_in as u32,
                    c_out: spec.c_out as u32,
                    k_w: spec.k_w as u32,
                    s_w: spec.s_w as u32,
                    h: h_i as u32,
                    w: plan.w_i_p as u32,
                    payloads: requests
                        .iter()
                        .zip(&all_tasks)
                        .map(|(&(id, _), tasks)| super::messages::WorkPayload {
                            request: id,
                            data: tasks[t].payload.clone(),
                        })
                        .collect(),
                })
                .encode()
            })
            .collect();
        let t_encode = t0.elapsed().as_secs_f64() / n_req as f64;
        {
            let mut h = self.hub.lock();
            h.t_split.record(t_split);
            h.t_encode.record(t_encode);
            h.gauges.round = round;
        }

        let parts: Vec<PreparedPart> = requests
            .iter()
            .zip(&padded)
            .map(|(&(id, _), p)| PreparedPart {
                request: id,
                remainder_input: match (plan.remainder_in, plan.remainder_out) {
                    (Some(ri), Some(_)) => Some(p.slice_w(ri.start, ri.end)),
                    _ => None,
                },
                lm: LayerMetrics {
                    node_id: node_id.to_string(),
                    distributed: true,
                    k,
                    n_tasks: n_tasks_out,
                    // Split/encode wall time divided evenly across the
                    // coalesced requests so per-request sums stay honest.
                    t_split,
                    t_encode,
                    ..Default::default()
                },
            })
            .collect();
        let params = self.weights.get(node_id)?.clone();
        let h_o = spec.out_dim_padded(h_i);
        // Telemetry normalization: one subtask convolves a w_i_p-wide
        // piece into a w_o_p-wide output (eqs. 9–11 at the concrete
        // integer piece widths) — times the number of coalesced payloads
        // it carries.
        let flops_per_task = 2.0
            * (spec.c_out * h_o) as f64
            * plan.w_o_p as f64
            * (spec.c_in * spec.k_w * spec.k_w) as f64
            * n_req as f64;
        let bytes_per_task = (4.0 * (spec.c_in * h_i * plan.w_i_p) as f64
            + 4.0 * (spec.c_out * h_o * plan.w_o_p) as f64)
            * n_req as f64;
        Ok(PreparedRound {
            round,
            scheme,
            frames,
            parts,
            params,
            c_out: spec.c_out,
            h_o,
            w_o_p: plan.w_o_p,
            flops_per_task,
            bytes_per_task,
        })
    }

    /// One coded-computation round (paper Fig. 1 workflow), blocking
    /// until this layer decodes — the round-barrier execution path.
    fn run_distributed_conv(
        &mut self,
        node_id: &str,
        spec: &crate::conv::ConvSpec,
        relu: bool,
        k_planned: usize,
        input: &Tensor,
    ) -> Result<(Tensor, LayerMetrics)> {
        // Dispatch set (stable worker ids): the live pool, or — adaptive
        // — the registry's active workers (quarantined ones appear only
        // when their probe is due).
        let mut targets = self.dispatch_targets();
        anyhow::ensure!(
            !targets.is_empty(),
            "layer {node_id}: no live workers to dispatch to"
        );
        let (scheme_kind, k_eff) = self.choose_scheme(node_id, k_planned, targets.len(), None);
        let mut pr = self.prepare_round(
            &[(0, input)],
            node_id,
            spec,
            scheme_kind,
            k_eff,
            targets.len(),
        )?;
        let round = pr.round;
        let mut lm = std::mem::take(&mut pr.parts[0].lm);

        // -- execution phase (dispatch + master-local remainder) -------
        let t0 = Instant::now();
        let mut dispatched_at: Vec<Instant> = Vec::with_capacity(pr.frames.len());
        // task id -> the worker currently holding it, so link death can
        // recover exactly the dead worker's subtasks.
        let mut assigned: Vec<usize> = Vec::with_capacity(pr.frames.len());
        for i in 0..pr.frames.len() {
            dispatched_at.push(Instant::now());
            assigned.push(targets[i % targets.len()]);
        }
        for (frame, &target) in pr.frames.iter().zip(&assigned) {
            self.send_to(target, frame);
        }
        self.log_round(round, pr.flops_per_task, pr.bytes_per_task, dispatched_at);

        // Master-local remainder piece (footnote 2) while workers run.
        let t_local0 = Instant::now();
        let remainder: Option<Tensor> = match &pr.parts[0].remainder_input {
            Some(piece) => Some(self.provider.conv(spec, piece, &pr.params.weights)?),
            None => None,
        };
        let mut t_local = t_local0.elapsed().as_secs_f64();

        // -- collect until decodable -----------------------------------
        let mut decoder = pr.scheme.decoder();
        let mut received: Vec<usize> = Vec::new();
        let mut outstanding: Vec<usize> = (0..pr.frames.len()).collect();
        let mut next_redispatch_worker = 0usize;
        while !decoder.ready() {
            if outstanding.is_empty() {
                bail!(
                    "layer {node_id}: no outstanding subtasks but decoder needs more \
                     (received {} of {})",
                    received.len(),
                    pr.scheme.min_completions()
                );
            }
            let (wid, msg, arrival) = match self
                .events
                .recv_timeout(self.config.recv_timeout)
                .with_context(|| format!("layer {node_id}: timed out waiting for workers"))?
            {
                MasterEvent::Reply(wid, msg, arrival) => (wid, msg, arrival),
                // A server never drives the barrier path directly; if a
                // submission ever reaches it, refuse it rather than hang
                // the caller's handle.
                MasterEvent::Submit(req) => {
                    req.reject();
                    continue;
                }
                MasterEvent::Drain => continue,
                // A runtime joiner is admitted immediately; it starts
                // receiving shards from the next round (this round's
                // frames are already sized to the old dispatch set).
                MasterEvent::Joined { id, name, tx } => {
                    self.admit_worker(id, name, tx);
                    continue;
                }
                MasterEvent::LinkDown(wid) => {
                    if !self.drop_worker(wid) {
                        continue; // double-fire: already handled
                    }
                    targets.retain(|&t| t != wid);
                    // Recover the dead worker's outstanding subtasks.
                    let orphaned: Vec<usize> = outstanding
                        .iter()
                        .copied()
                        .filter(|&t| assigned[t] == wid)
                        .collect();
                    for task_id in orphaned {
                        outstanding.retain(|&t| t != task_id);
                        lm.failures += 1;
                        if pr.scheme.needs_redispatch(task_id, &received, &outstanding) {
                            anyhow::ensure!(
                                !targets.is_empty(),
                                "layer {node_id}: all workers lost mid-round"
                            );
                            let ti = next_redispatch_worker % targets.len();
                            next_redispatch_worker = ti + 1;
                            let target = targets[ti];
                            if let Some(rt) = self.round_log.get_mut(&round) {
                                rt.dispatched_at[task_id] = Instant::now();
                            }
                            self.send_to(target, &pr.frames[task_id]);
                            assigned[task_id] = target;
                            outstanding.push(task_id);
                            lm.redispatches += 1;
                            log::debug!(
                                "layer {node_id}: task {task_id} orphaned by dead \
                                 worker {wid}, re-dispatched to {target}"
                            );
                        }
                    }
                    continue;
                }
            };
            match msg {
                FromWorker::Output {
                    round: r,
                    task_id,
                    exec_secs,
                    data,
                    ..
                } => {
                    let task_id = task_id as usize;
                    // Telemetry first, even for stale rounds: a late
                    // straggler reply is exactly the sample the capacity
                    // estimator must not lose.
                    let wp = self.record_output(wid, r, task_id, arrival, exec_secs);
                    if r != round {
                        lm.stale_results += 1;
                        continue;
                    }
                    outstanding.retain(|&t| t != task_id);
                    if let Some(wp) = wp {
                        lm.per_worker.push(wp);
                    }
                    if decoder.add(task_id, data) {
                        received.push(task_id);
                        break;
                    }
                    received.push(task_id);
                }
                FromWorker::Failed { round: r, task_id } => {
                    self.record_failed(wid, r);
                    if r != round {
                        lm.stale_results += 1;
                        continue;
                    }
                    let task_id = task_id as usize;
                    lm.failures += 1;
                    outstanding.retain(|&t| t != task_id);
                    if pr.scheme.needs_redispatch(task_id, &received, &outstanding) {
                        if lm.redispatches > 4 * pr.frames.len() {
                            bail!("layer {node_id}: re-dispatch storm; giving up");
                        }
                        anyhow::ensure!(
                            !targets.is_empty(),
                            "layer {node_id}: all workers lost mid-round"
                        );
                        // Round-robin (over the round's dispatch set) to
                        // a different worker than the one that failed.
                        let mut ti = next_redispatch_worker % targets.len();
                        if targets[ti] == wid && targets.len() > 1 {
                            ti = (ti + 1) % targets.len();
                        }
                        next_redispatch_worker = ti + 1;
                        let target = targets[ti];
                        if let Some(rt) = self.round_log.get_mut(&round) {
                            rt.dispatched_at[task_id] = Instant::now();
                        }
                        self.send_to(target, &pr.frames[task_id]);
                        assigned[task_id] = target;
                        outstanding.push(task_id);
                        lm.redispatches += 1;
                        log::debug!(
                            "layer {node_id}: task {task_id} failed on worker {wid}, \
                             re-dispatched to {target}"
                        );
                    }
                }
                FromWorker::Skipped { .. } => {
                    // Only the pipelined engine cancels rounds; a skip
                    // reaching the barrier path is a leftover from an
                    // earlier pipelined batch on this master.
                    lm.stale_results += 1;
                }
                // Liveness beacon from a TCP joiner: the read timeout on
                // its link polices silence; here we only check the seq
                // for stale-beacon replay.
                FromWorker::Heartbeat { seq } => self.note_heartbeat(wid, seq),
                // Graceful retirement: stop assigning new shards; the
                // worker is finalized once this round's decode clears.
                FromWorker::Retire => {
                    self.retire_worker(wid);
                    targets.retain(|&t| t != wid);
                    anyhow::ensure!(
                        !targets.is_empty(),
                        "layer {node_id}: every worker retired mid-round"
                    );
                }
                FromWorker::Join { .. } => {
                    bail!("worker {wid}: Join on an established link")
                }
                FromWorker::Ready => bail!("unexpected Ready from worker {wid}"),
            }
        }
        lm.t_workers = t0.elapsed().as_secs_f64() - t_local;

        // -- decoding phase ---------------------------------------------
        let t0 = Instant::now();
        let decoded = decoder.decode()?;
        lm.t_decode = t0.elapsed().as_secs_f64();

        // -- reassembly + bias/activation (master-local) -----------------
        let t0 = Instant::now();
        let out = assemble_output(&pr, decoded, remainder, relu)?;
        t_local += t0.elapsed().as_secs_f64();
        lm.t_local = t_local;
        {
            let mut h = self.hub.lock();
            h.t_workers.record(lm.t_workers);
            h.t_decode.record(lm.t_decode);
            h.t_local.record(lm.t_local);
        }
        self.retire_round(round);
        // Barrier mode runs one round at a time, so once this round
        // decodes no retiring worker holds work we still need — any
        // straggler reply of this round would be stale anyway.
        self.finalize_retiring(&BTreeSet::new());
        Ok((out, lm))
    }

    /// Graceful shutdown (workers exit their loops).
    pub fn shutdown(mut self) {
        let frame = ToWorker::Shutdown.encode();
        for w in self.workers.values_mut() {
            let _ = w.tx.send(&frame);
        }
    }
}
