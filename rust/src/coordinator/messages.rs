//! Wire messages between master and workers, with the hand-rolled binary
//! codec (see `transport::codec`).

use anyhow::{bail, Result};

use crate::conv::{ConvSpec, Tensor};
use crate::transport::codec::{Decoder, Encoder};

/// Membership wire-protocol version, checked during the join handshake
/// so an old worker binary can't silently join a newer master.
pub const PROTOCOL_VERSION: u32 = 1;

/// Master -> worker.
#[derive(Clone, Debug, PartialEq)]
pub enum ToWorker {
    /// Load a model: the worker regenerates the deterministic weights
    /// (the paper's "preloaded weights") and stands by.
    Setup { model: String, weight_seed: u64 },
    /// Execute one encoded conv subtask.
    Work(WorkOrder),
    /// Drop any queued (not yet started) subtasks of this round: the
    /// master has already decoded it, so straggler results are useless.
    Cancel { round: u64 },
    Shutdown,
    /// Handshake accept: the master assigns a stable worker id and tells
    /// the joiner which model to prepack and how often to heartbeat.
    JoinAck {
        worker_id: u64,
        model: String,
        weight_seed: u64,
        heartbeat_ms: u32,
    },
    /// Handshake reject (wrong protocol / model mismatch).
    JoinReject { reason: String },
}

/// One request's slice of a (possibly coalesced) subtask: which
/// inference request it belongs to, and that request's encoded input
/// partition. The request tag is the full engine id (u64 — a long-lived
/// server overflows u32) and is diagnostic-only on the worker.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkPayload {
    pub request: u64,
    pub data: Vec<f32>,
}

/// One encoded subtask: the (already padded, already encoded) input
/// partition(s) plus which layer's preloaded weights to convolve them
/// with. A *coalesced* order carries the same-index shard of several
/// concurrent requests at the same layer (`payloads.len() > 1`): the
/// worker runs them through one prepacked-weight pass whose im2col/GEMM
/// N dimension spans all payloads, and replies with the concatenated
/// outputs in payload order.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkOrder {
    /// Coded-computation round (one per distributed layer execution,
    /// unique across concurrent requests *and* shared by every request
    /// coalesced into it); the master routes results and discards stale
    /// ones by this id.
    pub round: u64,
    /// Scheme-local subtask id.
    pub task_id: u32,
    /// Conv node whose weights to use.
    pub node_id: String,
    /// Conv geometry (pad is irrelevant: input arrives pre-padded).
    pub c_in: u32,
    pub c_out: u32,
    pub k_w: u32,
    pub s_w: u32,
    /// Input partition shape; identical for every payload (coalescing
    /// only merges same-layer same-split shards).
    pub h: u32,
    pub w: u32,
    /// One entry per coalesced request, each `c_in * h * w` long.
    pub payloads: Vec<WorkPayload>,
}

impl WorkOrder {
    /// Single-request order (the uncoalesced common case).
    pub fn single(
        round: u64,
        request: u64,
        task_id: u32,
        node_id: String,
        c_in: u32,
        c_out: u32,
        k_w: u32,
        s_w: u32,
        h: u32,
        w: u32,
        data: Vec<f32>,
    ) -> WorkOrder {
        WorkOrder {
            round,
            task_id,
            node_id,
            c_in,
            c_out,
            k_w,
            s_w,
            h,
            w,
            payloads: vec![WorkPayload { request, data }],
        }
    }

    /// Exact byte length of this order's encoded `ToWorker::Work` frame
    /// (tag + fixed header + node id + payload vector). Lets the
    /// master's dispatch encode allocate each frame exactly once with
    /// zero slack — these frames are cached for re-dispatch, so
    /// over-reservation would stay alive for the whole round.
    pub fn encoded_len(&self) -> usize {
        1 + 8 + 4
            + (4 + self.node_id.len())
            + 6 * 4
            + 4
            + self
                .payloads
                .iter()
                .map(|p| 8 + (8 + 4 * p.data.len()))
                .sum::<usize>()
    }

    /// Expected element count of each payload (`c_in * h * w`).
    pub fn payload_elems(&self) -> usize {
        self.c_in as usize * self.h as usize * self.w as usize
    }

    pub fn spec(&self) -> ConvSpec {
        ConvSpec::new(
            self.c_in as usize,
            self.c_out as usize,
            self.k_w as usize,
            self.s_w as usize,
            0,
        )
    }

    /// Payload `i` as an input tensor.
    pub fn input_tensor(&self, i: usize) -> Result<Tensor> {
        Tensor::from_vec(
            self.c_in as usize,
            self.h as usize,
            self.w as usize,
            self.payloads[i].data.clone(),
        )
    }
}

/// Worker -> master.
#[derive(Clone, Debug, PartialEq)]
pub enum FromWorker {
    /// Setup done.
    Ready,
    /// Subtask output (flattened CHW). For a coalesced order, `data` is
    /// the per-request outputs concatenated in payload order (each
    /// `c*h*w` long) and `c`/`h`/`w` describe ONE request's slice — the
    /// master fans the reply back out per request. `exec_secs` is the
    /// worker-measured execution wall time of the whole (batched) conv
    /// (plus any chronic-straggler stretch, but not transmission): the
    /// master subtracts it from its dispatch→reply measurement to
    /// decompose the sample into transmission vs execution for the
    /// telemetry registry, normalizing by the order's *coalesced* FLOPs
    /// so batched samples don't bias the per-FLOP fits.
    Output {
        round: u64,
        task_id: u32,
        c: u32,
        h: u32,
        w: u32,
        exec_secs: f64,
        data: Vec<f32>,
    },
    /// The worker failed this subtask and signals the master (paper §IV-C
    /// uncoded failure model).
    Failed { round: u64, task_id: u32 },
    /// The worker dropped this queued subtask because its round was
    /// cancelled. Every dispatched subtask yields exactly one reply
    /// (Output / Failed / Skipped), which is what keeps the master's
    /// per-worker load accounting exact.
    Skipped { round: u64, task_id: u32 },
    /// Membership handshake: a worker announcing itself to a running
    /// cluster. `model` is a hint ("" = whatever the master serves);
    /// a non-empty mismatch is rejected.
    Join {
        name: String,
        protocol: u32,
        model: String,
    },
    /// Periodic liveness beacon from a joined worker. The master's
    /// per-worker read timeout (heartbeat deadline) is what evicts a
    /// silent peer; `seq` is diagnostic.
    Heartbeat { seq: u64 },
    /// Graceful retirement request: stop assigning me new subtasks,
    /// let my in-flight ones drain, then drop me from the pool.
    Retire,
}

const TAG_SETUP: u8 = 1;
const TAG_WORK: u8 = 2;
const TAG_SHUTDOWN: u8 = 3;
const TAG_CANCEL: u8 = 4;
const TAG_JOIN_ACK: u8 = 5;
const TAG_JOIN_REJECT: u8 = 6;
const TAG_READY: u8 = 11;
const TAG_OUTPUT: u8 = 12;
const TAG_FAILED: u8 = 13;
const TAG_SKIPPED: u8 = 14;
const TAG_JOIN: u8 = 15;
const TAG_HEARTBEAT: u8 = 16;
const TAG_RETIRE: u8 = 17;

impl ToWorker {
    pub fn encode(&self) -> Vec<u8> {
        // Work frames (the dispatch hot path) get an exact-capacity
        // buffer; the other variants are tiny.
        let mut e = match self {
            ToWorker::Work(w) => Encoder::with_capacity(w.encoded_len()),
            _ => Encoder::new(),
        };
        match self {
            ToWorker::Setup { model, weight_seed } => {
                e.u8(TAG_SETUP).str(model).u64(*weight_seed);
            }
            ToWorker::Work(w) => {
                e.u8(TAG_WORK)
                    .u64(w.round)
                    .u32(w.task_id)
                    .str(&w.node_id)
                    .u32(w.c_in)
                    .u32(w.c_out)
                    .u32(w.k_w)
                    .u32(w.s_w)
                    .u32(w.h)
                    .u32(w.w)
                    .u32(w.payloads.len() as u32);
                for p in &w.payloads {
                    e.u64(p.request).f32s(&p.data);
                }
            }
            ToWorker::Cancel { round } => {
                e.u8(TAG_CANCEL).u64(*round);
            }
            ToWorker::Shutdown => {
                e.u8(TAG_SHUTDOWN);
            }
            ToWorker::JoinAck {
                worker_id,
                model,
                weight_seed,
                heartbeat_ms,
            } => {
                e.u8(TAG_JOIN_ACK)
                    .u64(*worker_id)
                    .str(model)
                    .u64(*weight_seed)
                    .u32(*heartbeat_ms);
            }
            ToWorker::JoinReject { reason } => {
                e.u8(TAG_JOIN_REJECT).str(reason);
            }
        }
        if let ToWorker::Work(w) = self {
            debug_assert_eq!(e.len(), w.encoded_len(), "encoded_len out of sync");
        }
        e.finish()
    }

    pub fn decode(frame: &[u8]) -> Result<ToWorker> {
        let mut d = Decoder::new(frame);
        let msg = match d.u8()? {
            TAG_SETUP => ToWorker::Setup {
                model: d.str()?,
                weight_seed: d.u64()?,
            },
            TAG_WORK => {
                let round = d.u64()?;
                let task_id = d.u32()?;
                let node_id = d.str()?;
                let (c_in, c_out) = (d.u32()?, d.u32()?);
                let (k_w, s_w) = (d.u32()?, d.u32()?);
                let (h, w) = (d.u32()?, d.u32()?);
                let n_payloads = d.u32()? as usize;
                // Each payload is ≥ 16 wire bytes (request tag + length
                // prefix); bound the claimed count by the remaining
                // frame before allocating.
                anyhow::ensure!(
                    n_payloads >= 1 && n_payloads <= d.remaining() / 16,
                    "implausible payload count {n_payloads}"
                );
                let mut payloads = Vec::with_capacity(n_payloads);
                for _ in 0..n_payloads {
                    payloads.push(WorkPayload {
                        request: d.u64()?,
                        data: d.f32s()?,
                    });
                }
                ToWorker::Work(WorkOrder {
                    round,
                    task_id,
                    node_id,
                    c_in,
                    c_out,
                    k_w,
                    s_w,
                    h,
                    w,
                    payloads,
                })
            }
            TAG_CANCEL => ToWorker::Cancel { round: d.u64()? },
            TAG_SHUTDOWN => ToWorker::Shutdown,
            TAG_JOIN_ACK => ToWorker::JoinAck {
                worker_id: d.u64()?,
                model: d.str()?,
                weight_seed: d.u64()?,
                heartbeat_ms: d.u32()?,
            },
            TAG_JOIN_REJECT => ToWorker::JoinReject { reason: d.str()? },
            t => bail!("unknown ToWorker tag {t}"),
        };
        d.done()?;
        Ok(msg)
    }
}

impl FromWorker {
    pub fn encode(&self) -> Vec<u8> {
        // Output frames (the reply hot path) get an exact-capacity
        // buffer: tag(1) + round(8) + task(4) + c/h/w(12) + exec(8) +
        // len(8) + data.
        let mut e = match self {
            FromWorker::Output { data, .. } => Encoder::with_capacity(41 + 4 * data.len()),
            _ => Encoder::new(),
        };
        match self {
            FromWorker::Ready => {
                e.u8(TAG_READY);
            }
            FromWorker::Output {
                round,
                task_id,
                c,
                h,
                w,
                exec_secs,
                data,
            } => {
                e.u8(TAG_OUTPUT)
                    .u64(*round)
                    .u32(*task_id)
                    .u32(*c)
                    .u32(*h)
                    .u32(*w)
                    .f64(*exec_secs)
                    .f32s(data);
            }
            FromWorker::Failed { round, task_id } => {
                e.u8(TAG_FAILED).u64(*round).u32(*task_id);
            }
            FromWorker::Skipped { round, task_id } => {
                e.u8(TAG_SKIPPED).u64(*round).u32(*task_id);
            }
            FromWorker::Join {
                name,
                protocol,
                model,
            } => {
                e.u8(TAG_JOIN).str(name).u32(*protocol).str(model);
            }
            FromWorker::Heartbeat { seq } => {
                e.u8(TAG_HEARTBEAT).u64(*seq);
            }
            FromWorker::Retire => {
                e.u8(TAG_RETIRE);
            }
        }
        e.finish()
    }

    pub fn decode(frame: &[u8]) -> Result<FromWorker> {
        let mut d = Decoder::new(frame);
        let msg = match d.u8()? {
            TAG_READY => FromWorker::Ready,
            TAG_OUTPUT => FromWorker::Output {
                round: d.u64()?,
                task_id: d.u32()?,
                c: d.u32()?,
                h: d.u32()?,
                w: d.u32()?,
                exec_secs: d.f64()?,
                data: d.f32s()?,
            },
            TAG_FAILED => FromWorker::Failed {
                round: d.u64()?,
                task_id: d.u32()?,
            },
            TAG_SKIPPED => FromWorker::Skipped {
                round: d.u64()?,
                task_id: d.u32()?,
            },
            TAG_JOIN => FromWorker::Join {
                name: d.str()?,
                protocol: d.u32()?,
                model: d.str()?,
            },
            TAG_HEARTBEAT => FromWorker::Heartbeat { seq: d.u64()? },
            TAG_RETIRE => FromWorker::Retire,
            t => bail!("unknown FromWorker tag {t}"),
        };
        d.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn message_roundtrips() {
        prop::check("message codec roundtrip", 48, |rng| {
            // 1..=3 payloads: the single-request case and coalesced ones.
            let n_payloads = 1 + rng.below(3);
            let len = rng.below(500);
            let payloads: Vec<WorkPayload> = (0..n_payloads)
                .map(|_| WorkPayload {
                    request: rng.next_u64(),
                    data: (0..len).map(|_| rng.uniform() as f32).collect(),
                })
                .collect();
            let order = WorkOrder {
                round: rng.next_u64(),
                task_id: rng.below(100) as u32,
                node_id: format!("conv{}", rng.below(20)),
                c_in: 1 + rng.below(64) as u32,
                c_out: 1 + rng.below(64) as u32,
                k_w: 3,
                s_w: 1 + rng.below(2) as u32,
                h: 4,
                w: 5,
                payloads,
            };
            for msg in [
                ToWorker::Setup {
                    model: "tinyvgg".into(),
                    weight_seed: rng.next_u64(),
                },
                ToWorker::Work(order),
                ToWorker::Cancel { round: rng.next_u64() },
                ToWorker::Shutdown,
                ToWorker::JoinAck {
                    worker_id: rng.next_u64(),
                    model: "tinyvgg".into(),
                    weight_seed: rng.next_u64(),
                    heartbeat_ms: rng.below(60_000) as u32,
                },
                ToWorker::JoinReject {
                    reason: "protocol mismatch".into(),
                },
            ] {
                assert_eq!(ToWorker::decode(&msg.encode()).unwrap(), msg);
            }
            for msg in [
                FromWorker::Ready,
                FromWorker::Join {
                    name: format!("edge-{}", rng.below(100)),
                    protocol: PROTOCOL_VERSION,
                    model: String::new(),
                },
                FromWorker::Heartbeat { seq: rng.next_u64() },
                FromWorker::Retire,
                FromWorker::Output {
                    round: 3,
                    task_id: 1,
                    c: 2,
                    h: 3,
                    w: 4,
                    exec_secs: 0.125,
                    data: vec![1.0; 24],
                },
                FromWorker::Failed { round: 9, task_id: 7 },
                FromWorker::Skipped { round: 11, task_id: 3 },
            ] {
                assert_eq!(FromWorker::decode(&msg.encode()).unwrap(), msg);
            }
        });
    }

    #[test]
    fn garbage_rejected() {
        assert!(ToWorker::decode(&[99, 1, 2]).is_err());
        assert!(FromWorker::decode(&[]).is_err());
        // Work frame claiming zero / implausibly many payloads.
        for claimed in [0u32, u32::MAX] {
            let mut e = Encoder::new();
            e.u8(TAG_WORK)
                .u64(1)
                .u32(0)
                .str("conv1")
                .u32(1)
                .u32(1)
                .u32(1)
                .u32(1)
                .u32(1)
                .u32(1)
                .u32(claimed);
            assert!(ToWorker::decode(&e.finish()).is_err(), "count {claimed}");
        }
    }

    #[test]
    fn work_frame_length_is_exact() {
        let mut order = WorkOrder::single(3, 1, 2, "conv_x".into(), 3, 8, 3, 1, 6, 7, vec![0.5; 97]);
        let frame = ToWorker::Work(order.clone()).encode();
        assert_eq!(frame.len(), order.encoded_len());
        // Coalesced frames too: the length formula must track payloads.
        order.payloads.push(WorkPayload {
            request: u64::MAX, // full-width tag survives the wire
            data: vec![0.25; 97],
        });
        let frame = ToWorker::Work(order.clone()).encode();
        assert_eq!(frame.len(), order.encoded_len());
        assert_eq!(ToWorker::decode(&frame).unwrap(), ToWorker::Work(order));
        // Output frames likewise match their reserved capacity formula.
        let reply = FromWorker::Output {
            round: 3,
            task_id: 2,
            c: 8,
            h: 4,
            w: 5,
            exec_secs: 1.5,
            data: vec![1.0; 160],
        };
        assert_eq!(reply.encode().len(), 41 + 4 * 160);
    }
}
