//! Fault injection: the programmable stand-in for the paper's testbed
//! manipulations (§V): slept devices / extra WiFi delay (scenario 1),
//! per-round worker failures (scenario 2), and a chronic straggler
//! (scenario 3).

use std::collections::HashSet;

use crate::util::Rng;

/// Per-worker fault configuration, applied inside the worker loop.
#[derive(Clone, Debug, Default)]
pub struct WorkerFaults {
    /// Mean (seconds) of an exponential extra delay added before sending
    /// each result — scenario 1's `Exp(λ_tr · T̄_tr)` transmission delay.
    pub extra_send_delay_mean: f64,
    /// Rounds in which this worker fails its subtask and signals the
    /// master (scenario 2/3). A failed round costs the worker the time it
    /// takes to *notice* (modelled as half the compute it completed).
    pub fail_rounds: HashSet<u64>,
    /// Compute slowdown factor (1.0 = nominal). The paper's
    /// "high-probability straggler" runs at ≈1.68× (85.2 s vs 50.8 s).
    pub cmp_slowdown: f64,
    /// Rounds in which this worker *stalls*: it accepts the subtask and
    /// then silently never replies — no Output, no Failed — while its
    /// link (and heartbeat, on TCP workers) stays alive. The black-hole
    /// failure mode only a watchdog can catch; neither the clean-failure
    /// re-dispatch path nor heartbeat eviction ever fires.
    pub stall_rounds: HashSet<u64>,
}

impl WorkerFaults {
    pub fn none() -> WorkerFaults {
        WorkerFaults {
            cmp_slowdown: 1.0,
            ..Default::default()
        }
    }

    pub fn with_send_delay(mean: f64) -> WorkerFaults {
        WorkerFaults {
            extra_send_delay_mean: mean,
            cmp_slowdown: 1.0,
            ..Default::default()
        }
    }

    pub fn fails_in(mut self, rounds: impl IntoIterator<Item = u64>) -> WorkerFaults {
        self.fail_rounds.extend(rounds);
        self
    }

    pub fn slowdown(mut self, factor: f64) -> WorkerFaults {
        self.cmp_slowdown = factor;
        self
    }

    pub fn stalls_in(mut self, rounds: impl IntoIterator<Item = u64>) -> WorkerFaults {
        self.stall_rounds.extend(rounds);
        self
    }

    /// Sample this round's extra send delay.
    pub fn sample_send_delay(&self, rng: &mut Rng) -> f64 {
        if self.extra_send_delay_mean <= 0.0 {
            0.0
        } else {
            rng.exponential(1.0 / self.extra_send_delay_mean)
        }
    }

    pub fn fails(&self, round: u64) -> bool {
        self.fail_rounds.contains(&round)
    }

    pub fn stalls(&self, round: u64) -> bool {
        self.stall_rounds.contains(&round)
    }
}

/// Build per-worker fault plans for the three scenarios of §V.
pub struct ScenarioFaults;

impl ScenarioFaults {
    /// Scenario 1: every worker gets exponential extra transmission delay
    /// with mean `lambda_tr * mean_tr_seconds`.
    pub fn straggling(n: usize, lambda_tr: f64, mean_tr_seconds: f64) -> Vec<WorkerFaults> {
        (0..n)
            .map(|_| WorkerFaults::with_send_delay(lambda_tr * mean_tr_seconds))
            .collect()
    }

    /// Scenario 2: `n_f` distinct workers fail in each of `rounds` rounds
    /// (fresh draw per round).
    pub fn failures(n: usize, n_f: usize, rounds: u64, rng: &mut Rng) -> Vec<WorkerFaults> {
        let mut faults: Vec<WorkerFaults> = (0..n).map(|_| WorkerFaults::none()).collect();
        for round in 0..rounds {
            for w in rng.sample_distinct(n, n_f.min(n)) {
                faults[w].fail_rounds.insert(round);
            }
        }
        faults
    }

    /// Scenario 3: scenario 2 plus worker 0 as a chronic ~1.68× straggler.
    pub fn failures_plus_straggler(
        n: usize,
        n_f: usize,
        rounds: u64,
        rng: &mut Rng,
    ) -> Vec<WorkerFaults> {
        let mut faults = Self::failures(n, n_f, rounds, rng);
        faults[0].cmp_slowdown = 1.68;
        faults
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario2_fails_exactly_nf_per_round() {
        let mut rng = Rng::new(4);
        let faults = ScenarioFaults::failures(10, 2, 5, &mut rng);
        for round in 0..5 {
            let failing = faults.iter().filter(|f| f.fails(round)).count();
            assert_eq!(failing, 2, "round {round}");
        }
    }

    #[test]
    fn send_delay_mean_close() {
        let f = WorkerFaults::with_send_delay(0.02);
        let mut rng = Rng::new(5);
        let m: f64 = (0..20_000).map(|_| f.sample_send_delay(&mut rng)).sum::<f64>() / 20_000.0;
        assert!((m - 0.02).abs() < 0.002, "m={m}");
        assert_eq!(WorkerFaults::none().sample_send_delay(&mut rng), 0.0);
    }

    #[test]
    fn stall_rounds_are_independent_of_fail_rounds() {
        let f = WorkerFaults::none().stalls_in([2, 5]).fails_in([3]);
        assert!(f.stalls(2) && f.stalls(5) && !f.stalls(3));
        assert!(f.fails(3) && !f.fails(2));
        assert!(!WorkerFaults::none().stalls(0));
    }

    #[test]
    fn scenario3_has_chronic_straggler() {
        let mut rng = Rng::new(6);
        let faults = ScenarioFaults::failures_plus_straggler(4, 1, 3, &mut rng);
        assert!(faults[0].cmp_slowdown > 1.5);
        assert!(faults[1..].iter().all(|f| f.cmp_slowdown == 1.0));
    }
}
