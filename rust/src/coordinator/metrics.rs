//! Per-phase latency metrics: the observable the whole paper is about.

use crate::util::json::Json;

/// Per-subtask timing decomposition: which worker executed it, how long
/// the device computed (worker-measured), and how long the rest of the
/// dispatch→reply path took (transmission + queueing, master-measured).
/// This is the *same* sample the telemetry registry ingests, so the
/// metrics JSON and the capacity estimator report one source of truth.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkerPhase {
    pub worker: usize,
    pub task_id: usize,
    /// Dispatch→reply minus execution (seconds).
    pub transmission: f64,
    /// Worker-measured execution (seconds).
    pub execution: f64,
}

impl WorkerPhase {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("worker", Json::Num(self.worker as f64)),
            ("task_id", Json::Num(self.task_id as f64)),
            ("transmission", Json::Num(self.transmission)),
            ("execution", Json::Num(self.execution)),
        ])
    }
}

/// Wall-clock breakdown of one distributed layer execution (Fig. 4's
/// stacked bars: master enc/dec vs worker transmission+execution).
#[derive(Clone, Debug, Default)]
pub struct LayerMetrics {
    pub node_id: String,
    pub k: usize,
    pub n_tasks: usize,
    pub distributed: bool,
    /// Seconds per phase.
    pub t_split: f64,
    pub t_encode: f64,
    /// Dispatch -> k-th useful result received (the `T^w_{n:k}` analogue).
    pub t_workers: f64,
    pub t_decode: f64,
    /// Master-local work: remainder piece + bias/activation (+ the whole
    /// layer when `!distributed`).
    pub t_local: f64,
    pub failures: usize,
    pub redispatches: usize,
    pub stale_results: usize,
    /// Straggler subtasks cancelled after the round decoded (pipelined
    /// engine only; the round-barrier path lets them finish as stale).
    pub cancelled: usize,
    /// Watchdog hedges: subtasks speculatively re-dispatched after
    /// exceeding their fitted completion quantile (first result wins).
    pub hedges: usize,
    /// Shards the master computed locally to complete the decode when
    /// the pool could not deliver them (`--local-fallback`).
    pub fallbacks: usize,
    /// Per-subtask worker breakdown (one entry per useful reply), in
    /// arrival order.
    pub per_worker: Vec<WorkerPhase>,
}

impl LayerMetrics {
    pub fn total(&self) -> f64 {
        self.t_split + self.t_encode + self.t_workers + self.t_decode + self.t_local
    }

    /// Master coding share (the paper's 2–9% encode/decode overhead).
    pub fn coding_share(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            (self.t_encode + self.t_decode) / self.total()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node_id", Json::Str(self.node_id.clone())),
            ("k", Json::Num(self.k as f64)),
            ("n_tasks", Json::Num(self.n_tasks as f64)),
            ("distributed", Json::Bool(self.distributed)),
            ("t_split", Json::Num(self.t_split)),
            ("t_encode", Json::Num(self.t_encode)),
            ("t_workers", Json::Num(self.t_workers)),
            ("t_decode", Json::Num(self.t_decode)),
            ("t_local", Json::Num(self.t_local)),
            ("failures", Json::Num(self.failures as f64)),
            ("redispatches", Json::Num(self.redispatches as f64)),
            ("stale_results", Json::Num(self.stale_results as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("hedges", Json::Num(self.hedges as f64)),
            ("fallbacks", Json::Num(self.fallbacks as f64)),
            (
                "per_worker",
                Json::Arr(self.per_worker.iter().map(|w| w.to_json()).collect()),
            ),
        ])
    }
}

/// Whole-inference metrics.
#[derive(Clone, Debug, Default)]
pub struct InferenceMetrics {
    pub layers: Vec<LayerMetrics>,
    /// End-to-end wall time (includes type-2 layers).
    pub total_seconds: f64,
}

impl InferenceMetrics {
    pub fn distributed_layer_seconds(&self) -> f64 {
        self.layers.iter().filter(|l| l.distributed).map(|l| l.total()).sum()
    }

    pub fn coding_seconds(&self) -> f64 {
        self.layers.iter().map(|l| l.t_encode + l.t_decode).sum()
    }

    pub fn failures(&self) -> usize {
        self.layers.iter().map(|l| l.failures).sum()
    }

    pub fn redispatches(&self) -> usize {
        self.layers.iter().map(|l| l.redispatches).sum()
    }

    pub fn cancelled(&self) -> usize {
        self.layers.iter().map(|l| l.cancelled).sum()
    }

    pub fn hedges(&self) -> usize {
        self.layers.iter().map(|l| l.hedges).sum()
    }

    pub fn fallbacks(&self) -> usize {
        self.layers.iter().map(|l| l.fallbacks).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_seconds", Json::Num(self.total_seconds)),
            (
                "layers",
                Json::Arr(self.layers.iter().map(|l| l.to_json()).collect()),
            ),
        ])
    }

    /// A compact table for examples/CLI output.
    pub fn table(&self) -> String {
        let mut s = String::from(
            "layer        k  dist   split    enc     workers  dec     local    total\n",
        );
        for l in &self.layers {
            s.push_str(&format!(
                "{:<12} {:<2} {:<5} {:>7.1} {:>7.1} {:>8.1} {:>7.1} {:>7.1} {:>8.1}  (ms)\n",
                l.node_id,
                l.k,
                l.distributed,
                l.t_split * 1e3,
                l.t_encode * 1e3,
                l.t_workers * 1e3,
                l.t_decode * 1e3,
                l.t_local * 1e3,
                l.total() * 1e3,
            ));
        }
        s.push_str(&format!("total: {:.1} ms\n", self.total_seconds * 1e3));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_and_totals() {
        let l = LayerMetrics {
            node_id: "conv2".into(),
            k: 4,
            n_tasks: 6,
            distributed: true,
            t_split: 0.01,
            t_encode: 0.02,
            t_workers: 0.9,
            t_decode: 0.03,
            t_local: 0.04,
            stale_results: 3,
            ..Default::default()
        };
        assert!((l.total() - 1.0).abs() < 1e-12);
        assert!((l.coding_share() - 0.05).abs() < 1e-12);
        // Every maintained counter must survive the JSON emit —
        // `stale_results` used to be silently dropped here.
        assert_eq!(l.to_json().req_f64("stale_results").unwrap(), 3.0);
        let m = InferenceMetrics {
            layers: vec![l],
            total_seconds: 1.2,
        };
        assert!((m.coding_seconds() - 0.05).abs() < 1e-12);
        assert!(m.table().contains("conv2"));
        assert!(m.to_json().to_string_compact().contains("t_encode"));
        assert!(m.to_json().to_string_compact().contains("stale_results"));
    }

    #[test]
    fn per_worker_breakdown_in_json() {
        let l = LayerMetrics {
            node_id: "conv3".into(),
            per_worker: vec![
                WorkerPhase { worker: 1, task_id: 0, transmission: 0.02, execution: 0.4 },
                WorkerPhase { worker: 0, task_id: 1, transmission: 0.03, execution: 0.5 },
            ],
            ..Default::default()
        };
        let j = l.to_json();
        assert_eq!(j.req_f64("stale_results").unwrap(), 0.0);
        let pw = j.get("per_worker").as_arr().unwrap();
        assert_eq!(pw.len(), 2);
        assert_eq!(pw[0].req_f64("worker").unwrap(), 1.0);
        assert!((pw[1].req_f64("execution").unwrap() - 0.5).abs() < 1e-12);
        assert!((pw[0].req_f64("transmission").unwrap() - 0.02).abs() < 1e-12);
    }
}
