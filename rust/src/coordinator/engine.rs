//! The pipelined execution engine — a continuous, admission-driven run
//! loop.
//!
//! The round-barrier path (`Master::infer`) dispatches layer ℓ, blocks
//! until it decodes, then starts layer ℓ+1 — workers sit idle while the
//! master decodes/re-encodes, and exactly one request is served at a
//! time. This engine removes both stalls and, since the serving API
//! redesign, no longer needs the full request list up front:
//!
//! * requests are *admitted* between event-loop iterations: the loop
//!   blocks on the master's single event channel, which multiplexes
//!   worker replies with [`MasterEvent::Submit`] from the serving
//!   front-end ([`super::server::InferenceServer`]);
//! * admitted requests wait in per-tenant queues served by **deficit
//!   round robin** over the configured tenant weights
//!   (`MasterConfig::tenant_weights`), with **(priority, deadline,
//!   submission order)** EDF ordering inside each tenant's turn — a
//!   single tenant (the default) reduces exactly to the old global
//!   order — and start when a concurrency slot frees up
//!   (`StreamOptions::max_concurrent`);
//! * requests whose deadline has expired, or whose predicted completion
//!   (from the telemetry-fitted profile, `--adaptive`) misses it, are
//!   shed at dispatch time instead of served late;
//! * several in-flight requests advance through the model graph
//!   independently; a request reaching a distributed conv *stages* its
//!   round, and staged rounds are flushed together after the loop has
//!   drained every already-queued event — so requests that become ready
//!   at the same moment are visible to one flush;
//! * at flush time, staged same-layer same-shape rounds are **coalesced**
//!   (up to `MasterConfig::coalesce` requests): their same-index shards
//!   merge into one multi-payload [`WorkOrder`] and a worker runs one
//!   prepacked-weight pass whose GEMM N dimension spans every request —
//!   the per-dispatch fixed costs (wire framing, im2col, queueing) are
//!   paid once per *batch* instead of once per request. One reply fans
//!   back out into per-request decoders; requests coalesced at layer ℓ
//!   finish ℓ together and naturally re-coalesce at ℓ+1;
//! * a coalesced dispatch goes to the *least-loaded* workers and yields
//!   back to the event loop;
//! * the moment a round has its first `k` results, its outstanding
//!   straggler subtasks are cancelled ([`ToWorker::Cancel`]) so the
//!   per-worker queues (see `coordinator::worker`) drop them and free
//!   capacity for the next wave;
//! * `maybe_replan` runs after every finished round, so the adaptive
//!   plan tracks the *live* arrival stream rather than batch boundaries
//!   — and runs again the moment a worker joins, so a request admitted
//!   against a small pool picks the joiner up at its next layer;
//! * a **reliability layer** guarantees every admitted request
//!   completes: a watchdog folded into the event wait *hedges* subtasks
//!   that exceed their holder's fitted completion quantile
//!   (`MasterConfig::hedge_quantile`; first reply wins, the loser is
//!   cancelled), failure re-dispatches draw from a bounded per-round
//!   budget (`MasterConfig::retry_budget`) with per-worker exponential
//!   backoff instead of erroring on a storm, and when the pool cannot
//!   deliver the missing shards at all — collapse to zero mid-round,
//!   budget exhausted, deadline about to expire — the master computes
//!   them *locally* through its own provider and finishes the decode
//!   (`MasterConfig::local_fallback`; conv linearity makes an encoded
//!   payload convolve to the matching encoded output, so this works for
//!   every scheme).
//!
//! `Master::infer_batch` is a thin wrapper: it seeds the admission queue
//! with the whole batch and drains it ([`StreamOptions::draining`]), so
//! the batch path and the serving path cannot diverge.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coding;
use crate::conv::Tensor;
use crate::model::{Node, Op};
use crate::telemetry::EventKind;

use super::fair::{self, DrrQueue, DEFAULT_TENANT};
use super::master::{assemble_output, Master, MasterEvent, PreparedRound, SchemeKind};
use super::messages::{FromWorker, ToWorker};
use super::metrics::{InferenceMetrics, LayerMetrics, WorkerPhase};
use super::server::ServeError;

/// One admitted request, as the engine sees it.
pub(super) struct EngineRequest {
    pub(super) id: u64,
    pub(super) input: Tensor,
    /// Larger = more urgent (the dispatch-order key ahead of the
    /// deadline).
    pub(super) priority: u8,
    pub(super) deadline: Option<Instant>,
    /// Tenant the request bills to: keys its DRR admission queue and
    /// the per-tenant metrics row.
    pub(super) tenant: String,
    /// When the caller handed the request over (server submit / batch
    /// seed). Queue-wait and sojourn measure from this stamp.
    pub(super) submitted_at: Instant,
}

/// Where terminal request outcomes go: the batch wrapper collects them
/// into a vector, the serving front-end routes them to per-request
/// handles and keeps the admission accounting.
pub(super) trait EngineSink {
    /// Register a server submission (stash its reply channel) and hand
    /// back the engine-facing request.
    fn accept(&mut self, req: super::server::ServerRequest) -> EngineRequest;
    /// Deliver a terminal outcome for request `id`.
    fn deliver(&mut self, id: u64, result: Result<(Tensor, InferenceMetrics), ServeError>);
}

/// Run-loop options for [`Master::serve_stream`].
pub(super) struct StreamOptions {
    /// Max requests advancing concurrently (0 = unlimited). Admitted
    /// requests beyond it wait in the (priority, deadline, id) queue.
    pub(super) max_concurrent: usize,
    /// Start in draining mode: serve the seeded requests, accept no
    /// submissions, return when everything delivered (the `infer_batch`
    /// path). A live server starts `false` and flips on
    /// [`MasterEvent::Drain`].
    pub(super) draining: bool,
}

/// Admission-queue entry: a newtype whose `Ord` ranks the *most urgent*
/// request greatest (the heap is a max-heap): higher priority first,
/// then earlier deadline (`None` = no deadline = last), then lower id
/// (submission order).
struct Pending {
    req: EngineRequest,
}

impl Pending {
    fn new(req: EngineRequest) -> Pending {
        Pending { req }
    }
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> Ordering {
        self.req
            .priority
            .cmp(&other.req.priority)
            .then_with(|| match (self.req.deadline, other.req.deadline) {
                (Some(a), Some(b)) => b.cmp(&a),
                (Some(_), None) => Ordering::Greater,
                (None, Some(_)) => Ordering::Less,
                (None, None) => Ordering::Equal,
            })
            .then_with(|| other.req.id.cmp(&self.req.id))
    }
}

/// One request's progress through the model graph.
struct RequestState {
    values: BTreeMap<String, Tensor>,
    /// Next node to execute (all earlier nodes are in `values`).
    node_idx: usize,
    metrics: InferenceMetrics,
    t_start: Instant,
    /// Carried past admission so in-flight rounds can clamp their hedge
    /// and fallback timers: a tight-deadline request speculates *early*
    /// instead of being served late.
    deadline: Option<Instant>,
    /// Tenant id, for the per-tenant sojourn/completion meters.
    tenant: String,
    /// Submission stamp (sojourn = delivery − submitted_at).
    submitted_at: Instant,
    /// Root span id of this request's trace tree (`None` = tracing off).
    root_span: Option<u64>,
}

impl RequestState {
    fn new(
        input: Tensor,
        deadline: Option<Instant>,
        tenant: String,
        submitted_at: Instant,
        root_span: Option<u64>,
    ) -> RequestState {
        let mut values = BTreeMap::new();
        values.insert("input".to_string(), input);
        RequestState {
            values,
            node_idx: 0,
            metrics: InferenceMetrics::default(),
            t_start: Instant::now(),
            deadline,
            tenant,
            submitted_at,
            root_span,
        }
    }
}

/// One request's slice of an in-flight round: its own decoder (fed the
/// request's chunk of every batched reply), remainder piece, and layer
/// metrics.
struct ActivePart {
    request: u64,
    decoder: Box<dyn coding::Decoder>,
    remainder: Option<Tensor>,
    lm: LayerMetrics,
    /// This part's open `round` span in its request's trace tree
    /// (`None` = tracing off).
    span: Option<u64>,
}

/// One in-flight coded round: a distributed conv of one *or several
/// coalesced* requests whose subtasks are out on the pool. All parts
/// share the round's subtask set — every reply carries every part's
/// chunk — so their decoders become ready at the same completion and
/// the whole batch finishes together.
struct ActiveRound {
    relu: bool,
    pr: PreparedRound,
    /// Per-request slices, in payload order.
    parts: Vec<ActivePart>,
    received: Vec<usize>,
    outstanding: Vec<usize>,
    /// task id -> *primary* worker holding it (for cancel accounting).
    /// A hedged task has additional live copies in `extra`.
    assigned: Vec<usize>,
    /// task id -> extra hedge holders racing the primary. Absent for the
    /// (overwhelmingly common) unhedged task.
    extra: HashMap<usize, Vec<usize>>,
    /// Extra dispatches this round has consumed — failure re-dispatches,
    /// orphan recoveries, and hedges — against the per-round budget
    /// `retry_budget * frames.len()`. Keyed on the round itself, not on
    /// part 0's metrics: with coalesced rounds every part's
    /// `lm.redispatches` counter moves per event, so metrics are the
    /// wrong place to meter a budget.
    spent_retries: usize,
    /// Earliest deadline among the coalesced requests: hedge/fallback
    /// timers never fire later than this.
    deadline: Option<Instant>,
    /// The round's dispatch set (re-dispatch stays inside it).
    targets: Vec<usize>,
    t_dispatch: Instant,
    /// Master-local seconds already spent (remainder convs, all parts).
    t_local: f64,
    /// (task, worker) → open `subtask` span id in the *lead* request's
    /// trace tree. Empty when tracing is off. Hedge/retry dispatches add
    /// entries; replies (and cancels at round finish) close them.
    task_spans: HashMap<(usize, usize), u64>,
}

impl ActiveRound {
    /// Does `wid` hold a live copy of task `t`?
    fn holds(&self, t: usize, wid: usize) -> bool {
        self.assigned[t] == wid || self.extra.get(&t).is_some_and(|v| v.contains(&wid))
    }

    /// Remove `wid` from task `t`'s holder set, promoting a hedge copy
    /// to primary when the primary is the one lost. Returns `true` when
    /// NO live copy of `t` remains — the task is genuinely orphaned and
    /// needs recovery.
    fn drop_holder(&mut self, t: usize, wid: usize) -> bool {
        if self.assigned[t] != wid {
            if let Some(v) = self.extra.get_mut(&t) {
                v.retain(|&w| w != wid);
                if v.is_empty() {
                    self.extra.remove(&t);
                }
            }
            return false;
        }
        match self.extra.get_mut(&t).and_then(|v| v.pop()) {
            Some(promoted) => {
                if self.extra.get(&t).is_some_and(|v| v.is_empty()) {
                    self.extra.remove(&t);
                }
                self.assigned[t] = promoted;
                false
            }
            None => true,
        }
    }

    /// Resolve a hedge race: `winner` delivered task `t`. Clears the
    /// holder bookkeeping and returns the losing holders (possibly
    /// empty) so the caller can cancel them.
    fn resolve_race(&mut self, t: usize, winner: usize) -> Vec<usize> {
        let mut losers = self.extra.remove(&t).unwrap_or_default();
        if self.assigned[t] != winner {
            losers.push(self.assigned[t]);
            self.assigned[t] = winner;
        }
        losers.retain(|&w| w != winner);
        losers
    }

    /// Every live holder of task `t`, clearing the hedge bookkeeping
    /// (used when the master takes the task over locally).
    fn take_holders(&mut self, t: usize) -> Vec<usize> {
        let mut holders = self.extra.remove(&t).unwrap_or_default();
        if !holders.contains(&self.assigned[t]) {
            holders.push(self.assigned[t]);
        }
        holders
    }

    /// Is another extra dispatch (re-dispatch or hedge) within budget?
    fn retry_allowed(&self, budget_per_task: usize) -> bool {
        self.spent_retries < budget_per_task * self.pr.frames.len()
    }
}

/// Per-worker re-dispatch backoff: each strike (a `Failed` reply, or a
/// hedge fired against the worker) doubles the period during which the
/// recovery paths prefer other workers. A successful reply clears it.
/// Dispatch *placement* of fresh rounds is unaffected — redundancy
/// already covers first-dispatch risk; backoff only keeps retries from
/// hammering a worker that just demonstrated trouble.
#[derive(Clone, Copy, Debug, Default)]
struct WorkerBackoff {
    strikes: u32,
    eligible_at: Option<Instant>,
}

/// Base delay of the first strike; doubles per strike up to
/// [`BACKOFF_CAP`].
const BACKOFF_BASE: Duration = Duration::from_millis(100);
const BACKOFF_CAP: Duration = Duration::from_secs(10);

fn note_strike(backoff: &mut BTreeMap<usize, WorkerBackoff>, wid: usize, now: Instant) {
    let b = backoff.entry(wid).or_default();
    b.strikes = (b.strikes + 1).min(16);
    let delay = BACKOFF_BASE
        .saturating_mul(1u32 << (b.strikes - 1).min(10))
        .min(BACKOFF_CAP);
    b.eligible_at = Some(now + delay);
}

fn is_eligible(backoff: &BTreeMap<usize, WorkerBackoff>, wid: usize, now: Instant) -> bool {
    backoff
        .get(&wid)
        .and_then(|b| b.eligible_at)
        .map_or(true, |t| t <= now)
}

/// Least-loaded worker among `candidates`, lowest id on ties; avoids
/// `avoid` when there is a choice (re-dispatch should not go back to the
/// failing worker). `load` is keyed by stable worker id — a candidate
/// with no entry (just admitted) counts as idle.
fn pick_worker(
    load: &BTreeMap<usize, usize>,
    candidates: &[usize],
    avoid: Option<usize>,
) -> usize {
    let mut best = usize::MAX;
    let mut best_w = candidates[0];
    for &w in candidates {
        if Some(w) == avoid && candidates.len() > 1 {
            continue;
        }
        let l = load.get(&w).copied().unwrap_or(0);
        if l < best {
            best = l;
            best_w = w;
        }
    }
    best_w
}

/// [`pick_worker`] restricted to workers whose backoff has lapsed; when
/// every candidate is backing off, recovery still has to land somewhere,
/// so the filter degrades to the plain least-loaded pick.
fn pick_recovery_target(
    load: &BTreeMap<usize, usize>,
    backoff: &BTreeMap<usize, WorkerBackoff>,
    candidates: &[usize],
    avoid: Option<usize>,
    now: Instant,
) -> usize {
    let eligible: Vec<usize> = candidates
        .iter()
        .copied()
        .filter(|&w| is_eligible(backoff, w, now))
        .collect();
    if eligible.is_empty() {
        pick_worker(load, candidates, avoid)
    } else {
        pick_worker(load, &eligible, avoid)
    }
}

/// Collects the batch wrapper's outcomes by submission index.
struct BatchSink {
    out: Vec<Option<Result<(Tensor, InferenceMetrics), ServeError>>>,
}

impl EngineSink for BatchSink {
    fn accept(&mut self, _req: super::server::ServerRequest) -> EngineRequest {
        unreachable!("batch mode starts draining; nothing can be submitted")
    }

    fn deliver(&mut self, id: u64, result: Result<(Tensor, InferenceMetrics), ServeError>) {
        self.out[id as usize] = Some(result);
    }
}

impl Master {
    /// Pipelined batch inference: seed the admission queue with every
    /// input, drain it, return results in input order.
    pub(super) fn infer_pipelined(
        &mut self,
        inputs: &[Tensor],
    ) -> Result<Vec<(Tensor, InferenceMetrics)>> {
        debug_assert!(!inputs.is_empty(), "infer_batch guards the empty case");
        let seed: Vec<EngineRequest> = inputs
            .iter()
            .enumerate()
            .map(|(i, input)| EngineRequest {
                id: i as u64,
                input: input.clone(),
                priority: 0,
                deadline: None,
                tenant: DEFAULT_TENANT.to_string(),
                submitted_at: Instant::now(),
            })
            .collect();
        let mut sink = BatchSink {
            out: (0..inputs.len()).map(|_| None).collect(),
        };
        self.serve_stream(
            seed,
            StreamOptions {
                max_concurrent: 0,
                draining: true,
            },
            &mut sink,
        )?;
        sink.out
            .into_iter()
            .enumerate()
            .map(|(i, o)| {
                match o.with_context(|| format!("request {i} was never delivered"))? {
                    Ok(pair) => Ok(pair),
                    Err(e) => bail!("request {i}: {e}"),
                }
            })
            .collect()
    }

    /// Should a request with this deadline be shed instead of started?
    fn shed_decision(&self, deadline: Option<Instant>) -> Option<ServeError> {
        let d = deadline?;
        let remaining = d.saturating_duration_since(Instant::now()).as_secs_f64();
        if remaining <= 0.0 {
            // Expired in the queue: serving it late helps nobody.
            return Some(ServeError::DeadlineShed {
                predicted_secs: 0.0,
                remaining_secs: 0.0,
            });
        }
        if let Some(predicted) = self.predicted_service_secs() {
            if predicted > remaining {
                return Some(ServeError::DeadlineShed {
                    predicted_secs: predicted,
                    remaining_secs: remaining,
                });
            }
        }
        None
    }

    /// The engine's continuous run loop: admit from the (priority,
    /// deadline, id) queue up to the concurrency limit, block on the
    /// event channel, advance requests as replies arrive, replan between
    /// rounds, exit when draining and empty. Both `infer_batch`
    /// (pre-seeded, draining) and the serving front-end (live
    /// submissions) run through here.
    ///
    /// Requests that reach a distributed conv are *staged*, and the loop
    /// flushes the staging buffer only after draining every
    /// already-queued event — admissions that arrive in one burst, and
    /// coalesced batches that finish a layer together, therefore meet in
    /// the same flush and merge into coalesced rounds
    /// (`MasterConfig::coalesce`).
    pub(super) fn serve_stream(
        &mut self,
        seed: Vec<EngineRequest>,
        opts: StreamOptions,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        let nodes = self.model.nodes.clone();
        // Outstanding-reply charge per *stable worker id*. Seeded from
        // the current membership; joins insert, evictions remove.
        let mut worker_load: BTreeMap<usize, usize> =
            self.workers.keys().map(|&w| (w, 0)).collect();
        let mut rounds: HashMap<u64, ActiveRound> = HashMap::new();
        let mut active: BTreeMap<u64, RequestState> = BTreeMap::new();
        // Admission order: DRR across weighted tenant queues, EDF
        // (priority, deadline, id — the `Pending` Ord) inside each
        // tenant's turn. With one tenant at weight 1 — the default —
        // the pop sequence is exactly the old global heap's.
        let mut pending: DrrQueue<Pending> = DrrQueue::new(&self.config.tenant_weights);
        for req in seed {
            let tenant = req.tenant.clone();
            pending.push(&tenant, Pending::new(req));
        }
        let mut staged: Vec<u64> = Vec::new();
        let mut backoff: BTreeMap<usize, WorkerBackoff> = BTreeMap::new();
        let mut draining = opts.draining;
        // The reliability watchdog runs whenever either of its two
        // mechanisms is on; with both off the loop keeps the original
        // fail-fast recv_timeout behavior.
        let watchdog = self.config.hedge_quantile > 0.0 || self.config.local_fallback;
        // Trace-sampling counter (`--trace-sample N`): admissions are
        // numbered in admission order and one in every N gets a span
        // tree. A sampled-out request's `root_span` stays `None`, which
        // every per-request emit site already gates on — it allocates
        // zero spans end to end.
        let mut trace_seq: u64 = 0;

        loop {
            // -- admission: start the most urgent pending requests ----
            while !pending.is_empty()
                && (opts.max_concurrent == 0 || active.len() < opts.max_concurrent)
            {
                let req = pending.pop().unwrap().req;
                let now = Instant::now();
                let wait = now.saturating_duration_since(req.submitted_at).as_secs_f64();
                self.hub.lock().queue_wait.record(wait);
                // Sampling decision, made once per admission attempt
                // (shed or started) so the 1-in-N cadence follows the
                // arrival stream.
                trace_seq += 1;
                let sampled = self.config.trace_sample <= 1
                    || (trace_seq - 1) % self.config.trace_sample as u64 == 0;
                if let Some(err) = self.shed_decision(req.deadline) {
                    // A shed request still gets a (tiny) trace tree, so a
                    // traced run shows *why* nothing else was recorded.
                    if sampled {
                        if let Some(tr) = &self.config.trace {
                            let root = tr.begin_request(req.id, req.submitted_at);
                            tr.instant(req.id, "shed", None, Some(wait), now);
                            tr.end_request(req.id, root, now);
                        }
                    }
                    log::debug!("engine: req={} shed wait_secs={wait:.4}", req.id);
                    sink.deliver(req.id, Err(err));
                    continue;
                }
                let root_span = if sampled {
                    self.config.trace.as_ref().map(|tr| {
                        let root = tr.begin_request(req.id, req.submitted_at);
                        tr.span_closed(req.id, root, "queue-wait", None, req.submitted_at, now);
                        root
                    })
                } else {
                    None
                };
                log::debug!("engine: req={} admitted wait_secs={wait:.4}", req.id);
                active.insert(
                    req.id,
                    RequestState::new(
                        req.input,
                        req.deadline,
                        req.tenant,
                        req.submitted_at,
                        root_span,
                    ),
                );
                self.advance_request(req.id, &nodes, &mut active, &mut staged, sink)?;
            }

            // -- flush staged dispatches (coalescing same-layer shards)
            self.dispatch_staged(
                &mut staged,
                &nodes,
                &mut active,
                &mut rounds,
                &mut worker_load,
            )?;
            if draining && pending.is_empty() && active.is_empty() {
                debug_assert!(rounds.is_empty());
                return Ok(());
            }

            // Liveness: a round with nothing outstanding can never
            // decode on its own. The local fallback completes it on the
            // master (pool collapsed / retries exhausted); with the
            // fallback off this is still the old fail-fast diagnosis.
            let stuck: Vec<u64> = rounds
                .iter()
                .filter(|(_, ar)| ar.outstanding.is_empty() && !ar.parts[0].decoder.ready())
                .map(|(&r, _)| r)
                .collect();
            if !stuck.is_empty() {
                for r in stuck {
                    let mut ar = rounds.remove(&r).unwrap();
                    if !self.config.local_fallback {
                        bail!(
                            "layer {} (requests {:?}): no outstanding subtasks but decoder \
                             needs more (received {} of {})",
                            ar.parts[0].lm.node_id,
                            ar.parts.iter().map(|p| p.request).collect::<Vec<_>>(),
                            ar.received.len(),
                            ar.pr.scheme.min_completions()
                        );
                    }
                    self.fallback_complete(&mut ar)?;
                    self.finish_round(ar, &nodes, &mut active, &mut staged, sink)?;
                    self.maybe_replan();
                }
                // Rescued rounds staged their next layers: restart the
                // iteration so they flush (and the drain-exit check
                // re-runs) before blocking.
                continue;
            }

            // -- block for the next event -----------------------------
            // An empty `rounds` means nothing is out on the pool: wait
            // (without a wedge timeout) for a submission, the drain
            // signal, or a membership event. Requests may still be
            // staged here — an empty (or fully-retiring) pool parks
            // them until a worker joins. With work in flight the wait
            // is bounded by the watchdog's next hedge/fallback timer; a
            // lapse wakes the watchdog rather than killing the stream.
            let ev = if rounds.is_empty() {
                Some(self.events.recv().context("master event channel closed")?)
            } else if !watchdog {
                Some(
                    self.events
                        .recv_timeout(self.config.recv_timeout)
                        .context("pipelined engine: timed out waiting for workers")?,
                )
            } else {
                match self.events.recv_timeout(self.watchdog_wait(&rounds)) {
                    Ok(ev) => Some(ev),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        bail!("master event channel closed")
                    }
                }
            };
            if let Some(ev) = ev {
                self.on_event(
                    ev,
                    &mut draining,
                    &nodes,
                    &mut pending,
                    &mut active,
                    &mut rounds,
                    &mut worker_load,
                    &mut backoff,
                    &mut staged,
                    sink,
                )?;
                // Opportunistically drain whatever else is already queued
                // before the next flush: replies/submissions that landed
                // together stage together, which is what lets their rounds
                // coalesce.
                while let Ok(ev) = self.events.try_recv() {
                    self.on_event(
                        ev,
                        &mut draining,
                        &nodes,
                        &mut pending,
                        &mut active,
                        &mut rounds,
                        &mut worker_load,
                        &mut backoff,
                        &mut staged,
                        sink,
                    )?;
                }
            }
            // The watchdog runs on every wake — timer lapse or not:
            // hedge overdue subtasks, locally complete any past their
            // fallback point.
            if watchdog && !rounds.is_empty() {
                self.run_watchdog(
                    &nodes,
                    &mut active,
                    &mut rounds,
                    &mut worker_load,
                    &mut backoff,
                    &mut staged,
                    sink,
                )?;
            }
            // Retiring members finalize (Shutdown + removal) only once
            // every charge against them has drained — a nonzero load
            // means replies (possibly stale Outputs of cancelled work)
            // are still owed.
            let busy: BTreeSet<usize> = worker_load
                .iter()
                .filter(|(_, &l)| l > 0)
                .map(|(&w, _)| w)
                .collect();
            self.finalize_retiring(&busy);
            worker_load.retain(|w, _| self.workers.contains_key(w));
        }
    }

    /// Fold one multiplexed event into the engine state.
    #[allow(clippy::too_many_arguments)]
    fn on_event(
        &mut self,
        ev: MasterEvent,
        draining: &mut bool,
        nodes: &[Node],
        pending: &mut DrrQueue<Pending>,
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
        backoff: &mut BTreeMap<usize, WorkerBackoff>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        match ev {
            MasterEvent::Submit(sreq) => {
                if *draining {
                    // Lost the race with drain(): refuse, don't hang.
                    sreq.reject();
                } else {
                    let req = sink.accept(sreq);
                    let tenant = req.tenant.clone();
                    pending.push(&tenant, Pending::new(req));
                }
                Ok(())
            }
            MasterEvent::Drain => {
                *draining = true;
                Ok(())
            }
            MasterEvent::Joined { id, name, tx } => {
                self.admit_worker(id, name, tx);
                worker_load.insert(id, 0);
                // A mid-request joiner must be visible at the *next
                // layer boundary*, not just the next request:
                // `admit_worker` forced the replanner, so run the
                // replan now instead of waiting for a finished round.
                self.maybe_replan();
                // Staged requests parked on an empty pool flush on the
                // next loop iteration now that a target exists.
                self.probe_worker(id, worker_load)
            }
            MasterEvent::LinkDown(wid) => {
                if !self.drop_worker(wid) {
                    return Ok(()); // double-fire: already evicted
                }
                worker_load.remove(&wid);
                backoff.remove(&wid);
                self.redispatch_orphans(wid, rounds, worker_load, backoff)
            }
            MasterEvent::Reply(wid, msg, arrival) => self.handle_reply(
                wid,
                msg,
                arrival,
                nodes,
                active,
                rounds,
                worker_load,
                backoff,
                staged,
                sink,
            ),
        }
    }

    /// Dispatch a one-subtask probe round to a just-joined worker: the
    /// registry needs real (exec, transmission) samples before the
    /// adaptive policy can place or judge it. The round is logged for
    /// telemetry and immediately retired — its Output reply takes the
    /// stale-reply path (`record_output` still feeds the registry; the
    /// engine holds no `ActiveRound` for it, so the data is dropped).
    fn probe_worker(
        &mut self,
        id: usize,
        worker_load: &mut BTreeMap<usize, usize>,
    ) -> Result<()> {
        let Some(c) = self.plan.convs.iter().find(|c| c.distributed).cloned() else {
            return Ok(()); // nothing distributed: nothing worth probing
        };
        let spec = c.dims.spec;
        let h = c.dims.h_i - 2 * spec.pad;
        let w = c.dims.w_i - 2 * spec.pad;
        let input = Tensor::from_vec(spec.c_in, h, w, vec![0.5; spec.c_in * h * w])?;
        // u64::MAX marks the probe's pseudo-request; no decoder ever
        // sees it. n = k = 1: the smallest real subtask on this layer.
        // Rateless kinds are mapped to uncoded for probing — an LT probe
        // would dispatch its whole symbol budget (~18 frames) at one
        // just-joined worker when a single sample is all the registry
        // needs.
        let probe_scheme = match self.config.scheme {
            SchemeKind::LtFine | SchemeKind::LtCoarse | SchemeKind::Auto => SchemeKind::Uncoded,
            s => s,
        };
        let pr = self.prepare_round(&[(u64::MAX, &input)], &c.node_id, &spec, probe_scheme, 1, 1)?;
        let dispatched_at: Vec<Instant> = pr.frames.iter().map(|_| Instant::now()).collect();
        *worker_load.entry(id).or_insert(0) += pr.frames.len();
        for frame in &pr.frames {
            self.send_to(id, frame);
        }
        self.log_round(pr.round, pr.flops_per_task, pr.bytes_per_task, dispatched_at);
        self.retire_round(pr.round);
        log::debug!("worker {id}: probe round {} dispatched", pr.round);
        Ok(())
    }

    /// A member died mid-flight: every outstanding subtask copy it held
    /// is lost. A task whose *hedge* copy survives loses nothing; a task
    /// with no copy left is re-dispatched inside its round's (shrunken)
    /// dispatch set, exactly like a `Failed` reply — the round decodes
    /// from whichever k subtasks land first, so churn costs latency, not
    /// correctness. When the set is empty or the retry budget is spent,
    /// the task is handed to the master-local fallback instead of
    /// failing the request.
    fn redispatch_orphans(
        &mut self,
        wid: usize,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
        backoff: &mut BTreeMap<usize, WorkerBackoff>,
    ) -> Result<()> {
        let now = Instant::now();
        // Recovery placement draws on the CURRENT live pool, not the
        // round's original dispatch set: a worker that joined after the
        // round went out is a perfectly good home for an orphan.
        let pool = self.dispatch_targets();
        for (&round, ar) in rounds.iter_mut() {
            ar.targets.retain(|&w| w != wid);
            let held: Vec<usize> = ar
                .outstanding
                .iter()
                .copied()
                .filter(|&t| ar.holds(t, wid))
                .collect();
            if held.is_empty() {
                continue;
            }
            let mut orphaned: Vec<usize> = Vec::new();
            for &t in &held {
                if let Some(tr) = &self.config.trace {
                    if let Some(sid) = ar.task_spans.remove(&(t, wid)) {
                        tr.span_end(ar.parts[0].request, sid, now);
                    }
                }
                if ar.drop_holder(t, wid) {
                    orphaned.push(t);
                }
                // else: a hedge copy survives the eviction — the race
                // simply lost one contestant.
            }
            if orphaned.is_empty() {
                continue;
            }
            ar.outstanding.retain(|t| !orphaned.contains(t));
            for p in &mut ar.parts {
                p.lm.failures += orphaned.len();
            }
            for t in orphaned {
                if !ar
                    .pr
                    .scheme
                    .needs_redispatch(t, &ar.received, &ar.outstanding)
                {
                    continue;
                }
                if pool.is_empty() || !ar.retry_allowed(self.config.retry_budget) {
                    if self.config.local_fallback {
                        // Leave the task un-redispatched: the liveness
                        // sweep (or the per-task watchdog for the rest
                        // of the round) completes the decode locally.
                        log::warn!(
                            "pipeline: task {t} of round {round} orphaned by dead worker \
                             {wid} is unrecoverable on the pool; deferring to the \
                             master-local fallback"
                        );
                        continue;
                    }
                    anyhow::ensure!(
                        !pool.is_empty(),
                        "layer {} (round {round}): worker {wid} died and no live workers \
                         remain to take over its subtasks",
                        ar.parts[0].lm.node_id
                    );
                    bail!(
                        "layer {} (round {round}): re-dispatch storm; giving up",
                        ar.parts[0].lm.node_id
                    );
                }
                let target = pick_recovery_target(worker_load, backoff, &pool, None, now);
                let redispatched_at = Instant::now();
                if let Some(rt) = self.round_log.get_mut(&round) {
                    rt.dispatched_at[t] = redispatched_at;
                }
                self.send_to(target, &ar.pr.frames[t]);
                *worker_load.entry(target).or_insert(0) += 1;
                ar.assigned[t] = target;
                ar.outstanding.push(t);
                ar.spent_retries += 1;
                for p in &mut ar.parts {
                    p.lm.redispatches += 1;
                }
                self.hub.lock().gauges.retries += 1;
                if let Some(tr) = &self.config.trace {
                    // Gated on the lead part's round span: a sampled-out
                    // request has none, and emitting under its id would
                    // be an orphan event.
                    if let Some(parent) = ar.parts[0].span {
                        let lead = ar.parts[0].request;
                        tr.instant(lead, "retry", Some(target), None, redispatched_at);
                        let sid = tr.span_start(
                            lead,
                            parent,
                            &format!("task:{t}"),
                            Some(target),
                            redispatched_at,
                        );
                        ar.task_spans.insert((t, target), sid);
                    }
                }
                log::warn!(
                    "pipeline: round={round} task={t} orphaned by dead worker={wid}, \
                     re-dispatched to worker={target}"
                );
            }
        }
        Ok(())
    }

    /// Fold one worker reply into the engine state; finishes (and
    /// advances past) any round it completes.
    #[allow(clippy::too_many_arguments)]
    fn handle_reply(
        &mut self,
        wid: usize,
        msg: FromWorker,
        arrival: Instant,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
        backoff: &mut BTreeMap<usize, WorkerBackoff>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        // Every dispatched subtask yields exactly one reply (Output,
        // Failed, or Skipped after a cancel), so the worker's load
        // charge is released here — at reply time, never earlier. A
        // cancelled-but-already-executing subtask therefore keeps its
        // worker charged until the stale Output actually arrives,
        // which is what keeps the straggler off the next wave's
        // least-loaded placement. Only subtask replies release charge:
        // heartbeats and membership messages never carried one.
        if matches!(
            msg,
            FromWorker::Output { .. } | FromWorker::Failed { .. } | FromWorker::Skipped { .. }
        ) {
            if let Some(l) = worker_load.get_mut(&wid) {
                *l = l.saturating_sub(1);
            }
        }
        match msg {
            FromWorker::Output {
                round,
                task_id,
                exec_secs,
                data,
                ..
            } => {
                let task_id = task_id as usize;
                // Telemetry first, even when the round already decoded
                // (a cancelled-but-executed straggler's stale Output is
                // the estimator's key sample). The round log's
                // flops/bytes scales are the *coalesced* totals, so a
                // batched reply's exec_secs normalizes to the same
                // per-FLOP sample a single-request conv would yield.
                let wp = self.record_output(wid, round, task_id, arrival, exec_secs);
                // A delivered subtask clears the worker's retry backoff.
                backoff.remove(&wid);
                let ready = {
                    let Some(ar) = rounds.get_mut(&round) else {
                        return Ok(()); // stale: round decoded + cancelled earlier
                    };
                    let lead = ar.parts[0].request;
                    if ar.received.contains(&task_id) || !ar.outstanding.contains(&task_id) {
                        // A hedge race (or a master-local fallback) for
                        // this task already resolved: the telemetry
                        // above is the reply's whole value.
                        if let Some(tr) = &self.config.trace {
                            if let Some(sid) = ar.task_spans.remove(&(task_id, wid)) {
                                tr.span_end(lead, sid, arrival);
                            }
                        }
                        for p in &mut ar.parts {
                            p.lm.stale_results += 1;
                        }
                        return Ok(());
                    }
                    ar.outstanding.retain(|&t| t != task_id);
                    // Hedge outcome, observed *before* the race resolves:
                    // the registry is scored from the primary worker's
                    // perspective (a backup win is the primary's loss),
                    // the histograms from the system's (a backup win is
                    // latency the hedge bought). The task's dispatch
                    // clock was restarted at hedge fire, so
                    // arrival − dispatched_at is the race window.
                    let was_hedged = ar.extra.contains_key(&task_id);
                    if was_hedged {
                        let primary = ar.assigned[task_id];
                        let backup_won = wid != primary;
                        let latency = self
                            .round_log
                            .get(&round)
                            .and_then(|rt| rt.dispatched_at.get(task_id).copied())
                            .map(|d| arrival.saturating_duration_since(d).as_secs_f64());
                        if let Some(lat) = latency {
                            let mut h = self.hub.lock();
                            if backup_won {
                                h.hedge_win.record(lat);
                            } else {
                                h.hedge_loss.record(lat);
                            }
                        }
                        self.registry.note_reliability(
                            if backup_won {
                                EventKind::HedgeLost
                            } else {
                                EventKind::HedgeWon
                            },
                            primary,
                            round,
                        );
                        let name = if backup_won { "hedge-won" } else { "hedge-lost" };
                        if let Some(tr) = &self.config.trace {
                            if ar.parts[0].span.is_some() {
                                tr.instant(lead, name, Some(wid), latency, arrival);
                            }
                        }
                        log::debug!(
                            "engine: req={lead} round={round} task={task_id} worker={wid} \
                             {name} latency_secs={:.4}",
                            latency.unwrap_or(f64::NAN)
                        );
                    }
                    if let Some(tr) = &self.config.trace {
                        if let Some(sid) = ar.task_spans.remove(&(task_id, wid)) {
                            tr.span_end(lead, sid, arrival);
                        }
                    }
                    // Resolve the hedge race: cancel each losing holder
                    // unless it still holds other work of this round
                    // (Cancel is round-granular per worker).
                    for loser in ar.resolve_race(task_id, wid) {
                        let busy = ar.outstanding.iter().any(|&t| ar.holds(t, loser));
                        if !busy {
                            self.send_to(loser, &ToWorker::Cancel { round }.encode());
                        }
                    }
                    let n_parts = ar.parts.len();
                    if let Some(wp) = wp {
                        // Attribute the batched subtask's wall time
                        // evenly across the coalesced requests so each
                        // request's per-worker breakdown sums sanely.
                        let share = 1.0 / n_parts as f64;
                        for p in &mut ar.parts {
                            p.lm.per_worker.push(WorkerPhase {
                                transmission: wp.transmission * share,
                                execution: wp.execution * share,
                                ..wp
                            });
                        }
                    }
                    // Fan the (possibly batched) output back out: chunk
                    // `i` belongs to part `i`'s decoder. Every part's
                    // decoder sees the same subtask ids, so readiness
                    // flips for all of them on the same reply.
                    let ready = if n_parts == 1 {
                        ar.parts[0].decoder.add(task_id, data)
                    } else {
                        let part_len = ar.pr.part_elems();
                        anyhow::ensure!(
                            data.len() == part_len * n_parts,
                            "round {round}: batched output {} != {} parts x {part_len}",
                            data.len(),
                            n_parts
                        );
                        let mut ready = true;
                        for (i, p) in ar.parts.iter_mut().enumerate() {
                            let r = p
                                .decoder
                                .add(task_id, data[i * part_len..(i + 1) * part_len].to_vec());
                            // Identical subtask sets ⇒ identical
                            // readiness; never finish before every
                            // part can decode.
                            ready = ready && r;
                        }
                        ready
                    };
                    if !ready {
                        ar.received.push(task_id);
                    }
                    ready
                };
                if ready {
                    let ar = rounds.remove(&round).unwrap();
                    self.finish_round(ar, nodes, active, staged, sink)?;
                    // Between rounds is the live stream's "between
                    // requests": swap the plan here if one is due.
                    self.maybe_replan();
                }
            }
            FromWorker::Skipped { round, task_id } => {
                // Normally stale by construction (Cancel is only sent
                // after a round decoded or a hedge race resolved).
                // Defensively unblock the round if one ever arrives
                // live — holder-aware, so a skip from a hedge loser
                // never drops a task whose primary copy is still out.
                if let Some(ar) = rounds.get_mut(&round) {
                    let t = task_id as usize;
                    if let Some(tr) = &self.config.trace {
                        if let Some(sid) = ar.task_spans.remove(&(t, wid)) {
                            tr.span_end(ar.parts[0].request, sid, arrival);
                        }
                    }
                    if ar.outstanding.contains(&t) && ar.drop_holder(t, wid) {
                        ar.outstanding.retain(|&x| x != t);
                    }
                }
            }
            FromWorker::Failed { round, task_id } => {
                let task_id = task_id as usize;
                // Symmetric with record_output: only rounds this master
                // still tracks count toward failure streaks.
                self.record_failed(wid, round);
                note_strike(backoff, wid, arrival);
                let Some(ar) = rounds.get_mut(&round) else {
                    return Ok(());
                };
                if let Some(tr) = &self.config.trace {
                    if let Some(sid) = ar.task_spans.remove(&(task_id, wid)) {
                        tr.span_end(ar.parts[0].request, sid, arrival);
                    }
                }
                if ar.received.contains(&task_id) || !ar.outstanding.contains(&task_id) {
                    return Ok(()); // late loser of an already-resolved race
                }
                // Every coalesced request experienced this failure.
                for p in &mut ar.parts {
                    p.lm.failures += 1;
                }
                // Drop only this holder: a hedged copy may still be
                // racing, in which case nothing needs re-dispatching.
                if !ar.drop_holder(task_id, wid) {
                    return Ok(());
                }
                ar.outstanding.retain(|&t| t != task_id);
                if ar
                    .pr
                    .scheme
                    .needs_redispatch(task_id, &ar.received, &ar.outstanding)
                {
                    // Current live pool, not the round's original target
                    // set: mid-round joiners are valid recovery homes.
                    let pool = self.dispatch_targets();
                    if pool.is_empty() || !ar.retry_allowed(self.config.retry_budget) {
                        if self.config.local_fallback {
                            // Escalate to the master instead of failing
                            // the request: the liveness sweep or the
                            // watchdog completes the decode locally.
                            log::warn!(
                                "pipeline: task {task_id} of round {round} failed on \
                                 worker {wid} and is unrecoverable on the pool; \
                                 deferring to the master-local fallback"
                            );
                            return Ok(());
                        }
                        anyhow::ensure!(
                            !pool.is_empty(),
                            "layer {}: task {task_id} failed and no live workers remain \
                             in the round's dispatch set",
                            ar.parts[0].lm.node_id
                        );
                        bail!(
                            "layer {}: re-dispatch storm; giving up",
                            ar.parts[0].lm.node_id
                        );
                    }
                    let target =
                        pick_recovery_target(worker_load, backoff, &pool, Some(wid), arrival);
                    let redispatched_at = Instant::now();
                    if let Some(rt) = self.round_log.get_mut(&round) {
                        rt.dispatched_at[task_id] = redispatched_at;
                    }
                    self.send_to(target, &ar.pr.frames[task_id]);
                    *worker_load.entry(target).or_insert(0) += 1;
                    ar.assigned[task_id] = target;
                    ar.outstanding.push(task_id);
                    ar.spent_retries += 1;
                    for p in &mut ar.parts {
                        p.lm.redispatches += 1;
                    }
                    self.hub.lock().gauges.retries += 1;
                    if let Some(tr) = &self.config.trace {
                        if let Some(parent) = ar.parts[0].span {
                            let lead = ar.parts[0].request;
                            tr.instant(lead, "retry", Some(target), None, redispatched_at);
                            let sid = tr.span_start(
                                lead,
                                parent,
                                &format!("task:{task_id}"),
                                Some(target),
                                redispatched_at,
                            );
                            ar.task_spans.insert((task_id, target), sid);
                        }
                    }
                    log::debug!(
                        "pipeline: round={round} task={task_id} failed on worker={wid}, \
                         re-dispatched to worker={target}"
                    );
                }
            }
            // Liveness is serviced by the reader's read-timeout clock;
            // here the seq is checked for stale-beacon replay.
            FromWorker::Heartbeat { seq } => self.note_heartbeat(wid, seq),
            // Graceful leave: stop dispatching to it; the main loop
            // finalizes (Shutdown + removal) once its charge drains.
            FromWorker::Retire => self.retire_worker(wid),
            FromWorker::Join { .. } => {
                bail!("unexpected Join from already-admitted worker {wid}")
            }
            FromWorker::Ready => bail!("unexpected Ready from worker {wid}"),
        }
        Ok(())
    }

    /// Execute request `id` forward from its cursor: type-2/simple ops
    /// run locally; the first distributed conv *stages* the request
    /// (the caller flushes staged rounds — possibly coalesced — via
    /// [`Master::dispatch_staged`]) and yields. A request that reaches
    /// the end of the graph is delivered to the sink and removed from
    /// the active set.
    fn advance_request(
        &mut self,
        id: u64,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        loop {
            if active[&id].node_idx >= nodes.len() {
                let mut st = active.remove(&id).unwrap();
                let last = nodes.last().unwrap();
                let out = st.values.remove(&last.id).context("missing model output")?;
                st.metrics.total_seconds = st.t_start.elapsed().as_secs_f64();
                let now = Instant::now();
                let sojourn = now.saturating_duration_since(st.submitted_at).as_secs_f64();
                {
                    let mut h = self.hub.lock();
                    h.sojourn.record(sojourn);
                    let t = h.tenant(&st.tenant);
                    t.completed += 1;
                    t.sojourn.record(sojourn);
                }
                if let (Some(tr), Some(root)) = (&self.config.trace, st.root_span) {
                    tr.end_request(id, root, now);
                }
                log::debug!("engine: req={id} delivered sojourn_secs={sojourn:.4}");
                sink.deliver(id, Ok((out, st.metrics)));
                return Ok(());
            }
            let node = &nodes[active[&id].node_idx];
            if let Op::Conv { .. } = &node.op {
                let dist = self
                    .plan
                    .conv(&node.id)
                    .map(|c| c.distributed)
                    .unwrap_or(false);
                if dist {
                    staged.push(id);
                    return Ok(()); // yield: dispatch_staged resumes us
                }
            }
            let fetched: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|i| active[&id].values.get(i).cloned().context("missing value"))
                .collect::<Result<_>>()?;
            let st = active.get_mut(&id).unwrap();
            let out = self.run_local_node(node, &fetched, &mut st.metrics)?;
            st.values.insert(node.id.clone(), out);
            st.node_idx += 1;
        }
    }

    /// Flush the staging buffer: group staged requests by (layer,
    /// input shape) in staging order, chunk groups at the coalescing
    /// limit, and dispatch each group as ONE coded round whose frames
    /// carry every member's shard. With `coalesce <= 1` every group is
    /// a singleton and dispatch behaves exactly like the uncoalesced
    /// engine.
    fn dispatch_staged(
        &mut self,
        staged: &mut Vec<u64>,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
    ) -> Result<()> {
        if staged.is_empty() {
            return Ok(());
        }
        // No live members (elastic cluster before the first join, or
        // everyone retiring/evicted): park the staging buffer as-is. A
        // `Joined` event wakes the loop and the next flush drains it.
        if self.live_worker_ids().is_empty() {
            return Ok(());
        }
        let cap = self.config.coalesce.max(1);
        // Stable grouping: same layer cursor + same input shape, first
        // open group wins, groups close at `cap` members. Deadline-aware
        // exception: a *tight*-deadline request (remaining slack under a
        // small multiple of the predicted service time — see
        // `fair::tight_deadline`) rides alone in a closed singleton
        // group. Folding it into a wide coalesced batch would put other
        // requests' compute on its critical path, which is exactly how a
        // feasible deadline gets missed; and conversely nothing may pile
        // in behind it. With `coalesce <= 1` every group is a singleton
        // anyway and this changes nothing.
        let now = Instant::now();
        let predicted = self.predicted_service_secs();
        let mut groups: Vec<(usize, (usize, usize, usize), Vec<u64>, bool)> = Vec::new();
        for &id in staged.iter() {
            let st = active.get(&id).context("staged request not active")?;
            let node = &nodes[st.node_idx];
            let input = st
                .values
                .get(&node.inputs[0])
                .context("staged conv input missing")?;
            let key = (st.node_idx, (input.c, input.h, input.w));
            let slack = st
                .deadline
                .map(|d| d.saturating_duration_since(now).as_secs_f64());
            if cap > 1 && fair::tight_deadline(slack, predicted) {
                groups.push((key.0, key.1, vec![id], true));
                continue;
            }
            match groups
                .iter_mut()
                .find(|(ni, sh, ids, closed)| !*closed && (*ni, *sh) == key && ids.len() < cap)
            {
                Some((_, _, ids, _)) => ids.push(id),
                None => groups.push((key.0, key.1, vec![id], false)),
            }
        }
        staged.clear();

        for (node_idx, _, ids, _) in groups {
            let node = &nodes[node_idx];
            let (spec, relu) = match &node.op {
                Op::Conv { spec, relu } => (*spec, *relu),
                _ => bail!("staged request not at a conv node"),
            };
            let k_planned = self.plan.conv(&node.id).map(|c| c.k).unwrap_or(1);
            // Dispatch set for this round: the registry's active
            // workers under the adaptive policy (quarantined
            // stragglers sit out except for due probes), the full pool
            // otherwise.
            let targets = self.dispatch_targets();
            if targets.is_empty() {
                // Membership changed under us mid-flush: re-park this
                // group for the next flush.
                staged.extend(ids.iter().copied());
                continue;
            }
            // Earliest deadline across the coalesced requests: it clamps
            // the round's hedge/fallback timers below AND feeds the
            // selector's deadline-redundancy rule (remaining slack sizes
            // n - k, or flips the layer to rateless when no k fits).
            let deadline = ids
                .iter()
                .filter_map(|rid| active.get(rid).and_then(|st| st.deadline))
                .min();
            let (scheme_kind, k_eff) =
                self.choose_scheme(&node.id, k_planned, targets.len(), deadline);
            let reqs: Vec<(u64, &Tensor)> = ids
                .iter()
                .map(|rid| {
                    (
                        *rid,
                        active
                            .get(rid)
                            .and_then(|st| st.values.get(&node.inputs[0]))
                            .expect("validated during grouping"),
                    )
                })
                .collect();
            let mut pr =
                self.prepare_round(&reqs, &node.id, &spec, scheme_kind, k_eff, targets.len())?;
            let t_dispatch = Instant::now();
            // Spread the round's shards over *distinct* workers (the
            // MDS resilience model assumes one shard per device),
            // least-loaded first; wrap only when a scheme issues more
            // subtasks than workers (LT).
            let mut order: Vec<usize> = targets.clone();
            order.sort_by_key(|&w| (worker_load.get(&w).copied().unwrap_or(0), w));
            let mut assigned = vec![0usize; pr.frames.len()];
            let mut dispatched_at = Vec::with_capacity(pr.frames.len());
            for (t, frame) in pr.frames.iter().enumerate() {
                let w = order[t % order.len()];
                dispatched_at.push(Instant::now());
                self.send_to(w, frame);
                *worker_load.entry(w).or_insert(0) += 1;
                assigned[t] = w;
            }
            // Tracing needs the per-task stamps after log_round takes
            // the vector; copy only on traced runs.
            let dispatched_at_copy = if self.config.trace.is_some() {
                dispatched_at.clone()
            } else {
                Vec::new()
            };
            self.log_round(pr.round, pr.flops_per_task, pr.bytes_per_task, dispatched_at);
            // Master-local remainder pieces while workers run (one per
            // coalesced request).
            let t0 = Instant::now();
            let prepared = std::mem::take(&mut pr.parts);
            let mut parts = Vec::with_capacity(prepared.len());
            for pp in prepared {
                let remainder = match &pp.remainder_input {
                    Some(piece) => Some(self.provider.conv(&spec, piece, &pr.params.weights)?),
                    None => None,
                };
                // One `round` span per coalesced part, under its own
                // request's root — every member of a coalesced round
                // shows the layer window on its own track.
                let span = self.config.trace.as_ref().and_then(|tr| {
                    let root = active.get(&pp.request).and_then(|st| st.root_span)?;
                    Some(tr.span_start(
                        pp.request,
                        root,
                        &format!("round:{}", node.id),
                        None,
                        t_dispatch,
                    ))
                });
                parts.push(ActivePart {
                    request: pp.request,
                    decoder: pr.scheme.decoder(),
                    remainder,
                    lm: pp.lm,
                    span,
                });
            }
            let t_local = t0.elapsed().as_secs_f64();
            // Subtask dispatch spans live under the *lead* part's round
            // span (one track carries the shared fan-out; duplicating it
            // per coalesced request would only multiply identical bars).
            let mut task_spans: HashMap<(usize, usize), u64> = HashMap::new();
            if let Some(tr) = &self.config.trace {
                if let Some(parent) = parts.first().and_then(|p| p.span) {
                    let lead = parts[0].request;
                    for (t, &w) in assigned.iter().enumerate() {
                        let sid = tr.span_start(
                            lead,
                            parent,
                            &format!("task:{t}"),
                            Some(w),
                            dispatched_at_copy[t],
                        );
                        task_spans.insert((t, w), sid);
                    }
                }
            }
            let outstanding: Vec<usize> = (0..pr.frames.len()).collect();
            rounds.insert(
                pr.round,
                ActiveRound {
                    relu,
                    pr,
                    parts,
                    received: Vec::new(),
                    outstanding,
                    assigned,
                    extra: HashMap::new(),
                    spent_retries: 0,
                    deadline,
                    targets,
                    t_dispatch,
                    t_local,
                    task_spans,
                },
            );
        }
        Ok(())
    }

    /// A round just became decodable: cancel stragglers, decode every
    /// coalesced part, and advance each owning request (which stages
    /// their next rounds — coalesced batches move through the model in
    /// lockstep and re-coalesce at the next distributed layer).
    fn finish_round(
        &mut self,
        mut ar: ActiveRound,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        // Cancel outstanding stragglers so worker queues drop them. Their
        // load charges are NOT released here: each cancelled subtask
        // still produces exactly one reply (a Skipped ack for queued
        // work, a stale Output for work already executing), and the
        // charge is released when that reply arrives.
        if !ar.outstanding.is_empty() {
            let frame = ToWorker::Cancel { round: ar.pr.round }.encode();
            let mut notified: BTreeSet<usize> = BTreeSet::new();
            for &t in &ar.outstanding {
                let w = ar.assigned[t];
                if notified.insert(w) {
                    // Evicted holders are a no-op inside send_to.
                    self.send_to(w, &frame);
                }
                // Hedge copies still racing are stragglers too.
                if let Some(extras) = ar.extra.get(&t) {
                    for &w in extras {
                        if notified.insert(w) {
                            self.send_to(w, &frame);
                        }
                    }
                }
            }
            for p in &mut ar.parts {
                p.lm.cancelled += ar.outstanding.len();
            }
            self.hub.lock().gauges.cancels += ar.outstanding.len() as u64;
            if let Some(tr) = &self.config.trace {
                if ar.parts[0].span.is_some() {
                    tr.instant(
                        ar.parts[0].request,
                        "cancel",
                        None,
                        Some(ar.outstanding.len() as f64),
                        Instant::now(),
                    );
                }
            }
            ar.outstanding.clear();
        }
        let t_workers = ar.t_dispatch.elapsed().as_secs_f64() - ar.t_local;
        let t_local_share = ar.t_local / ar.parts.len() as f64;
        self.retire_round(ar.pr.round);
        self.hub.lock().t_workers.record(t_workers);
        // Cancelled stragglers' dispatch spans never see a live reply;
        // close them at the round boundary so the tree is sealed before
        // the owning request can be delivered.
        if let Some(tr) = &self.config.trace {
            let now = Instant::now();
            let lead = ar.parts[0].request;
            for (_, sid) in ar.task_spans.drain() {
                tr.span_end(lead, sid, now);
            }
        }

        let mut advanced = Vec::with_capacity(ar.parts.len());
        for mut part in std::mem::take(&mut ar.parts) {
            part.lm.t_workers = t_workers;

            let t0 = Instant::now();
            let decoded = part.decoder.decode()?;
            part.lm.t_decode = t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let out = assemble_output(&ar.pr, decoded, part.remainder.take(), ar.relu)?;
            part.lm.t_local = t_local_share + t0.elapsed().as_secs_f64();
            {
                let mut h = self.hub.lock();
                h.t_decode.record(part.lm.t_decode);
                h.t_local.record(part.lm.t_local);
            }
            if let (Some(tr), Some(sid)) = (&self.config.trace, part.span) {
                tr.span_end(part.request, sid, Instant::now());
            }

            let id = part.request;
            let st = active.get_mut(&id).context("finished round for unknown request")?;
            let node_id = nodes[st.node_idx].id.clone();
            st.metrics.layers.push(part.lm);
            st.values.insert(node_id, out);
            st.node_idx += 1;
            advanced.push(id);
        }
        for id in advanced {
            self.advance_request(id, nodes, active, staged, sink)?;
        }
        Ok(())
    }

    /// How long the event wait may block before the watchdog must look
    /// at the pool again: the earliest pending hedge or fallback timer
    /// across every outstanding subtask, deadline-clamped, bounded by
    /// `recv_timeout` above and a small floor below (no hot spin).
    fn watchdog_wait(&self, rounds: &HashMap<u64, ActiveRound>) -> Duration {
        let now = Instant::now();
        let mut next: Option<Instant> = None;
        for (&round, ar) in rounds {
            let Some(rt) = self.round_log.get(&round) else {
                continue;
            };
            for &t in &ar.outstanding {
                let Some(&dispatched) = rt.dispatched_at.get(t) else {
                    continue;
                };
                let delay = self.hedge_delay(
                    ar.assigned[t],
                    ar.pr.flops_per_task,
                    ar.pr.bytes_per_task,
                );
                // An unhedged task wakes us at its hedge point; a task
                // already hedged (or with hedging off) at its fallback
                // point.
                let hedge_pending =
                    self.config.hedge_quantile > 0.0 && !ar.extra.contains_key(&t);
                let mut at = if hedge_pending {
                    dispatched + delay
                } else {
                    dispatched + delay * 2
                };
                if let Some(d) = ar.deadline {
                    at = at.min(d);
                }
                next = Some(next.map_or(at, |n| n.min(at)));
            }
        }
        next.map_or(self.config.recv_timeout, |at| at.saturating_duration_since(now))
            .min(self.config.recv_timeout)
            .max(Duration::from_millis(10))
    }

    /// The reliability watchdog: runs on every loop wake while work is
    /// in flight. Each outstanding subtask carries two fitted timers
    /// (clamped to the round's earliest request deadline, so
    /// tight-deadline requests speculate *early*):
    ///
    /// * past `hedge_at = dispatched + p-quantile delay`, the subtask is
    ///   *hedged*: its frame is speculatively re-sent to the
    ///   least-loaded eligible worker and the copies race — first reply
    ///   wins, the loser is cancelled ([`ActiveRound::resolve_race`]);
    /// * past `fallback_at = dispatched + 2×delay`, the master stops
    ///   waiting and computes the shard locally
    ///   ([`Master::compute_task_locally`]), cancelling every live copy.
    ///
    /// With `local_fallback` off, a subtask outstanding longer than
    /// `recv_timeout` keeps the old fail-fast wedge diagnosis instead.
    #[allow(clippy::too_many_arguments)]
    fn run_watchdog(
        &mut self,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
        backoff: &mut BTreeMap<usize, WorkerBackoff>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        let now = Instant::now();
        // Hedge placement draws on the CURRENT live pool: a worker that
        // joined after a round went out is exactly the rescue target a
        // wedged 1-worker round needs (elastic follow-up (b)).
        let pool = self.dispatch_targets();
        let round_ids: Vec<u64> = rounds.keys().copied().collect();
        for round in round_ids {
            let mut completed = false;
            if let Some(ar) = rounds.get_mut(&round) {
                let tasks: Vec<usize> = ar.outstanding.clone();
                for t in tasks {
                    let Some(dispatched) = self
                        .round_log
                        .get(&round)
                        .and_then(|rt| rt.dispatched_at.get(t).copied())
                    else {
                        continue;
                    };
                    if !self.config.local_fallback
                        && now.duration_since(dispatched) >= self.config.recv_timeout
                    {
                        bail!(
                            "pipelined engine: timed out waiting for workers \
                             (task {t} of round {round} outstanding past recv_timeout)"
                        );
                    }
                    let delay = self.hedge_delay(
                        ar.assigned[t],
                        ar.pr.flops_per_task,
                        ar.pr.bytes_per_task,
                    );
                    let mut hedge_at = dispatched + delay;
                    let mut fallback_at = dispatched + delay * 2;
                    if let Some(d) = ar.deadline {
                        hedge_at = hedge_at.min(d);
                        fallback_at = fallback_at.min(d);
                    }
                    if self.config.local_fallback && now >= fallback_at {
                        // The pool had two chances; the master takes
                        // this shard over and cancels every live copy.
                        let chunks = self.compute_task_locally(&ar.pr, t)?;
                        self.registry.note_reliability(
                            EventKind::LocalFallback,
                            ar.assigned[t],
                            round,
                        );
                        let done_at = Instant::now();
                        let fb_latency =
                            done_at.saturating_duration_since(dispatched).as_secs_f64();
                        {
                            let mut h = self.hub.lock();
                            h.fallback_latency.record(fb_latency);
                            h.gauges.fallbacks += 1;
                        }
                        if let Some(tr) = &self.config.trace {
                            if ar.parts[0].span.is_some() {
                                tr.instant(
                                    ar.parts[0].request,
                                    "local-fallback",
                                    Some(ar.assigned[t]),
                                    Some(fb_latency),
                                    done_at,
                                );
                            }
                        }
                        ar.outstanding.retain(|&x| x != t);
                        for holder in ar.take_holders(t) {
                            if let Some(tr) = &self.config.trace {
                                if let Some(sid) = ar.task_spans.remove(&(t, holder)) {
                                    tr.span_end(ar.parts[0].request, sid, done_at);
                                }
                            }
                            let busy = ar.outstanding.iter().any(|&x| ar.holds(x, holder));
                            if !busy {
                                self.send_to(holder, &ToWorker::Cancel { round }.encode());
                            }
                        }
                        let mut ready = true;
                        for (p, chunk) in ar.parts.iter_mut().zip(chunks) {
                            let r = p.decoder.add(t, chunk);
                            p.lm.fallbacks += 1;
                            ready = ready && r;
                        }
                        log::warn!(
                            "watchdog: round={round} task={t} computed locally \
                             (master fallback) latency_secs={fb_latency:.4}"
                        );
                        if ready {
                            completed = true;
                            break;
                        }
                        ar.received.push(t);
                        continue;
                    }
                    if self.config.hedge_quantile > 0.0
                        && now >= hedge_at
                        && !ar.extra.contains_key(&t)
                        && ar.retry_allowed(self.config.retry_budget)
                    {
                        let holder = ar.assigned[t];
                        // Race an extra copy on a worker not already
                        // holding one.
                        let candidates: Vec<usize> = pool
                            .iter()
                            .copied()
                            .filter(|&w| !ar.holds(t, w))
                            .collect();
                        if candidates.is_empty() {
                            continue;
                        }
                        let target =
                            pick_recovery_target(worker_load, backoff, &candidates, None, now);
                        self.send_to(target, &ar.pr.frames[t]);
                        *worker_load.entry(target).or_insert(0) += 1;
                        ar.extra.entry(t).or_default().push(target);
                        ar.spent_retries += 1;
                        for p in &mut ar.parts {
                            p.lm.hedges += 1;
                        }
                        self.hub.lock().gauges.hedges += 1;
                        self.registry
                            .note_reliability(EventKind::Hedged, holder, round);
                        note_strike(backoff, holder, now);
                        // Restart the task's clock: the fallback timer
                        // now counts from the hedge dispatch, and a
                        // hedge-winner's telemetry sample measures the
                        // winning dispatch (same convention as failure
                        // re-dispatch).
                        let hedged_at = Instant::now();
                        if let Some(rt) = self.round_log.get_mut(&round) {
                            rt.dispatched_at[t] = hedged_at;
                        }
                        if let Some(tr) = &self.config.trace {
                            if let Some(parent) = ar.parts[0].span {
                                let lead = ar.parts[0].request;
                                tr.instant(lead, "hedge-fired", Some(holder), None, hedged_at);
                                let sid = tr.span_start(
                                    lead,
                                    parent,
                                    &format!("task:{t}"),
                                    Some(target),
                                    hedged_at,
                                );
                                ar.task_spans.insert((t, target), sid);
                            }
                        }
                        log::info!(
                            "watchdog: round={round} task={t} overdue on worker={holder}, \
                             hedged to worker={target}"
                        );
                    }
                }
            }
            if completed {
                let ar = rounds.remove(&round).unwrap();
                self.finish_round(ar, nodes, active, staged, sink)?;
                self.maybe_replan();
            }
        }
        Ok(())
    }

    /// Complete a wedged round entirely on the master: compute missing
    /// shards through the local provider until every part's decoder is
    /// ready. Correct for every scheme — conv linearity means an encoded
    /// payload convolves to the matching encoded output, so feeding
    /// locally-computed shards to the decoder is indistinguishable from
    /// a worker reply.
    fn fallback_complete(&mut self, ar: &mut ActiveRound) -> Result<()> {
        let round = ar.pr.round;
        // Missing shards are recovered in waves of at most
        // `fallback_concurrency` so a wedged wide round (LT budgets
        // especially) overlaps its shard convolutions instead of
        // grinding through them one by one — while never computing
        // unboundedly past what the decoder needs.
        let cap = self.config.fallback_concurrency.max(1);
        let mut next_t = 0usize;
        while !ar.parts[0].decoder.ready() {
            let mut wave = Vec::with_capacity(cap);
            while wave.len() < cap && next_t < ar.pr.frames.len() {
                if !ar.received.contains(&next_t) {
                    wave.push(next_t);
                }
                next_t += 1;
            }
            anyhow::ensure!(
                !wave.is_empty(),
                "layer {} (round {round}): local fallback exhausted every shard but the \
                 decoder is still short",
                ar.parts[0].lm.node_id
            );
            let all_chunks = self.compute_tasks_locally(&ar.pr, &wave)?;
            let done_at = Instant::now();
            for (&t, chunks) in wave.iter().zip(all_chunks) {
                if ar.parts[0].decoder.ready() {
                    // An earlier shard in this wave finished the decode;
                    // surplus shards are dropped unfed and unreported.
                    break;
                }
                self.registry
                    .note_reliability(EventKind::LocalFallback, ar.assigned[t], round);
                let fb_latency = self
                    .round_log
                    .get(&round)
                    .and_then(|rt| rt.dispatched_at.get(t).copied())
                    .map(|d| done_at.saturating_duration_since(d).as_secs_f64());
                {
                    let mut h = self.hub.lock();
                    if let Some(lat) = fb_latency {
                        h.fallback_latency.record(lat);
                    }
                    h.gauges.fallbacks += 1;
                }
                if let Some(tr) = &self.config.trace {
                    if ar.parts[0].span.is_some() {
                        tr.instant(
                            ar.parts[0].request,
                            "local-fallback",
                            Some(ar.assigned[t]),
                            fb_latency,
                            done_at,
                        );
                    }
                }
                for (p, chunk) in ar.parts.iter_mut().zip(chunks) {
                    p.decoder.add(t, chunk);
                    p.lm.fallbacks += 1;
                }
                ar.received.push(t);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;
    use std::time::Duration;

    fn req(id: u64, priority: u8, deadline: Option<Instant>) -> Pending {
        Pending::new(EngineRequest {
            id,
            input: Tensor::zeros(1, 1, 1),
            priority,
            deadline,
            tenant: DEFAULT_TENANT.to_string(),
            submitted_at: Instant::now(),
        })
    }

    /// Admission order is (priority desc, deadline asc with None last,
    /// id asc) — the serving redesign's dispatch-order contract.
    #[test]
    fn pending_orders_by_priority_deadline_id() {
        let t0 = Instant::now();
        let mut heap = BinaryHeap::new();
        heap.push(req(0, 0, None));
        heap.push(req(1, 0, Some(t0 + Duration::from_secs(5))));
        heap.push(req(2, 1, None));
        heap.push(req(3, 1, Some(t0 + Duration::from_secs(9))));
        heap.push(req(4, 1, Some(t0 + Duration::from_secs(2))));
        heap.push(req(5, 0, None));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|p| p.req.id)).collect();
        assert_eq!(order, vec![4, 3, 2, 1, 0, 5]);
    }

    /// Equal-weight tenants alternate admissions; inside each tenant's
    /// turn the EDF (priority, deadline, id) order still holds.
    #[test]
    fn drr_alternates_tenants_edf_within() {
        let mut q: DrrQueue<Pending> = DrrQueue::new(&[]);
        for (id, tenant) in [(0, "a"), (1, "a"), (2, "b"), (3, "b")] {
            q.push(
                tenant,
                Pending::new(EngineRequest {
                    id,
                    input: Tensor::zeros(1, 1, 1),
                    priority: 0,
                    deadline: None,
                    tenant: tenant.to_string(),
                    submitted_at: Instant::now(),
                }),
            );
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|p| p.req.id)).collect();
        assert_eq!(order, vec![0, 2, 1, 3]);
    }

    #[test]
    fn pick_worker_prefers_least_loaded_and_avoids() {
        // Keyed by stable worker id — ids need not be contiguous.
        let load: BTreeMap<usize, usize> = [(0, 3), (2, 2), (7, 0)].into_iter().collect();
        let all = [0, 2, 7];
        assert_eq!(pick_worker(&load, &all, None), 7);
        assert_eq!(pick_worker(&load, &all, Some(7)), 2);
        // A candidate with no load entry (just admitted) counts as idle.
        assert_eq!(pick_worker(&load, &[0, 9], None), 9);
        // A single candidate is used even if it should be avoided.
        assert_eq!(pick_worker(&load, &[2], Some(2)), 2);
    }
}
