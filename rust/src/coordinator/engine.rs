//! The pipelined execution engine — a continuous, admission-driven run
//! loop.
//!
//! The round-barrier path (`Master::infer`) dispatches layer ℓ, blocks
//! until it decodes, then starts layer ℓ+1 — workers sit idle while the
//! master decodes/re-encodes, and exactly one request is served at a
//! time. This engine removes both stalls and, since the serving API
//! redesign, no longer needs the full request list up front:
//!
//! * requests are *admitted* between event-loop iterations: the loop
//!   blocks on the master's single event channel, which multiplexes
//!   worker replies with [`MasterEvent::Submit`] from the serving
//!   front-end ([`super::server::InferenceServer`]);
//! * admitted requests wait in a queue ordered by **(priority, deadline,
//!   submission order)** — not batch index — and start when a
//!   concurrency slot frees up (`StreamOptions::max_concurrent`);
//! * requests whose deadline has expired, or whose predicted completion
//!   (from the telemetry-fitted profile, `--adaptive`) misses it, are
//!   shed at dispatch time instead of served late;
//! * several in-flight requests advance through the model graph
//!   independently; a request reaching a distributed conv *stages* its
//!   round, and staged rounds are flushed together after the loop has
//!   drained every already-queued event — so requests that become ready
//!   at the same moment are visible to one flush;
//! * at flush time, staged same-layer same-shape rounds are **coalesced**
//!   (up to `MasterConfig::coalesce` requests): their same-index shards
//!   merge into one multi-payload [`WorkOrder`] and a worker runs one
//!   prepacked-weight pass whose GEMM N dimension spans every request —
//!   the per-dispatch fixed costs (wire framing, im2col, queueing) are
//!   paid once per *batch* instead of once per request. One reply fans
//!   back out into per-request decoders; requests coalesced at layer ℓ
//!   finish ℓ together and naturally re-coalesce at ℓ+1;
//! * a coalesced dispatch goes to the *least-loaded* workers and yields
//!   back to the event loop;
//! * the moment a round has its first `k` results, its outstanding
//!   straggler subtasks are cancelled ([`ToWorker::Cancel`]) so the
//!   per-worker queues (see `coordinator::worker`) drop them and free
//!   capacity for the next wave;
//! * `maybe_replan` runs after every finished round, so the adaptive
//!   plan tracks the *live* arrival stream rather than batch boundaries.
//!
//! `Master::infer_batch` is a thin wrapper: it seeds the admission queue
//! with the whole batch and drains it ([`StreamOptions::draining`]), so
//! the batch path and the serving path cannot diverge.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coding;
use crate::conv::Tensor;
use crate::model::{Node, Op};

use super::master::{assemble_output, Master, MasterEvent, PreparedRound};
use super::messages::{FromWorker, ToWorker};
use super::metrics::{InferenceMetrics, LayerMetrics, WorkerPhase};
use super::server::ServeError;

/// One admitted request, as the engine sees it.
pub(super) struct EngineRequest {
    pub(super) id: u64,
    pub(super) input: Tensor,
    /// Larger = more urgent (the dispatch-order key ahead of the
    /// deadline).
    pub(super) priority: u8,
    pub(super) deadline: Option<Instant>,
}

/// Where terminal request outcomes go: the batch wrapper collects them
/// into a vector, the serving front-end routes them to per-request
/// handles and keeps the admission accounting.
pub(super) trait EngineSink {
    /// Register a server submission (stash its reply channel) and hand
    /// back the engine-facing request.
    fn accept(&mut self, req: super::server::ServerRequest) -> EngineRequest;
    /// Deliver a terminal outcome for request `id`.
    fn deliver(&mut self, id: u64, result: Result<(Tensor, InferenceMetrics), ServeError>);
}

/// Run-loop options for [`Master::serve_stream`].
pub(super) struct StreamOptions {
    /// Max requests advancing concurrently (0 = unlimited). Admitted
    /// requests beyond it wait in the (priority, deadline, id) queue.
    pub(super) max_concurrent: usize,
    /// Start in draining mode: serve the seeded requests, accept no
    /// submissions, return when everything delivered (the `infer_batch`
    /// path). A live server starts `false` and flips on
    /// [`MasterEvent::Drain`].
    pub(super) draining: bool,
}

/// Admission-queue entry: a newtype whose `Ord` ranks the *most urgent*
/// request greatest (the heap is a max-heap): higher priority first,
/// then earlier deadline (`None` = no deadline = last), then lower id
/// (submission order).
struct Pending {
    req: EngineRequest,
}

impl Pending {
    fn new(req: EngineRequest) -> Pending {
        Pending { req }
    }
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> Ordering {
        self.req
            .priority
            .cmp(&other.req.priority)
            .then_with(|| match (self.req.deadline, other.req.deadline) {
                (Some(a), Some(b)) => b.cmp(&a),
                (Some(_), None) => Ordering::Greater,
                (None, Some(_)) => Ordering::Less,
                (None, None) => Ordering::Equal,
            })
            .then_with(|| other.req.id.cmp(&self.req.id))
    }
}

/// One request's progress through the model graph.
struct RequestState {
    values: BTreeMap<String, Tensor>,
    /// Next node to execute (all earlier nodes are in `values`).
    node_idx: usize,
    metrics: InferenceMetrics,
    t_start: Instant,
}

impl RequestState {
    fn new(input: Tensor) -> RequestState {
        let mut values = BTreeMap::new();
        values.insert("input".to_string(), input);
        RequestState {
            values,
            node_idx: 0,
            metrics: InferenceMetrics::default(),
            t_start: Instant::now(),
        }
    }
}

/// One request's slice of an in-flight round: its own decoder (fed the
/// request's chunk of every batched reply), remainder piece, and layer
/// metrics.
struct ActivePart {
    request: u64,
    decoder: Box<dyn coding::Decoder>,
    remainder: Option<Tensor>,
    lm: LayerMetrics,
}

/// One in-flight coded round: a distributed conv of one *or several
/// coalesced* requests whose subtasks are out on the pool. All parts
/// share the round's subtask set — every reply carries every part's
/// chunk — so their decoders become ready at the same completion and
/// the whole batch finishes together.
struct ActiveRound {
    relu: bool,
    pr: PreparedRound,
    /// Per-request slices, in payload order.
    parts: Vec<ActivePart>,
    received: Vec<usize>,
    outstanding: Vec<usize>,
    /// task id -> worker currently holding it (for cancel accounting).
    assigned: Vec<usize>,
    /// The round's dispatch set (re-dispatch stays inside it).
    targets: Vec<usize>,
    t_dispatch: Instant,
    /// Master-local seconds already spent (remainder convs, all parts).
    t_local: f64,
}

/// Least-loaded worker among `candidates`, lowest id on ties; avoids
/// `avoid` when there is a choice (re-dispatch should not go back to the
/// failing worker). `load` is keyed by stable worker id — a candidate
/// with no entry (just admitted) counts as idle.
fn pick_worker(
    load: &BTreeMap<usize, usize>,
    candidates: &[usize],
    avoid: Option<usize>,
) -> usize {
    let mut best = usize::MAX;
    let mut best_w = candidates[0];
    for &w in candidates {
        if Some(w) == avoid && candidates.len() > 1 {
            continue;
        }
        let l = load.get(&w).copied().unwrap_or(0);
        if l < best {
            best = l;
            best_w = w;
        }
    }
    best_w
}

/// Collects the batch wrapper's outcomes by submission index.
struct BatchSink {
    out: Vec<Option<Result<(Tensor, InferenceMetrics), ServeError>>>,
}

impl EngineSink for BatchSink {
    fn accept(&mut self, _req: super::server::ServerRequest) -> EngineRequest {
        unreachable!("batch mode starts draining; nothing can be submitted")
    }

    fn deliver(&mut self, id: u64, result: Result<(Tensor, InferenceMetrics), ServeError>) {
        self.out[id as usize] = Some(result);
    }
}

impl Master {
    /// Pipelined batch inference: seed the admission queue with every
    /// input, drain it, return results in input order.
    pub(super) fn infer_pipelined(
        &mut self,
        inputs: &[Tensor],
    ) -> Result<Vec<(Tensor, InferenceMetrics)>> {
        debug_assert!(!inputs.is_empty(), "infer_batch guards the empty case");
        let seed: Vec<EngineRequest> = inputs
            .iter()
            .enumerate()
            .map(|(i, input)| EngineRequest {
                id: i as u64,
                input: input.clone(),
                priority: 0,
                deadline: None,
            })
            .collect();
        let mut sink = BatchSink {
            out: (0..inputs.len()).map(|_| None).collect(),
        };
        self.serve_stream(
            seed,
            StreamOptions {
                max_concurrent: 0,
                draining: true,
            },
            &mut sink,
        )?;
        sink.out
            .into_iter()
            .enumerate()
            .map(|(i, o)| {
                match o.with_context(|| format!("request {i} was never delivered"))? {
                    Ok(pair) => Ok(pair),
                    Err(e) => bail!("request {i}: {e}"),
                }
            })
            .collect()
    }

    /// Should a request with this deadline be shed instead of started?
    fn shed_decision(&self, deadline: Option<Instant>) -> Option<ServeError> {
        let d = deadline?;
        let remaining = d.saturating_duration_since(Instant::now()).as_secs_f64();
        if remaining <= 0.0 {
            // Expired in the queue: serving it late helps nobody.
            return Some(ServeError::DeadlineShed {
                predicted_secs: 0.0,
                remaining_secs: 0.0,
            });
        }
        if let Some(predicted) = self.predicted_service_secs() {
            if predicted > remaining {
                return Some(ServeError::DeadlineShed {
                    predicted_secs: predicted,
                    remaining_secs: remaining,
                });
            }
        }
        None
    }

    /// The engine's continuous run loop: admit from the (priority,
    /// deadline, id) queue up to the concurrency limit, block on the
    /// event channel, advance requests as replies arrive, replan between
    /// rounds, exit when draining and empty. Both `infer_batch`
    /// (pre-seeded, draining) and the serving front-end (live
    /// submissions) run through here.
    ///
    /// Requests that reach a distributed conv are *staged*, and the loop
    /// flushes the staging buffer only after draining every
    /// already-queued event — admissions that arrive in one burst, and
    /// coalesced batches that finish a layer together, therefore meet in
    /// the same flush and merge into coalesced rounds
    /// (`MasterConfig::coalesce`).
    pub(super) fn serve_stream(
        &mut self,
        seed: Vec<EngineRequest>,
        opts: StreamOptions,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        let nodes = self.model.nodes.clone();
        // Outstanding-reply charge per *stable worker id*. Seeded from
        // the current membership; joins insert, evictions remove.
        let mut worker_load: BTreeMap<usize, usize> =
            self.workers.keys().map(|&w| (w, 0)).collect();
        let mut rounds: HashMap<u64, ActiveRound> = HashMap::new();
        let mut active: BTreeMap<u64, RequestState> = BTreeMap::new();
        let mut pending: BinaryHeap<Pending> = seed.into_iter().map(Pending::new).collect();
        let mut staged: Vec<u64> = Vec::new();
        let mut draining = opts.draining;

        loop {
            // -- admission: start the most urgent pending requests ----
            while !pending.is_empty()
                && (opts.max_concurrent == 0 || active.len() < opts.max_concurrent)
            {
                let req = pending.pop().unwrap().req;
                if let Some(err) = self.shed_decision(req.deadline) {
                    sink.deliver(req.id, Err(err));
                    continue;
                }
                active.insert(req.id, RequestState::new(req.input));
                self.advance_request(req.id, &nodes, &mut active, &mut staged, sink)?;
            }

            // -- flush staged dispatches (coalescing same-layer shards)
            self.dispatch_staged(
                &mut staged,
                &nodes,
                &mut active,
                &mut rounds,
                &mut worker_load,
            )?;
            if draining && pending.is_empty() && active.is_empty() {
                debug_assert!(rounds.is_empty());
                return Ok(());
            }

            // Liveness: a round with nothing outstanding can never decode.
            for ar in rounds.values() {
                if ar.outstanding.is_empty() && !ar.parts[0].decoder.ready() {
                    bail!(
                        "layer {} (requests {:?}): no outstanding subtasks but decoder \
                         needs more (received {} of {})",
                        ar.parts[0].lm.node_id,
                        ar.parts.iter().map(|p| p.request).collect::<Vec<_>>(),
                        ar.received.len(),
                        ar.pr.scheme.min_completions()
                    );
                }
            }

            // -- block for the next event -----------------------------
            // An empty `rounds` means nothing is out on the pool: wait
            // (without a wedge timeout) for a submission, the drain
            // signal, or a membership event. Requests may still be
            // staged here — an empty (or fully-retiring) pool parks
            // them until a worker joins.
            let ev = if rounds.is_empty() {
                self.events.recv().context("master event channel closed")?
            } else {
                self.events
                    .recv_timeout(self.config.recv_timeout)
                    .context("pipelined engine: timed out waiting for workers")?
            };
            self.on_event(
                ev,
                &mut draining,
                &nodes,
                &mut pending,
                &mut active,
                &mut rounds,
                &mut worker_load,
                &mut staged,
                sink,
            )?;
            // Opportunistically drain whatever else is already queued
            // before the next flush: replies/submissions that landed
            // together stage together, which is what lets their rounds
            // coalesce.
            while let Ok(ev) = self.events.try_recv() {
                self.on_event(
                    ev,
                    &mut draining,
                    &nodes,
                    &mut pending,
                    &mut active,
                    &mut rounds,
                    &mut worker_load,
                    &mut staged,
                    sink,
                )?;
            }
            // Retiring members finalize (Shutdown + removal) only once
            // every charge against them has drained — a nonzero load
            // means replies (possibly stale Outputs of cancelled work)
            // are still owed.
            let busy: BTreeSet<usize> = worker_load
                .iter()
                .filter(|(_, &l)| l > 0)
                .map(|(&w, _)| w)
                .collect();
            self.finalize_retiring(&busy);
            worker_load.retain(|w, _| self.workers.contains_key(w));
        }
    }

    /// Fold one multiplexed event into the engine state.
    #[allow(clippy::too_many_arguments)]
    fn on_event(
        &mut self,
        ev: MasterEvent,
        draining: &mut bool,
        nodes: &[Node],
        pending: &mut BinaryHeap<Pending>,
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        match ev {
            MasterEvent::Submit(sreq) => {
                if *draining {
                    // Lost the race with drain(): refuse, don't hang.
                    sreq.reject();
                } else {
                    pending.push(Pending::new(sink.accept(sreq)));
                }
                Ok(())
            }
            MasterEvent::Drain => {
                *draining = true;
                Ok(())
            }
            MasterEvent::Joined { id, name, tx } => {
                self.admit_worker(id, name, tx);
                worker_load.insert(id, 0);
                // Staged requests parked on an empty pool flush on the
                // next loop iteration now that a target exists.
                self.probe_worker(id, worker_load)
            }
            MasterEvent::LinkDown(wid) => {
                if !self.drop_worker(wid) {
                    return Ok(()); // double-fire: already evicted
                }
                worker_load.remove(&wid);
                self.redispatch_orphans(wid, rounds, worker_load)
            }
            MasterEvent::Reply(wid, msg, arrival) => self.handle_reply(
                wid,
                msg,
                arrival,
                nodes,
                active,
                rounds,
                worker_load,
                staged,
                sink,
            ),
        }
    }

    /// Dispatch a one-subtask probe round to a just-joined worker: the
    /// registry needs real (exec, transmission) samples before the
    /// adaptive policy can place or judge it. The round is logged for
    /// telemetry and immediately retired — its Output reply takes the
    /// stale-reply path (`record_output` still feeds the registry; the
    /// engine holds no `ActiveRound` for it, so the data is dropped).
    fn probe_worker(
        &mut self,
        id: usize,
        worker_load: &mut BTreeMap<usize, usize>,
    ) -> Result<()> {
        let Some(c) = self.plan.convs.iter().find(|c| c.distributed).cloned() else {
            return Ok(()); // nothing distributed: nothing worth probing
        };
        let spec = c.dims.spec;
        let h = c.dims.h_i - 2 * spec.pad;
        let w = c.dims.w_i - 2 * spec.pad;
        let input = Tensor::from_vec(spec.c_in, h, w, vec![0.5; spec.c_in * h * w])?;
        // u64::MAX marks the probe's pseudo-request; no decoder ever
        // sees it. n = k = 1: the smallest real subtask on this layer.
        let pr = self.prepare_round(&[(u64::MAX, &input)], &c.node_id, &spec, 1, 1)?;
        let dispatched_at: Vec<Instant> = pr.frames.iter().map(|_| Instant::now()).collect();
        *worker_load.entry(id).or_insert(0) += pr.frames.len();
        for frame in &pr.frames {
            self.send_to(id, frame);
        }
        self.log_round(pr.round, pr.flops_per_task, pr.bytes_per_task, dispatched_at);
        self.retire_round(pr.round);
        log::debug!("worker {id}: probe round {} dispatched", pr.round);
        Ok(())
    }

    /// A member died mid-flight: every outstanding subtask it held is
    /// orphaned. Re-dispatch each one inside its round's (shrunken)
    /// dispatch set, exactly like a `Failed` reply — the round decodes
    /// from whichever k subtasks land first, so churn costs latency, not
    /// correctness.
    fn redispatch_orphans(
        &mut self,
        wid: usize,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
    ) -> Result<()> {
        for (&round, ar) in rounds.iter_mut() {
            ar.targets.retain(|&w| w != wid);
            let orphaned: Vec<usize> = ar
                .outstanding
                .iter()
                .copied()
                .filter(|&t| ar.assigned[t] == wid)
                .collect();
            if orphaned.is_empty() {
                continue;
            }
            let assigned = &ar.assigned;
            ar.outstanding.retain(|&t| assigned[t] != wid);
            for p in &mut ar.parts {
                p.lm.failures += orphaned.len();
            }
            for t in orphaned {
                if !ar
                    .pr
                    .scheme
                    .needs_redispatch(t, &ar.received, &ar.outstanding)
                {
                    continue;
                }
                anyhow::ensure!(
                    !ar.targets.is_empty(),
                    "layer {} (round {round}): worker {wid} died and no live workers \
                     remain to take over its subtasks",
                    ar.parts[0].lm.node_id
                );
                let target = pick_worker(worker_load, &ar.targets, None);
                if let Some(rt) = self.round_log.get_mut(&round) {
                    rt.dispatched_at[t] = Instant::now();
                }
                self.send_to(target, &ar.pr.frames[t]);
                *worker_load.entry(target).or_insert(0) += 1;
                ar.assigned[t] = target;
                ar.outstanding.push(t);
                for p in &mut ar.parts {
                    p.lm.redispatches += 1;
                }
                log::warn!(
                    "pipeline: task {t} of round {round} orphaned by dead worker \
                     {wid}, re-dispatched to {target}"
                );
            }
        }
        Ok(())
    }

    /// Fold one worker reply into the engine state; finishes (and
    /// advances past) any round it completes.
    #[allow(clippy::too_many_arguments)]
    fn handle_reply(
        &mut self,
        wid: usize,
        msg: FromWorker,
        arrival: Instant,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        // Every dispatched subtask yields exactly one reply (Output,
        // Failed, or Skipped after a cancel), so the worker's load
        // charge is released here — at reply time, never earlier. A
        // cancelled-but-already-executing subtask therefore keeps its
        // worker charged until the stale Output actually arrives,
        // which is what keeps the straggler off the next wave's
        // least-loaded placement. Only subtask replies release charge:
        // heartbeats and membership messages never carried one.
        if matches!(
            msg,
            FromWorker::Output { .. } | FromWorker::Failed { .. } | FromWorker::Skipped { .. }
        ) {
            if let Some(l) = worker_load.get_mut(&wid) {
                *l = l.saturating_sub(1);
            }
        }
        match msg {
            FromWorker::Output {
                round,
                task_id,
                exec_secs,
                data,
                ..
            } => {
                let task_id = task_id as usize;
                // Telemetry first, even when the round already decoded
                // (a cancelled-but-executed straggler's stale Output is
                // the estimator's key sample). The round log's
                // flops/bytes scales are the *coalesced* totals, so a
                // batched reply's exec_secs normalizes to the same
                // per-FLOP sample a single-request conv would yield.
                let wp = self.record_output(wid, round, task_id, arrival, exec_secs);
                let ready = {
                    let Some(ar) = rounds.get_mut(&round) else {
                        return Ok(()); // stale: round decoded + cancelled earlier
                    };
                    ar.outstanding.retain(|&t| t != task_id);
                    let n_parts = ar.parts.len();
                    if let Some(wp) = wp {
                        // Attribute the batched subtask's wall time
                        // evenly across the coalesced requests so each
                        // request's per-worker breakdown sums sanely.
                        let share = 1.0 / n_parts as f64;
                        for p in &mut ar.parts {
                            p.lm.per_worker.push(WorkerPhase {
                                transmission: wp.transmission * share,
                                execution: wp.execution * share,
                                ..wp
                            });
                        }
                    }
                    // Fan the (possibly batched) output back out: chunk
                    // `i` belongs to part `i`'s decoder. Every part's
                    // decoder sees the same subtask ids, so readiness
                    // flips for all of them on the same reply.
                    let ready = if n_parts == 1 {
                        ar.parts[0].decoder.add(task_id, data)
                    } else {
                        let part_len = ar.pr.part_elems();
                        anyhow::ensure!(
                            data.len() == part_len * n_parts,
                            "round {round}: batched output {} != {} parts x {part_len}",
                            data.len(),
                            n_parts
                        );
                        let mut ready = true;
                        for (i, p) in ar.parts.iter_mut().enumerate() {
                            let r = p
                                .decoder
                                .add(task_id, data[i * part_len..(i + 1) * part_len].to_vec());
                            // Identical subtask sets ⇒ identical
                            // readiness; never finish before every
                            // part can decode.
                            ready = ready && r;
                        }
                        ready
                    };
                    if !ready {
                        ar.received.push(task_id);
                    }
                    ready
                };
                if ready {
                    let ar = rounds.remove(&round).unwrap();
                    self.finish_round(ar, nodes, active, staged, sink)?;
                    // Between rounds is the live stream's "between
                    // requests": swap the plan here if one is due.
                    self.maybe_replan();
                }
            }
            FromWorker::Skipped { round, task_id } => {
                // Normally stale by construction (Cancel is only sent
                // after a round decoded). Defensively unblock the round
                // if one ever arrives live.
                if let Some(ar) = rounds.get_mut(&round) {
                    ar.outstanding.retain(|&t| t != task_id as usize);
                }
            }
            FromWorker::Failed { round, task_id } => {
                let task_id = task_id as usize;
                // Symmetric with record_output: only rounds this master
                // still tracks count toward failure streaks.
                self.record_failed(wid, round);
                let Some(ar) = rounds.get_mut(&round) else {
                    return Ok(());
                };
                // Every coalesced request experienced this failure.
                for p in &mut ar.parts {
                    p.lm.failures += 1;
                }
                ar.outstanding.retain(|&t| t != task_id);
                if ar
                    .pr
                    .scheme
                    .needs_redispatch(task_id, &ar.received, &ar.outstanding)
                {
                    if ar.parts[0].lm.redispatches > 4 * ar.pr.frames.len() {
                        bail!(
                            "layer {}: re-dispatch storm; giving up",
                            ar.parts[0].lm.node_id
                        );
                    }
                    anyhow::ensure!(
                        !ar.targets.is_empty(),
                        "layer {}: task {task_id} failed and no live workers remain \
                         in the round's dispatch set",
                        ar.parts[0].lm.node_id
                    );
                    let target = pick_worker(worker_load, &ar.targets, Some(wid));
                    if let Some(rt) = self.round_log.get_mut(&round) {
                        rt.dispatched_at[task_id] = Instant::now();
                    }
                    self.send_to(target, &ar.pr.frames[task_id]);
                    *worker_load.entry(target).or_insert(0) += 1;
                    ar.assigned[task_id] = target;
                    ar.outstanding.push(task_id);
                    for p in &mut ar.parts {
                        p.lm.redispatches += 1;
                    }
                    log::debug!(
                        "pipeline: task {task_id} of round {round} failed on \
                         worker {wid}, re-dispatched to {target}"
                    );
                }
            }
            // Liveness signal only; the reader's read-timeout clock is
            // what it actually services.
            FromWorker::Heartbeat { .. } => {}
            // Graceful leave: stop dispatching to it; the main loop
            // finalizes (Shutdown + removal) once its charge drains.
            FromWorker::Retire => self.retire_worker(wid),
            FromWorker::Join { .. } => {
                bail!("unexpected Join from already-admitted worker {wid}")
            }
            FromWorker::Ready => bail!("unexpected Ready from worker {wid}"),
        }
        Ok(())
    }

    /// Execute request `id` forward from its cursor: type-2/simple ops
    /// run locally; the first distributed conv *stages* the request
    /// (the caller flushes staged rounds — possibly coalesced — via
    /// [`Master::dispatch_staged`]) and yields. A request that reaches
    /// the end of the graph is delivered to the sink and removed from
    /// the active set.
    fn advance_request(
        &mut self,
        id: u64,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        loop {
            if active[&id].node_idx >= nodes.len() {
                let mut st = active.remove(&id).unwrap();
                let last = nodes.last().unwrap();
                let out = st.values.remove(&last.id).context("missing model output")?;
                st.metrics.total_seconds = st.t_start.elapsed().as_secs_f64();
                sink.deliver(id, Ok((out, st.metrics)));
                return Ok(());
            }
            let node = &nodes[active[&id].node_idx];
            if let Op::Conv { .. } = &node.op {
                let dist = self
                    .plan
                    .conv(&node.id)
                    .map(|c| c.distributed)
                    .unwrap_or(false);
                if dist {
                    staged.push(id);
                    return Ok(()); // yield: dispatch_staged resumes us
                }
            }
            let fetched: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|i| active[&id].values.get(i).cloned().context("missing value"))
                .collect::<Result<_>>()?;
            let st = active.get_mut(&id).unwrap();
            let out = self.run_local_node(node, &fetched, &mut st.metrics)?;
            st.values.insert(node.id.clone(), out);
            st.node_idx += 1;
        }
    }

    /// Flush the staging buffer: group staged requests by (layer,
    /// input shape) in staging order, chunk groups at the coalescing
    /// limit, and dispatch each group as ONE coded round whose frames
    /// carry every member's shard. With `coalesce <= 1` every group is
    /// a singleton and dispatch behaves exactly like the uncoalesced
    /// engine.
    fn dispatch_staged(
        &mut self,
        staged: &mut Vec<u64>,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut BTreeMap<usize, usize>,
    ) -> Result<()> {
        if staged.is_empty() {
            return Ok(());
        }
        // No live members (elastic cluster before the first join, or
        // everyone retiring/evicted): park the staging buffer as-is. A
        // `Joined` event wakes the loop and the next flush drains it.
        if self.live_worker_ids().is_empty() {
            return Ok(());
        }
        let cap = self.config.coalesce.max(1);
        // Stable grouping: same layer cursor + same input shape, first
        // open group wins, groups close at `cap` members.
        let mut groups: Vec<(usize, (usize, usize, usize), Vec<u64>)> = Vec::new();
        for &id in staged.iter() {
            let st = active.get(&id).context("staged request not active")?;
            let node = &nodes[st.node_idx];
            let input = st
                .values
                .get(&node.inputs[0])
                .context("staged conv input missing")?;
            let key = (st.node_idx, (input.c, input.h, input.w));
            match groups
                .iter_mut()
                .find(|(ni, sh, ids)| (*ni, *sh) == key && ids.len() < cap)
            {
                Some((_, _, ids)) => ids.push(id),
                None => groups.push((key.0, key.1, vec![id])),
            }
        }
        staged.clear();

        for (node_idx, _, ids) in groups {
            let node = &nodes[node_idx];
            let (spec, relu) = match &node.op {
                Op::Conv { spec, relu } => (*spec, *relu),
                _ => bail!("staged request not at a conv node"),
            };
            let k_planned = self.plan.conv(&node.id).map(|c| c.k).unwrap_or(1);
            // Dispatch set for this round: the registry's active
            // workers under the adaptive policy (quarantined
            // stragglers sit out except for due probes), the full pool
            // otherwise.
            let targets = self.dispatch_targets();
            if targets.is_empty() {
                // Membership changed under us mid-flush: re-park this
                // group for the next flush.
                staged.extend(ids.iter().copied());
                continue;
            }
            let k_eff = self.effective_k(k_planned, targets.len());
            let reqs: Vec<(u64, &Tensor)> = ids
                .iter()
                .map(|rid| {
                    (
                        *rid,
                        active
                            .get(rid)
                            .and_then(|st| st.values.get(&node.inputs[0]))
                            .expect("validated during grouping"),
                    )
                })
                .collect();
            let mut pr = self.prepare_round(&reqs, &node.id, &spec, k_eff, targets.len())?;
            let t_dispatch = Instant::now();
            // Spread the round's shards over *distinct* workers (the
            // MDS resilience model assumes one shard per device),
            // least-loaded first; wrap only when a scheme issues more
            // subtasks than workers (LT).
            let mut order: Vec<usize> = targets.clone();
            order.sort_by_key(|&w| (worker_load.get(&w).copied().unwrap_or(0), w));
            let mut assigned = vec![0usize; pr.frames.len()];
            let mut dispatched_at = Vec::with_capacity(pr.frames.len());
            for (t, frame) in pr.frames.iter().enumerate() {
                let w = order[t % order.len()];
                dispatched_at.push(Instant::now());
                self.send_to(w, frame);
                *worker_load.entry(w).or_insert(0) += 1;
                assigned[t] = w;
            }
            self.log_round(pr.round, pr.flops_per_task, pr.bytes_per_task, dispatched_at);
            // Master-local remainder pieces while workers run (one per
            // coalesced request).
            let t0 = Instant::now();
            let prepared = std::mem::take(&mut pr.parts);
            let mut parts = Vec::with_capacity(prepared.len());
            for pp in prepared {
                let remainder = match &pp.remainder_input {
                    Some(piece) => Some(self.provider.conv(&spec, piece, &pr.params.weights)?),
                    None => None,
                };
                parts.push(ActivePart {
                    request: pp.request,
                    decoder: pr.scheme.decoder(),
                    remainder,
                    lm: pp.lm,
                });
            }
            let t_local = t0.elapsed().as_secs_f64();
            let outstanding: Vec<usize> = (0..pr.frames.len()).collect();
            rounds.insert(
                pr.round,
                ActiveRound {
                    relu,
                    pr,
                    parts,
                    received: Vec::new(),
                    outstanding,
                    assigned,
                    targets,
                    t_dispatch,
                    t_local,
                },
            );
        }
        Ok(())
    }

    /// A round just became decodable: cancel stragglers, decode every
    /// coalesced part, and advance each owning request (which stages
    /// their next rounds — coalesced batches move through the model in
    /// lockstep and re-coalesce at the next distributed layer).
    fn finish_round(
        &mut self,
        mut ar: ActiveRound,
        nodes: &[Node],
        active: &mut BTreeMap<u64, RequestState>,
        staged: &mut Vec<u64>,
        sink: &mut dyn EngineSink,
    ) -> Result<()> {
        // Cancel outstanding stragglers so worker queues drop them. Their
        // load charges are NOT released here: each cancelled subtask
        // still produces exactly one reply (a Skipped ack for queued
        // work, a stale Output for work already executing), and the
        // charge is released when that reply arrives.
        if !ar.outstanding.is_empty() {
            let frame = ToWorker::Cancel { round: ar.pr.round }.encode();
            let mut notified: BTreeSet<usize> = BTreeSet::new();
            for &t in &ar.outstanding {
                let w = ar.assigned[t];
                if notified.insert(w) {
                    // Evicted holders are a no-op inside send_to.
                    self.send_to(w, &frame);
                }
            }
            for p in &mut ar.parts {
                p.lm.cancelled += ar.outstanding.len();
            }
            ar.outstanding.clear();
        }
        let t_workers = ar.t_dispatch.elapsed().as_secs_f64() - ar.t_local;
        let t_local_share = ar.t_local / ar.parts.len() as f64;
        self.retire_round(ar.pr.round);

        let mut advanced = Vec::with_capacity(ar.parts.len());
        for mut part in std::mem::take(&mut ar.parts) {
            part.lm.t_workers = t_workers;

            let t0 = Instant::now();
            let decoded = part.decoder.decode()?;
            part.lm.t_decode = t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let out = assemble_output(&ar.pr, decoded, part.remainder.take(), ar.relu)?;
            part.lm.t_local = t_local_share + t0.elapsed().as_secs_f64();

            let id = part.request;
            let st = active.get_mut(&id).context("finished round for unknown request")?;
            let node_id = nodes[st.node_idx].id.clone();
            st.metrics.layers.push(part.lm);
            st.values.insert(node_id, out);
            st.node_idx += 1;
            advanced.push(id);
        }
        for id in advanced {
            self.advance_request(id, nodes, active, staged, sink)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn req(id: u64, priority: u8, deadline: Option<Instant>) -> Pending {
        Pending::new(EngineRequest {
            id,
            input: Tensor::zeros(1, 1, 1),
            priority,
            deadline,
        })
    }

    /// Admission order is (priority desc, deadline asc with None last,
    /// id asc) — the serving redesign's dispatch-order contract.
    #[test]
    fn pending_orders_by_priority_deadline_id() {
        let t0 = Instant::now();
        let mut heap = BinaryHeap::new();
        heap.push(req(0, 0, None));
        heap.push(req(1, 0, Some(t0 + Duration::from_secs(5))));
        heap.push(req(2, 1, None));
        heap.push(req(3, 1, Some(t0 + Duration::from_secs(9))));
        heap.push(req(4, 1, Some(t0 + Duration::from_secs(2))));
        heap.push(req(5, 0, None));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|p| p.req.id)).collect();
        assert_eq!(order, vec![4, 3, 2, 1, 0, 5]);
    }

    #[test]
    fn pick_worker_prefers_least_loaded_and_avoids() {
        // Keyed by stable worker id — ids need not be contiguous.
        let load: BTreeMap<usize, usize> = [(0, 3), (2, 2), (7, 0)].into_iter().collect();
        let all = [0, 2, 7];
        assert_eq!(pick_worker(&load, &all, None), 7);
        assert_eq!(pick_worker(&load, &all, Some(7)), 2);
        // A candidate with no load entry (just admitted) counts as idle.
        assert_eq!(pick_worker(&load, &[0, 9], None), 9);
        // A single candidate is used even if it should be avoided.
        assert_eq!(pick_worker(&load, &[2], Some(2)), 2);
    }
}
