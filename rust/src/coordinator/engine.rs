//! The pipelined execution engine.
//!
//! The round-barrier path (`Master::infer`) dispatches layer ℓ, blocks
//! until it decodes, then starts layer ℓ+1 — workers sit idle while the
//! master decodes/re-encodes, and exactly one request is served at a
//! time. This engine removes both stalls:
//!
//! * several inference requests are in flight at once, each advancing
//!   through the model graph independently;
//! * a distributed conv dispatches its encoded subtasks to the
//!   *least-loaded* workers and yields back to the event loop instead of
//!   blocking, so other requests' rounds keep the pool busy while this
//!   one waits, decodes, or re-encodes;
//! * the moment a round has its first `k` results, its outstanding
//!   straggler subtasks are cancelled ([`ToWorker::Cancel`]) so the
//!   per-worker queues (see `coordinator::worker`) drop them and free
//!   capacity for the next wave.
//!
//! A single request's latency is still bounded by its layer dependency
//! chain, so the speedup materialises as multi-request throughput — see
//! the `throughput` experiment in `bench::experiments` and the
//! `bench_e2e` driver.

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coding;
use crate::conv::Tensor;
use crate::model::{Node, Op};

use super::master::{assemble_output, Master, PreparedRound};
use super::messages::{FromWorker, ToWorker};
use super::metrics::InferenceMetrics;

/// One request's progress through the model graph.
struct RequestState {
    values: BTreeMap<String, Tensor>,
    /// Next node to execute (all earlier nodes are in `values`).
    node_idx: usize,
    metrics: InferenceMetrics,
    t_start: Instant,
    output: Option<Tensor>,
}

/// One in-flight coded round: a distributed conv of one request whose
/// subtasks are out on the pool.
struct ActiveRound {
    request: usize,
    relu: bool,
    pr: PreparedRound,
    decoder: Box<dyn coding::Decoder>,
    remainder: Option<Tensor>,
    received: Vec<usize>,
    outstanding: Vec<usize>,
    /// task id -> worker currently holding it (for cancel accounting).
    assigned: Vec<usize>,
    /// The round's dispatch set (re-dispatch stays inside it).
    targets: Vec<usize>,
    t_dispatch: Instant,
    /// Master-local seconds already spent (remainder conv).
    t_local: f64,
}

/// Least-loaded worker among `candidates`, lowest index on ties; avoids
/// `avoid` when there is a choice (re-dispatch should not go back to the
/// failing worker).
fn pick_worker(load: &[usize], candidates: &[usize], avoid: Option<usize>) -> usize {
    let mut best = usize::MAX;
    let mut best_w = candidates[0];
    for &w in candidates {
        if Some(w) == avoid && candidates.len() > 1 {
            continue;
        }
        if load[w] < best {
            best = load[w];
            best_w = w;
        }
    }
    best_w
}

impl Master {
    /// Pipelined batch inference: every input in flight at once,
    /// multiplexed over the shared worker pool. Results come back in
    /// input order.
    pub(super) fn infer_pipelined(
        &mut self,
        inputs: &[Tensor],
    ) -> Result<Vec<(Tensor, InferenceMetrics)>> {
        anyhow::ensure!(!inputs.is_empty(), "empty inference batch");
        let nodes = self.model.nodes.clone();
        let mut worker_load = vec![0usize; self.n_workers()];
        let mut rounds: HashMap<u64, ActiveRound> = HashMap::new();
        let mut reqs: Vec<RequestState> = inputs
            .iter()
            .map(|input| {
                let mut values = BTreeMap::new();
                values.insert("input".to_string(), input.clone());
                RequestState {
                    values,
                    node_idx: 0,
                    metrics: InferenceMetrics::default(),
                    t_start: Instant::now(),
                    output: None,
                }
            })
            .collect();

        // Launch: run every request up to its first distributed round.
        for r in 0..reqs.len() {
            self.advance_request(r, &nodes, &mut reqs, &mut rounds, &mut worker_load)?;
        }

        while reqs.iter().any(|r| r.output.is_none()) {
            // Liveness: a round with nothing outstanding can never decode.
            for ar in rounds.values() {
                if ar.outstanding.is_empty() && !ar.decoder.ready() {
                    bail!(
                        "layer {} (request {}): no outstanding subtasks but decoder \
                         needs more (received {} of {})",
                        ar.pr.lm.node_id,
                        ar.request,
                        ar.received.len(),
                        ar.pr.scheme.min_completions()
                    );
                }
            }
            let (wid, msg, arrival) = self
                .from_workers
                .recv_timeout(self.config.recv_timeout)
                .context("pipelined engine: timed out waiting for workers")?;
            // Every dispatched subtask yields exactly one reply (Output,
            // Failed, or Skipped after a cancel), so the worker's load
            // charge is released here — at reply time, never earlier. A
            // cancelled-but-already-executing subtask therefore keeps its
            // worker charged until the stale Output actually arrives,
            // which is what keeps the straggler off the next wave's
            // least-loaded placement.
            if !matches!(msg, FromWorker::Ready) {
                worker_load[wid] = worker_load[wid].saturating_sub(1);
            }
            match msg {
                FromWorker::Output {
                    round,
                    task_id,
                    exec_secs,
                    data,
                    ..
                } => {
                    let task_id = task_id as usize;
                    // Telemetry first, even when the round already
                    // decoded (a cancelled-but-executed straggler's
                    // stale Output is the estimator's key sample).
                    let wp = self.record_output(wid, round, task_id, arrival, exec_secs);
                    let ready = {
                        let Some(ar) = rounds.get_mut(&round) else {
                            continue; // stale: round decoded + cancelled earlier
                        };
                        ar.outstanding.retain(|&t| t != task_id);
                        if let Some(wp) = wp {
                            ar.pr.lm.per_worker.push(wp);
                        }
                        if ar.decoder.add(task_id, data) {
                            true
                        } else {
                            ar.received.push(task_id);
                            false
                        }
                    };
                    if ready {
                        let ar = rounds.remove(&round).unwrap();
                        self.finish_round(ar, &nodes, &mut reqs, &mut rounds, &mut worker_load)?;
                        // Between rounds is the engine's "between
                        // requests": swap the plan here if one is due.
                        self.maybe_replan();
                    }
                }
                FromWorker::Skipped { round, task_id } => {
                    // Normally stale by construction (Cancel is only sent
                    // after a round decoded). Defensively unblock the
                    // round if one ever arrives live.
                    if let Some(ar) = rounds.get_mut(&round) {
                        ar.outstanding.retain(|&t| t != task_id as usize);
                    }
                }
                FromWorker::Failed { round, task_id } => {
                    let task_id = task_id as usize;
                    // Symmetric with record_output: only rounds this
                    // master still tracks count toward failure streaks.
                    self.record_failed(wid, round);
                    let Some(ar) = rounds.get_mut(&round) else {
                        continue;
                    };
                    ar.pr.lm.failures += 1;
                    ar.outstanding.retain(|&t| t != task_id);
                    if ar
                        .pr
                        .scheme
                        .needs_redispatch(task_id, &ar.received, &ar.outstanding)
                    {
                        if ar.pr.lm.redispatches > 4 * ar.pr.frames.len() {
                            bail!(
                                "layer {}: re-dispatch storm; giving up",
                                ar.pr.lm.node_id
                            );
                        }
                        let target = pick_worker(&worker_load, &ar.targets, Some(wid));
                        if let Some(rt) = self.round_log.get_mut(&round) {
                            rt.dispatched_at[task_id] = Instant::now();
                        }
                        self.worker_tx[target].send(&ar.pr.frames[task_id])?;
                        worker_load[target] += 1;
                        ar.assigned[task_id] = target;
                        ar.outstanding.push(task_id);
                        ar.pr.lm.redispatches += 1;
                        log::debug!(
                            "pipeline: task {task_id} of round {round} failed on \
                             worker {wid}, re-dispatched to {target}"
                        );
                    }
                }
                FromWorker::Ready => bail!("unexpected Ready from worker {wid}"),
            }
        }

        Ok(reqs
            .into_iter()
            .map(|mut r| (r.output.take().unwrap(), r.metrics))
            .collect())
    }

    /// Execute `reqs[req]` forward from its cursor: type-2/simple ops run
    /// locally; the first distributed conv dispatches a round and yields.
    fn advance_request(
        &mut self,
        req: usize,
        nodes: &[Node],
        reqs: &mut [RequestState],
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut [usize],
    ) -> Result<()> {
        loop {
            if reqs[req].node_idx >= nodes.len() {
                if reqs[req].output.is_none() {
                    let last = nodes.last().unwrap();
                    let out = reqs[req]
                        .values
                        .remove(&last.id)
                        .context("missing model output")?;
                    reqs[req].metrics.total_seconds =
                        reqs[req].t_start.elapsed().as_secs_f64();
                    reqs[req].output = Some(out);
                }
                return Ok(());
            }
            let node = &nodes[reqs[req].node_idx];
            let fetched: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|i| reqs[req].values.get(i).cloned().context("missing value"))
                .collect::<Result<_>>()?;
            match &node.op {
                Op::Conv { spec, relu } => {
                    let spec = *spec;
                    let relu = *relu;
                    let dist = self
                        .plan
                        .conv(&node.id)
                        .map(|c| (c.distributed, c.k))
                        .unwrap_or((false, 1));
                    if dist.0 {
                        // Dispatch set for this round: the registry's
                        // active workers under the adaptive policy
                        // (quarantined stragglers sit out except for due
                        // probes), the full pool otherwise.
                        let targets = self.dispatch_targets();
                        let k_eff = self.effective_k(dist.1, targets.len());
                        let pr = self.prepare_round(
                            req as u32,
                            &node.id,
                            &spec,
                            k_eff,
                            &fetched[0],
                            targets.len(),
                        )?;
                        let t_dispatch = Instant::now();
                        // Spread the round's shards over *distinct* workers
                        // (the MDS resilience model assumes one shard per
                        // device), least-loaded first; wrap only when a
                        // scheme issues more subtasks than workers (LT).
                        let mut order: Vec<usize> = targets.clone();
                        order.sort_by_key(|&w| (worker_load[w], w));
                        let mut assigned = vec![0usize; pr.frames.len()];
                        let mut dispatched_at = Vec::with_capacity(pr.frames.len());
                        for (t, frame) in pr.frames.iter().enumerate() {
                            let w = order[t % order.len()];
                            dispatched_at.push(Instant::now());
                            self.worker_tx[w].send(frame)?;
                            worker_load[w] += 1;
                            assigned[t] = w;
                        }
                        self.log_round(
                            pr.round,
                            pr.flops_per_task,
                            pr.bytes_per_task,
                            dispatched_at,
                        );
                        // Master-local remainder piece while workers run.
                        let t0 = Instant::now();
                        let remainder = match &pr.remainder_input {
                            Some(piece) => {
                                Some(self.provider.conv(&spec, piece, &pr.params.weights)?)
                            }
                            None => None,
                        };
                        let t_local = t0.elapsed().as_secs_f64();
                        let outstanding: Vec<usize> = (0..pr.frames.len()).collect();
                        let decoder = pr.scheme.decoder();
                        rounds.insert(
                            pr.round,
                            ActiveRound {
                                request: req,
                                relu,
                                pr,
                                decoder,
                                remainder,
                                received: Vec::new(),
                                outstanding,
                                assigned,
                                targets,
                                t_dispatch,
                                t_local,
                            },
                        );
                        return Ok(()); // yield: event loop resumes us
                    }
                    let out = self.run_local_node(node, &fetched, &mut reqs[req].metrics)?;
                    reqs[req].values.insert(node.id.clone(), out);
                    reqs[req].node_idx += 1;
                }
                _ => {
                    let out = self.run_local_node(node, &fetched, &mut reqs[req].metrics)?;
                    reqs[req].values.insert(node.id.clone(), out);
                    reqs[req].node_idx += 1;
                }
            }
        }
    }

    /// A round just became decodable: cancel stragglers, decode,
    /// reassemble, and advance the owning request.
    fn finish_round(
        &mut self,
        mut ar: ActiveRound,
        nodes: &[Node],
        reqs: &mut [RequestState],
        rounds: &mut HashMap<u64, ActiveRound>,
        worker_load: &mut [usize],
    ) -> Result<()> {
        // Cancel outstanding stragglers so worker queues drop them. Their
        // load charges are NOT released here: each cancelled subtask
        // still produces exactly one reply (a Skipped ack for queued
        // work, a stale Output for work already executing), and the
        // charge is released when that reply arrives.
        if !ar.outstanding.is_empty() {
            let frame = ToWorker::Cancel { round: ar.pr.round }.encode();
            let mut notified = vec![false; worker_load.len()];
            for &t in &ar.outstanding {
                let w = ar.assigned[t];
                if !notified[w] {
                    notified[w] = true;
                    self.worker_tx[w].send(&frame)?;
                }
            }
            ar.pr.lm.cancelled += ar.outstanding.len();
            ar.outstanding.clear();
        }
        ar.pr.lm.t_workers = ar.t_dispatch.elapsed().as_secs_f64() - ar.t_local;
        self.retire_round(ar.pr.round);

        let t0 = Instant::now();
        let decoded = ar.decoder.decode()?;
        ar.pr.lm.t_decode = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let out = assemble_output(&ar.pr, decoded, ar.remainder.take(), ar.relu)?;
        ar.pr.lm.t_local = ar.t_local + t0.elapsed().as_secs_f64();

        let req = ar.request;
        let node_id = nodes[reqs[req].node_idx].id.clone();
        reqs[req].metrics.layers.push(ar.pr.lm.clone());
        reqs[req].values.insert(node_id, out);
        reqs[req].node_idx += 1;
        self.advance_request(req, nodes, reqs, rounds, worker_load)
    }
}
