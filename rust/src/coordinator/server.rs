//! Streaming serving front-end: a non-blocking submit/handle API over
//! the pipelined engine, with bounded admission (backpressure), a
//! (priority, deadline) dispatch order, deadline-based shedding, and
//! graceful drain/shutdown.
//!
//! The engine thread owns the [`Master`]; [`InferenceServer::submit`]
//! injects requests into the master's event channel (the same one that
//! carries worker replies), so admission happens *between* event-loop
//! iterations of the live run loop — nothing blocks, and requests can
//! arrive while earlier ones are still in flight. `SubmitError::QueueFull`
//! is the backpressure signal: the bounded admission count covers every
//! accepted-but-undelivered request.
//!
//! ```text
//! let (master, workers) = LocalCluster::spawn(...)?.into_parts();
//! let server = InferenceServer::start(master, ServerConfig::default());
//! let handle = server.submit(InferenceRequest::new(input))?; // non-blocking
//! ...                                                        // submit more
//! let (out, metrics) = handle.wait()?;                       // any order
//! let master = server.shutdown()?;                           // drain + stop
//! master.shutdown();
//! workers.join()?;
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::conv::Tensor;
use crate::obs::{export, MetricsHub};

use super::engine::{EngineRequest, EngineSink, StreamOptions};
use super::fair::DEFAULT_TENANT;
use super::master::{ExecMode, Master, MasterEvent};
use super::metrics::InferenceMetrics;

/// One serving request: the input plus its scheduling contract.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub input: Tensor,
    /// Larger = more urgent. Dispatch order is (priority, deadline,
    /// submission order).
    pub priority: u8,
    /// Completion budget relative to submission. A request that has (or
    /// is predicted to have — see `Master::predicted_service_secs`) no
    /// chance of meeting it is shed at dispatch instead of served late.
    pub deadline: Option<Duration>,
    /// Tenant the request bills to: quota admission, DRR fair-share
    /// scheduling, and per-tenant metrics all key on this. Defaults to
    /// [`DEFAULT_TENANT`].
    pub tenant: String,
}

impl InferenceRequest {
    pub fn new(input: Tensor) -> InferenceRequest {
        InferenceRequest {
            input,
            priority: 0,
            deadline: None,
            tenant: DEFAULT_TENANT.to_string(),
        }
    }

    pub fn with_priority(mut self, priority: u8) -> InferenceRequest {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> InferenceRequest {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_tenant(mut self, tenant: &str) -> InferenceRequest {
        self.tenant = tenant.to_string();
        self
    }
}

/// Why a submission was refused (nothing was admitted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is at capacity — the backpressure
    /// signal. Retry after some in-flight request completes.
    QueueFull,
    /// The submitting tenant is at its per-tenant open-request quota
    /// ([`ServerConfig::tenant_quota`]); other tenants may still be
    /// admitted. Retry after one of this tenant's requests completes.
    TenantQuota,
    /// The server is draining, shut down, or its engine died.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::TenantQuota => write!(f, "tenant at open-request quota"),
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an *admitted* request produced no output.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Shed at dispatch: the deadline had expired, or the predicted
    /// service time (telemetry-fitted profile, adaptive mode) exceeded
    /// the remaining budget.
    DeadlineShed {
        predicted_secs: f64,
        remaining_secs: f64,
    },
    /// The submission lost the race with drain()/shutdown().
    Rejected,
    /// The engine terminated before delivering this request.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineShed {
                predicted_secs,
                remaining_secs,
            } => write!(
                f,
                "shed: predicted {predicted_secs:.3}s exceeds the {remaining_secs:.3}s \
                 remaining to the deadline"
            ),
            ServeError::Rejected => write!(f, "rejected: server draining"),
            ServeError::Engine(e) => write!(f, "engine failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Terminal outcome of one admitted request.
pub type ServeResult = Result<(Tensor, InferenceMetrics), ServeError>;

/// Server tuning.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Bound on admitted-but-undelivered requests; submissions beyond it
    /// get [`SubmitError::QueueFull`].
    pub queue_capacity: usize,
    /// Max requests advancing through the engine concurrently (0 =
    /// unlimited); the rest wait in the admission queue in (priority,
    /// deadline, id) order.
    pub max_concurrent: usize,
    /// Per-tenant bound on open (admitted-but-undelivered) requests;
    /// a tenant at its quota gets [`SubmitError::TenantQuota`] while
    /// other tenants keep being admitted. 0 = unlimited (the default:
    /// single-tenant behaviour is unchanged).
    pub tenant_quota: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            queue_capacity: 64,
            max_concurrent: 0,
            tenant_quota: 0,
        }
    }
}

/// Counters shared between the front-end and the engine sink.
#[derive(Default)]
struct Counters {
    /// Admitted, not yet delivered (the bounded-queue occupancy).
    open: usize,
    accepting: bool,
    engine_dead: bool,
    /// Root cause when `engine_dead` (error chain, or "panicked").
    dead_reason: Option<String>,
    submitted: u64,
    completed: u64,
    /// Deadline sheds only.
    shed: u64,
    /// Admitted requests terminated for any other reason (lost the race
    /// with drain(), engine failure).
    failed: u64,
    rejected_queue_full: u64,
    rejected_tenant_quota: u64,
    /// Open requests per tenant — what [`ServerConfig::tenant_quota`]
    /// is enforced against.
    open_by_tenant: HashMap<String, usize>,
}

struct Shared {
    state: Mutex<Counters>,
    /// Signalled on every delivery (drain() waits on it).
    delivered: Condvar,
    /// Mirror of the per-tenant admission meters (`cocoi_tenant_*`
    /// scrape families) — the engine hub, shared with the master.
    hub: MetricsHub,
}

impl Shared {
    fn new(hub: MetricsHub) -> Shared {
        Shared {
            state: Mutex::new(Counters {
                accepting: true,
                ..Default::default()
            }),
            delivered: Condvar::new(),
            hub,
        }
    }

    /// Close out one open request and wake any drain() waiter.
    fn finish(&self, outcome: &ServeResult, tenant: &str) {
        let mut st = self.state.lock().unwrap();
        st.open = st.open.saturating_sub(1);
        if let Some(n) = st.open_by_tenant.get_mut(tenant) {
            *n = n.saturating_sub(1);
        }
        match outcome {
            Ok(_) => st.completed += 1,
            Err(ServeError::DeadlineShed { .. }) => st.shed += 1,
            Err(_) => st.failed += 1,
        }
        drop(st);
        {
            let mut h = self.hub.lock();
            let t = h.tenant(tenant);
            t.open = t.open.saturating_sub(1);
        }
        self.delivered.notify_all();
    }

    /// Mark the engine dead and release every waiter — MUST run on any
    /// engine-thread exit that leaves requests undelivered, including
    /// panics (see [`EngineGuard`]), or drain()/shutdown() would block
    /// forever on the Condvar with no waker left alive. The first
    /// recorded reason wins (the Err path records the root cause before
    /// the guard's generic "panicked" would).
    fn mark_engine_dead(&self, reason: &str) {
        // Poison-tolerant: the panic may have happened inside a lock.
        let mut st = match self.state.lock() {
            Ok(st) => st,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.accepting = false;
        st.engine_dead = true;
        if st.dead_reason.is_none() {
            st.dead_reason = Some(reason.to_string());
        }
        st.open = 0;
        st.open_by_tenant.clear();
        drop(st);
        // The hub mutex may itself be poisoned by the same panic.
        let mut h = self.hub.lock_recover();
        for t in h.tenants.values_mut() {
            t.open = 0;
        }
        drop(h);
        self.delivered.notify_all();
    }
}

/// Unwind-safety for the engine thread: if `serve_stream` exits without
/// the guard being disarmed — an `Err` *or* a panic — the shared state
/// is marked dead so `drain()`/`shutdown()` return instead of hanging.
/// (Pending handles observe their reply senders dropping either way.)
struct EngineGuard {
    shared: Arc<Shared>,
    disarm: bool,
}

impl Drop for EngineGuard {
    fn drop(&mut self) {
        if !self.disarm {
            self.shared.mark_engine_dead("serve-engine thread panicked");
        }
    }
}

/// A submission accepted into the admission queue — the wire between
/// [`InferenceServer::submit`] and the engine loop.
pub(super) struct ServerRequest {
    pub(super) id: u64,
    pub(super) input: Tensor,
    pub(super) priority: u8,
    pub(super) deadline: Option<Instant>,
    /// Tenant the request bills to (quota + DRR + metrics key).
    pub(super) tenant: String,
    /// Stamped in `submit`; the engine's queue-wait and sojourn
    /// histograms (and the trace root span) measure from here.
    pub(super) submitted_at: Instant,
    /// Terminal result + the engine-stamped completion instant, so
    /// sojourn measurements don't depend on when the caller polls.
    reply: mpsc::Sender<(ServeResult, Instant)>,
    shared: Arc<Shared>,
}

impl ServerRequest {
    /// Terminal refusal for submissions that lost the race with
    /// drain()/shutdown(); keeps the open-count accounting exact.
    pub(super) fn reject(self) {
        let outcome: ServeResult = Err(ServeError::Rejected);
        let _ = self.reply.send((outcome.clone(), Instant::now()));
        self.shared.finish(&outcome, &self.tenant);
    }
}

/// Routes engine outcomes to the per-request reply channels and keeps
/// the admission accounting.
struct ServerSink {
    shared: Arc<Shared>,
    replies: HashMap<u64, mpsc::Sender<(ServeResult, Instant)>>,
    /// id → tenant, so `deliver` can close out the right quota slot.
    tenants: HashMap<u64, String>,
}

impl EngineSink for ServerSink {
    fn accept(&mut self, req: ServerRequest) -> EngineRequest {
        let ServerRequest {
            id,
            input,
            priority,
            deadline,
            tenant,
            submitted_at,
            reply,
            shared: _,
        } = req;
        self.replies.insert(id, reply);
        self.tenants.insert(id, tenant.clone());
        EngineRequest {
            id,
            input,
            priority,
            deadline,
            tenant,
            submitted_at,
        }
    }

    fn deliver(&mut self, id: u64, result: ServeResult) {
        let completed_at = Instant::now();
        let tenant = self
            .tenants
            .remove(&id)
            .unwrap_or_else(|| DEFAULT_TENANT.to_string());
        self.shared.finish(&result, &tenant);
        if let Some(tx) = self.replies.remove(&id) {
            let _ = tx.send((result, completed_at)); // receiver may be gone
        }
    }
}

/// Completion handle for one submitted request.
pub struct RequestHandle {
    id: u64,
    submitted_at: Instant,
    rx: mpsc::Receiver<(ServeResult, Instant)>,
    received: Option<ServeResult>,
    completed_at: Option<Instant>,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn submitted_at(&self) -> Instant {
        self.submitted_at
    }

    fn store(&mut self, result: ServeResult, completed_at: Instant) {
        self.received = Some(result);
        self.completed_at = Some(completed_at);
    }

    /// Non-blocking poll: `Some(&result)` once the request reached a
    /// terminal state (buffered — repeat calls keep returning it),
    /// `None` while it is still queued or executing.
    pub fn try_wait(&mut self) -> Option<&ServeResult> {
        if self.received.is_none() {
            match self.rx.try_recv() {
                Ok((r, at)) => self.store(r, at),
                Err(mpsc::TryRecvError::Empty) => return None,
                Err(mpsc::TryRecvError::Disconnected) => self.store(
                    Err(ServeError::Engine("engine terminated before delivering".into())),
                    Instant::now(),
                ),
            }
        }
        self.received.as_ref()
    }

    /// Submission → completion, engine-stamped (exact even when the
    /// result is collected much later). `None` until a terminal state
    /// has been observed via `try_wait`.
    pub fn sojourn(&self) -> Option<Duration> {
        self.completed_at
            .map(|at| at.saturating_duration_since(self.submitted_at))
    }

    /// Block until the request completes (or is shed).
    pub fn wait(self) -> ServeResult {
        self.wait_timed().0
    }

    /// Block until the request completes; also return the
    /// engine-stamped submission→completion sojourn. Exact regardless
    /// of when (or in what order) handles are awaited, so latency
    /// percentiles carry no collection-loop error.
    pub fn wait_timed(mut self) -> (ServeResult, Duration) {
        if self.received.is_none() {
            match self.rx.recv() {
                Ok((r, at)) => self.store(r, at),
                Err(_) => self.store(
                    Err(ServeError::Engine("engine terminated before delivering".into())),
                    Instant::now(),
                ),
            }
        }
        let sojourn = self.sojourn().unwrap();
        (self.received.take().unwrap(), sojourn)
    }
}

/// Point-in-time serving counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub submitted: u64,
    pub completed: u64,
    /// Deadline sheds only.
    pub shed: u64,
    /// Admitted requests terminated for any other reason (drain race,
    /// engine failure).
    pub failed: u64,
    pub rejected_queue_full: u64,
    /// Submissions refused by a per-tenant quota (all tenants).
    pub rejected_tenant_quota: u64,
    /// Admitted but not yet delivered.
    pub open: usize,
}

/// The streaming serving front-end (see the module docs).
pub struct InferenceServer {
    tx: mpsc::Sender<MasterEvent>,
    shared: Arc<Shared>,
    capacity: usize,
    /// Per-tenant open-request bound (0 = unlimited).
    tenant_quota: usize,
    next_id: AtomicU64,
    /// The master's metrics hub, captured before the master moves onto
    /// the engine thread — `scrape()` reads it live, no engine round-trip.
    hub: MetricsHub,
    engine: Option<std::thread::JoinHandle<Result<Master>>>,
}

impl InferenceServer {
    /// Take ownership of `master` and start the serving loop on a
    /// dedicated engine thread. Serving always runs the pipelined run
    /// loop; a `RoundBarrier`-mode master is served with one request in
    /// flight at a time (the sequential baseline).
    pub fn start(master: Master, config: ServerConfig) -> InferenceServer {
        let tx = master.event_sender();
        let hub = master.metrics_hub();
        let shared = Arc::new(Shared::new(hub.clone()));
        let max_concurrent = if master.config().mode == ExecMode::RoundBarrier {
            1
        } else {
            config.max_concurrent
        };
        let engine_shared = shared.clone();
        let engine = std::thread::Builder::new()
            .name("cocoi-serve".into())
            .spawn(move || -> Result<Master> {
                let mut master = master;
                // Armed until a clean exit: an Err return *or a panic*
                // anywhere below marks the engine dead so
                // submit()/drain()/shutdown() callers are unblocked
                // (dropping the sink drops every reply sender, so
                // pending handles observe the disconnect too).
                let mut guard = EngineGuard {
                    shared: engine_shared,
                    disarm: false,
                };
                let mut sink = ServerSink {
                    shared: guard.shared.clone(),
                    replies: HashMap::new(),
                    tenants: HashMap::new(),
                };
                match master.serve_stream(
                    Vec::new(),
                    StreamOptions {
                        max_concurrent,
                        draining: false,
                    },
                    &mut sink,
                ) {
                    Ok(()) => {
                        guard.disarm = true;
                        Ok(master)
                    }
                    Err(e) => {
                        // Record + log the root cause (handles only see
                        // a generic disconnect); the still-armed guard
                        // does the waiter-release bookkeeping.
                        log::error!("serve engine failed: {e:#}");
                        guard.shared.mark_engine_dead(&format!("{e:#}"));
                        Err(e)
                    }
                }
            })
            .expect("spawn serve-engine thread");
        InferenceServer {
            tx,
            shared,
            capacity: config.queue_capacity.max(1),
            tenant_quota: config.tenant_quota,
            next_id: AtomicU64::new(0),
            hub,
            engine: Some(engine),
        }
    }

    /// Non-blocking submission. `Err(QueueFull)` / `Err(TenantQuota)`
    /// are backpressure — nothing was admitted; retry after a
    /// completion (of anything / of this tenant's, respectively).
    pub fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let submitted_at = Instant::now();
        let tenant = req.tenant;
        {
            let mut st = self.shared.state.lock().unwrap();
            if !st.accepting || st.engine_dead {
                return Err(SubmitError::ShuttingDown);
            }
            if st.open >= self.capacity {
                st.rejected_queue_full += 1;
                return Err(SubmitError::QueueFull);
            }
            if self.tenant_quota > 0 {
                let tenant_open =
                    st.open_by_tenant.get(&tenant).copied().unwrap_or(0);
                if tenant_open >= self.tenant_quota {
                    st.rejected_tenant_quota += 1;
                    drop(st);
                    self.shared.hub.lock().tenant(&tenant).quota_rejections += 1;
                    return Err(SubmitError::TenantQuota);
                }
            }
            st.open += 1;
            st.submitted += 1;
            *st.open_by_tenant.entry(tenant.clone()).or_insert(0) += 1;
        }
        {
            let mut h = self.shared.hub.lock();
            let t = h.tenant(&tenant);
            t.submitted += 1;
            t.open += 1;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let sreq = ServerRequest {
            id,
            input: req.input,
            priority: req.priority,
            deadline: req.deadline.map(|d| submitted_at + d),
            tenant: tenant.clone(),
            submitted_at,
            reply,
            shared: self.shared.clone(),
        };
        log::debug!("server: req={id} submitted priority={} tenant={tenant}", sreq.priority);
        if self.tx.send(MasterEvent::Submit(sreq)).is_err() {
            // Engine gone; roll the admission back.
            let mut st = self.shared.state.lock().unwrap();
            st.open = st.open.saturating_sub(1);
            st.submitted -= 1;
            if let Some(n) = st.open_by_tenant.get_mut(&tenant) {
                *n = n.saturating_sub(1);
            }
            drop(st);
            {
                let mut h = self.shared.hub.lock();
                let t = h.tenant(&tenant);
                t.submitted = t.submitted.saturating_sub(1);
                t.open = t.open.saturating_sub(1);
            }
            return Err(SubmitError::ShuttingDown);
        }
        Ok(RequestHandle {
            id,
            submitted_at,
            rx,
            received: None,
            completed_at: None,
        })
    }

    /// Why the engine died, if it has (`None` while healthy). The same
    /// root cause is logged at `error` level when it happens.
    pub fn failure(&self) -> Option<String> {
        self.shared.state.lock().unwrap().dead_reason.clone()
    }

    /// One unified metrics snapshot: server admission counters plus the
    /// engine/master hub (latency histograms + pool gauges), ready to
    /// render as Prometheus text exposition (`.to_prometheus()`) or JSON
    /// (`.to_json()`). Live — callable while requests are in flight.
    pub fn scrape(&self) -> export::Snapshot {
        let st = self.stats();
        let mut snap = export::Snapshot::new();
        snap.counter(
            "cocoi_server_submitted_total",
            "Requests accepted by submit().",
            st.submitted as f64,
        )
        .counter(
            "cocoi_server_completed_total",
            "Requests delivered successfully.",
            st.completed as f64,
        )
        .counter(
            "cocoi_server_shed_total",
            "Requests shed at dispatch (deadline).",
            st.shed as f64,
        )
        .counter(
            "cocoi_server_failed_total",
            "Admitted requests terminated abnormally.",
            st.failed as f64,
        )
        .counter(
            "cocoi_server_rejected_queue_full_total",
            "Submissions refused by backpressure.",
            st.rejected_queue_full as f64,
        )
        .gauge(
            "cocoi_server_open_requests",
            "Admitted but not yet delivered.",
            st.open as f64,
        );
        self.hub.export_into(&mut snap);
        snap
    }

    pub fn stats(&self) -> ServerStats {
        let st = self.shared.state.lock().unwrap();
        ServerStats {
            submitted: st.submitted,
            completed: st.completed,
            shed: st.shed,
            failed: st.failed,
            rejected_queue_full: st.rejected_queue_full,
            rejected_tenant_quota: st.rejected_tenant_quota,
            open: st.open,
        }
    }

    /// Stop accepting and block until every already-admitted request has
    /// been delivered (their handles still receive results).
    pub fn drain(&self) {
        self.shared.state.lock().unwrap().accepting = false;
        let mut st = self.shared.state.lock().unwrap();
        while st.open > 0 {
            st = self.shared.delivered.wait(st).unwrap();
        }
    }

    /// Drain, stop the engine loop, and hand the master back (so the
    /// caller can reuse it or shut the workers down).
    pub fn shutdown(mut self) -> Result<Master> {
        self.drain();
        let _ = self.tx.send(MasterEvent::Drain);
        let engine = self.engine.take().unwrap();
        engine
            .join()
            .map_err(|_| anyhow::anyhow!("serve-engine thread panicked"))?
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        if let Some(engine) = self.engine.take() {
            self.shared.state.lock().unwrap().accepting = false;
            let _ = self.tx.send(MasterEvent::Drain);
            // Don't silently eat the root cause on the drop path.
            match engine.join() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => log::error!("serve engine died: {e:#}"),
                Err(_) => log::error!("serve engine panicked"),
            }
        }
    }
}
