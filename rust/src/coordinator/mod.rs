//! The CoCoI coordinator (the paper's system contribution): master,
//! workers, wire messages, fault injection, metrics, and the local pool.

pub mod engine;
pub mod fair;
pub mod injector;
pub mod master;
pub mod messages;
pub mod metrics;
pub mod pool;
pub mod server;
pub mod worker;

pub use injector::{ScenarioFaults, WorkerFaults};
pub use master::{ExecMode, Master, MasterConfig, SchemeKind};
pub use metrics::{InferenceMetrics, LayerMetrics, WorkerPhase};
pub use pool::{LocalCluster, PoolOptions, WorkerHandles};
pub use server::{
    InferenceRequest, InferenceServer, RequestHandle, ServeError, ServeResult, ServerConfig,
    ServerStats, SubmitError,
};
pub use worker::{run_worker, run_worker_announcing, JoinOptions, WorkerConfig, WorkerExit};

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::conv::Tensor;
    use crate::model::graph::forward_local;
    use crate::model::{zoo, WeightStore};
    use crate::planner::SplitPolicy;
    use crate::runtime::FallbackProvider;
    use crate::util::Rng;

    fn random_input(seed: u64) -> Tensor {
        let mut t = Tensor::zeros(3, 56, 56);
        Rng::new(seed).fill_uniform_f32(&mut t.data, -1.0, 1.0);
        t
    }

    fn run_cluster(
        scheme: SchemeKind,
        n: usize,
        faults: Vec<WorkerFaults>,
        seed: u64,
    ) -> (Tensor, InferenceMetrics) {
        let config = MasterConfig {
            scheme,
            policy: SplitPolicy::Fixed(3),
            ..Default::default()
        };
        let mut cluster = LocalCluster::spawn(
            "tinyvgg",
            n,
            config,
            Arc::new(FallbackProvider::new()),
            faults,
        )
        .unwrap();
        let input = random_input(seed);
        let result = cluster.master.infer(&input).unwrap();
        cluster.shutdown().unwrap();
        result
    }

    fn local_reference(seed: u64) -> Tensor {
        let model = zoo::model("tinyvgg").unwrap();
        let weights = WeightStore::generate(&model, 42).unwrap();
        forward_local(&model, &weights, &random_input(seed)).unwrap()
    }

    /// THE system-level correctness statement: distributed coded inference
    /// must reproduce local inference (paper §II-B.4 "O can be perfectly
    /// restored ... keeping the inference quality unchanged").
    #[test]
    fn coded_inference_matches_local() {
        let want = local_reference(11);
        for scheme in [
            SchemeKind::Mds,
            SchemeKind::Uncoded,
            SchemeKind::Replication,
            SchemeKind::LtCoarse,
        ] {
            let (got, metrics) = run_cluster(
                scheme,
                4,
                (0..4).map(|_| WorkerFaults::none()).collect(),
                11,
            );
            assert_eq!(got.shape(), want.shape());
            let err = got.max_abs_diff(&want);
            assert!(
                err < 2e-2,
                "{:?}: distributed output differs from local by {err}",
                scheme
            );
            assert!(metrics.layers.iter().any(|l| l.distributed));
            assert_eq!(metrics.failures(), 0);
        }
    }

    /// MDS redundancy absorbs failures with zero re-dispatch; uncoded must
    /// re-dispatch every failed piece.
    #[test]
    fn failure_handling_per_scheme() {
        let want = local_reference(13);
        let n = 4;
        // Worker 2 fails every distributed round (tinyvgg has 6 convs; use
        // generous round coverage).
        let faults = |victim: usize| -> Vec<WorkerFaults> {
            (0..n)
                .map(|i| {
                    if i == victim {
                        WorkerFaults::none().fails_in(0..64)
                    } else {
                        WorkerFaults::none()
                    }
                })
                .collect()
        };

        let (got, metrics) = run_cluster(SchemeKind::Mds, n, faults(2), 13);
        assert!(got.max_abs_diff(&want) < 2e-2);
        assert!(metrics.failures() > 0);
        assert_eq!(
            metrics.redispatches(),
            0,
            "MDS with k=3, n=4 tolerates one failure without re-dispatch"
        );

        let (got, metrics) = run_cluster(SchemeKind::Uncoded, n, faults(1), 13);
        assert!(got.max_abs_diff(&want) < 2e-2);
        assert!(metrics.failures() > 0);
        assert!(
            metrics.redispatches() >= metrics.failures(),
            "uncoded must re-execute every failed piece"
        );
    }

    /// Replication tolerates the loss of one replica per pair.
    #[test]
    fn replication_survives_single_failure() {
        let want = local_reference(17);
        let n = 4;
        let faults = (0..n)
            .map(|i| {
                if i == 3 {
                    WorkerFaults::none().fails_in(0..64)
                } else {
                    WorkerFaults::none()
                }
            })
            .collect();
        let (got, metrics) = run_cluster(SchemeKind::Replication, n, faults, 17);
        assert!(got.max_abs_diff(&want) < 2e-2);
        assert!(metrics.failures() > 0);
    }

    /// tinyresnet exercises the DAG path (skip connections + downsamples).
    #[test]
    fn resnet_distributed_matches_local() {
        let model = zoo::model("tinyresnet").unwrap();
        let weights = WeightStore::generate(&model, 42).unwrap();
        let input = random_input(19);
        let want = forward_local(&model, &weights, &input).unwrap();

        let config = MasterConfig {
            scheme: SchemeKind::Mds,
            policy: SplitPolicy::Fixed(2),
            ..Default::default()
        };
        let mut cluster = LocalCluster::spawn(
            "tinyresnet",
            3,
            config,
            Arc::new(FallbackProvider::new()),
            (0..3).map(|_| WorkerFaults::none()).collect(),
        )
        .unwrap();
        let (got, _) = cluster.master.infer(&input).unwrap();
        cluster.shutdown().unwrap();
        assert_eq!(got.shape(), want.shape());
        assert!(got.max_abs_diff(&want) < 2e-2);
    }
}
