//! Tenant-fair admission ordering: deficit round robin (DRR) across
//! weighted per-tenant queues, with the caller's `Ord` (EDF: priority,
//! then earliest deadline, then id) deciding dispatch order *inside*
//! each tenant's turn.
//!
//! The serving front-end used one global `(priority, deadline)` heap,
//! so a single heavy tenant could starve everyone else — the ROADMAP's
//! multi-tenant failure mode. `DrrQueue` bounds that: each tenant holds
//! its own max-heap, tenants take turns in round-robin rotation, and a
//! tenant's turn lasts while its *deficit counter* covers another unit
//! of work. The counter is replenished by `weight` (the quantum) once
//! per turn, so over any window a backlogged tenant receives service
//! proportional to its weight — the classic DRR guarantee (Shreedhar &
//! Varghese) with unit-cost requests.
//!
//! Degenerate case, load-bearing for compatibility: with a single
//! tenant (any weight ≥ 1) the rotation is a self-loop and every pop
//! comes straight off that tenant's heap — the pop sequence is
//! *identical* to the old global `BinaryHeap`. The default config
//! (every request under the default tenant, weight 1) therefore
//! reproduces today's ordering bit for bit; `single_tenant_matches_heap`
//! pins it.

use std::collections::{BTreeMap, BinaryHeap, VecDeque};

/// Tenant id used when a request does not name one (plain
/// `InferenceRequest::new`, the batch path, internal probes).
pub const DEFAULT_TENANT: &str = "default";

/// Weight floor: a configured weight of 0 (or negative, from a hostile
/// config file) would make a tenant's turn never start; clamp instead
/// of erroring so a bad entry degrades to "minimum share", not a hang.
/// The floor also bounds `pop`'s rotate loop: a lone tenant at the
/// floor accumulates a full unit of deficit within ~1/MIN_WEIGHT
/// cheap iterations rather than spinning unboundedly.
const MIN_WEIGHT: f64 = 0.01;

/// Deficit-round-robin queue over per-tenant max-heaps.
///
/// `T`'s `Ord` must rank the most-urgent item greatest (same contract
/// as `BinaryHeap`); the engine's `Pending` (priority desc, deadline
/// asc, id asc) gives EDF-within-priority inside each tenant's turn.
pub struct DrrQueue<T: Ord> {
    /// Per-tenant heaps. A tenant is present iff it has ≥ 1 queued item.
    queues: BTreeMap<String, BinaryHeap<T>>,
    /// Round-robin rotation. Invariant: contains exactly the tenants
    /// present in `queues`, each once; the front tenant serves next.
    rotation: VecDeque<String>,
    /// Per-tenant quanta (weight, clamped to `MIN_WEIGHT`). Tenants not
    /// listed get weight 1.
    weights: BTreeMap<String, f64>,
    /// Deficit counters. Persist across turns while a tenant stays
    /// backlogged; reset to 0 when its queue empties (standard DRR —
    /// an idle tenant must not bank credit into a burst).
    deficit: BTreeMap<String, f64>,
    /// Tenant whose turn is in progress (== front of `rotation`), if
    /// its quantum has already been granted this turn. The quantum is
    /// added exactly once per turn: when the turn *begins*, not on
    /// every pop.
    granted: Option<String>,
    len: usize,
}

impl<T: Ord> DrrQueue<T> {
    /// Empty queue with the given `(tenant, weight)` table. Unlisted
    /// tenants get weight 1; weights are clamped to a small positive
    /// floor so a zero/negative entry cannot stall its tenant forever.
    pub fn new(weights: &[(String, f64)]) -> DrrQueue<T> {
        DrrQueue {
            queues: BTreeMap::new(),
            rotation: VecDeque::new(),
            weights: weights
                .iter()
                .map(|(t, w)| (t.clone(), w.max(MIN_WEIGHT)))
                .collect(),
            deficit: BTreeMap::new(),
            granted: None,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn quantum(&self, tenant: &str) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(1.0)
    }

    /// Enqueue `item` under `tenant`. A newly-seen (or newly re-active)
    /// tenant joins the *back* of the rotation with zero deficit — it
    /// cannot preempt the tenant currently mid-turn.
    pub fn push(&mut self, tenant: &str, item: T) {
        match self.queues.get_mut(tenant) {
            Some(q) => q.push(item),
            None => {
                let mut q = BinaryHeap::new();
                q.push(item);
                self.queues.insert(tenant.to_string(), q);
                self.rotation.push_back(tenant.to_string());
            }
        }
        self.len += 1;
    }

    /// Dequeue the next item under the DRR schedule: the front tenant's
    /// most urgent item while its deficit lasts, then rotate.
    pub fn pop(&mut self) -> Option<T> {
        loop {
            if self.len == 0 {
                return None;
            }
            let front = self.rotation.front()?.clone();
            // Lazy-cleanup guard; the main path removes emptied tenants
            // eagerly, so this only fires if an invariant ever slips.
            if !self.queues.contains_key(&front) {
                self.rotation.pop_front();
                if self.granted.as_deref() == Some(&front) {
                    self.granted = None;
                }
                continue;
            }
            if self.granted.as_deref() != Some(&front) {
                // Turn begins: grant the quantum exactly once.
                let q = self.quantum(&front);
                *self.deficit.entry(front.clone()).or_insert(0.0) += q;
                self.granted = Some(front.clone());
            }
            let d = self.deficit.get_mut(&front).expect("granted implies deficit");
            if *d >= 1.0 {
                *d -= 1.0;
                let heap = self.queues.get_mut(&front).expect("checked above");
                let item = heap.pop().expect("tenant in queues implies non-empty");
                self.len -= 1;
                if heap.is_empty() {
                    self.queues.remove(&front);
                    self.rotation.pop_front();
                    self.deficit.insert(front.clone(), 0.0);
                    self.granted = None;
                }
                return Some(item);
            }
            // Deficit exhausted: end the turn, rotate to the next tenant.
            self.granted = None;
            self.rotation.push_back(self.rotation.pop_front().expect("front exists"));
        }
    }
}

/// Deadline-aware coalescing policy (the PR 5 leftover): a request
/// whose remaining slack is under `TIGHT_SLACK_MULTIPLE` × the
/// predicted single-request service time must not be folded into (or
/// grown into) a wide coalesced batch — batching it behind other
/// requests' compute is exactly how a feasible deadline is missed.
/// `predicted_secs` is `None` until the adaptive profile has fitted
/// estimates; the floor keeps the policy meaningful before that.
pub const TIGHT_SLACK_MULTIPLE: f64 = 4.0;

/// Fallback predicted service time (seconds) before the capacity
/// registry has fitted per-layer estimates.
pub const UNFITTED_SERVICE_FLOOR_SECS: f64 = 0.5;

/// Is a request's deadline "tight" for coalescing purposes? `None`
/// slack (no deadline) is never tight.
pub fn tight_deadline(slack_secs: Option<f64>, predicted_secs: Option<f64>) -> bool {
    match slack_secs {
        Some(s) => s < TIGHT_SLACK_MULTIPLE * predicted_secs.unwrap_or(UNFITTED_SERVICE_FLOOR_SECS),
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drr_respects_weights_within_one_round() {
        // Weights a:2, b:1 → steady-state pop pattern a,a,b repeating.
        let mut q: DrrQueue<i64> =
            DrrQueue::new(&[("a".to_string(), 2.0), ("b".to_string(), 1.0)]);
        for i in 0..6 {
            q.push("a", 100 - i); // descending so heap order is insertion order
            q.push("b", 200 - i);
        }
        let mut owners = Vec::new();
        while let Some(v) = q.pop() {
            owners.push(if v >= 195 { 'b' } else { 'a' });
        }
        assert_eq!(owners, vec!['a', 'a', 'b', 'a', 'a', 'b', 'a', 'a', 'b', 'b', 'b', 'b']);
    }

    /// The compatibility keystone: a single tenant (the default config)
    /// pops in *exactly* the order the old global `BinaryHeap` did.
    #[test]
    fn single_tenant_matches_heap() {
        let items: Vec<i64> = vec![5, -3, 9, 9, 0, 7, -3, 12, 1];
        let mut heap: BinaryHeap<i64> = items.iter().copied().collect();
        let mut q: DrrQueue<i64> = DrrQueue::new(&[]);
        for &x in &items {
            q.push(DEFAULT_TENANT, x);
        }
        let mut want = Vec::new();
        while let Some(x) = heap.pop() {
            want.push(x);
        }
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        assert_eq!(got, want);
        assert!(q.is_empty());
    }

    #[test]
    fn idle_tenant_banks_no_credit() {
        // b drains, a keeps arriving; when b returns it gets its fair
        // share but no burst from the idle period.
        let mut q: DrrQueue<i64> =
            DrrQueue::new(&[("a".to_string(), 1.0), ("b".to_string(), 1.0)]);
        q.push("b", 0);
        assert_eq!(q.pop(), Some(0)); // b empties → deficit reset
        for i in 0..4 {
            q.push("a", 10 + i);
        }
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        q.push("b", 1);
        q.push("b", 2);
        // b re-joins with zero deficit and must alternate with a, not
        // burst both items at once. b goes first: a's current turn was
        // already spent by the setup pops above.
        let mut owners = Vec::new();
        while let Some(v) = q.pop() {
            owners.push(if v >= 10 { 'a' } else { 'b' });
        }
        assert_eq!(owners, vec!['b', 'a', 'b', 'a']);
    }

    #[test]
    fn zero_weight_is_clamped_not_starved() {
        let mut q: DrrQueue<i64> = DrrQueue::new(&[("z".to_string(), 0.0)]);
        q.push("z", 1);
        // MIN_WEIGHT per turn still accumulates to a pop eventually —
        // and with no competing tenant the rotation self-loops, so it
        // must terminate rather than spin forever.
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn tight_deadline_policy() {
        // Slack well over 4× predicted service: not tight.
        assert!(!tight_deadline(Some(10.0), Some(1.0)));
        // Slack under the multiple: tight.
        assert!(tight_deadline(Some(3.9), Some(1.0)));
        // No deadline: never tight.
        assert!(!tight_deadline(None, Some(0.001)));
        // Unfitted profile: the 0.5 s floor applies.
        assert!(tight_deadline(Some(1.9), None));
        assert!(!tight_deadline(Some(2.1), None));
    }
}
