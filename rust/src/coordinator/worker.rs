//! Worker runtime: receive encoded subtasks, convolve them with the
//! preloaded layer weights through a [`ConvProvider`], send results back.
//! One `run_worker` call per device (thread in in-proc mode, process in
//! TCP mode). Layer weights are pre-packed into the kernel's layout at
//! `Setup` time and every conv runs through a reusable [`Scratch`]
//! arena, so steady-state subtask execution avoids per-call packing and
//! buffer allocation.
//!
//! Each worker owns a *work queue*: a reader thread drains the link as
//! frames arrive — even while a conv is executing — so a [`ToWorker::Cancel`]
//! from the master (round already decoded elsewhere) immediately marks
//! queued subtasks of that round as dead instead of waiting behind them
//! in the transport FIFO. That is what frees straggler capacity for the
//! pipelined engine's next wave.

use std::collections::{BTreeMap, HashSet};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::model::{zoo, WeightStore};
use crate::runtime::{ConvProvider, PackedWeights, Scratch};
use crate::transport::{FrameRx, FrameTx};
use crate::util::Rng;

use super::injector::WorkerFaults;
use super::messages::{FromWorker, ToWorker, WorkOrder};

/// Worker identity + behaviour configuration.
pub struct WorkerConfig {
    pub id: usize,
    pub provider: Arc<dyn ConvProvider>,
    pub faults: WorkerFaults,
    /// Seed for the fault-sampling RNG (deterministic runs).
    pub rng_seed: u64,
}

/// Blocking worker main loop. Returns when the master shuts the link or
/// sends `Shutdown`.
pub fn run_worker(
    mut tx: Box<dyn FrameTx>,
    mut rx: Box<dyn FrameRx>,
    config: WorkerConfig,
) -> Result<()> {
    let mut rng = Rng::new(config.rng_seed);
    let mut weights: Option<(String, WeightStore)> = None;
    let mut specs: BTreeMap<String, crate::conv::ConvSpec> = Default::default();
    // Weights packed once at Setup into the kernel's execute-ready layout
    // (per layer), plus a reusable scratch arena: steady-state subtask
    // execution does no im2col/packing (re)allocation at all.
    let mut packed: BTreeMap<String, PackedWeights> = Default::default();
    let mut scratch = Scratch::new();

    // Reader thread: link frames -> in-memory work queue + cancel set.
    let (queue_tx, queue) = mpsc::channel::<Result<ToWorker>>();
    let cancelled: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
    let cancel_set = cancelled.clone();
    let reader = std::thread::Builder::new()
        .name(format!("worker-{}-rx", config.id))
        .spawn(move || loop {
            match rx.recv() {
                Ok(Some(frame)) => match ToWorker::decode(&frame) {
                    Ok(ToWorker::Cancel { round }) => {
                        let mut set = cancel_set.lock().unwrap();
                        // Round ids only grow; bound the set so a
                        // long-lived worker never accumulates forever.
                        // Un-cancelling is harmless: the master ignores
                        // stale outputs.
                        if set.len() > 4096 {
                            set.clear();
                        }
                        set.insert(round);
                    }
                    Ok(msg) => {
                        let stop = matches!(msg, ToWorker::Shutdown);
                        if queue_tx.send(Ok(msg)).is_err() || stop {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = queue_tx.send(Err(e));
                        break;
                    }
                },
                Ok(None) => break, // peer closed
                Err(e) => {
                    let _ = queue_tx.send(Err(e));
                    break;
                }
            }
        })?;

    let mut result = Ok(());
    while let Ok(msg) = queue.recv() {
        match msg {
            Err(e) => {
                result = Err(e);
                break;
            }
            Ok(ToWorker::Shutdown) => break,
            // Cancels are absorbed by the reader; tolerate one anyway.
            Ok(ToWorker::Cancel { .. }) => {}
            Ok(ToWorker::Setup { model, weight_seed }) => {
                let spec = zoo::model(&model)?;
                let store = WeightStore::generate(&spec, weight_seed)?;
                specs = spec
                    .conv_layers()?
                    .into_iter()
                    .map(|(id, s, _)| (id, s))
                    .collect();
                // Pre-pack every conv layer's weights now (the paper's
                // "preloaded weights" step) so no subtask pays for it.
                packed = specs
                    .iter()
                    .filter_map(|(id, s)| {
                        let params = store.get(id).ok()?;
                        config
                            .provider
                            .prepack(s, &params.weights)
                            .map(|pa| (id.clone(), pa))
                    })
                    .collect();
                weights = Some((model.clone(), store));
                log::debug!(
                    "worker {}: loaded {model} ({} layers prepacked)",
                    config.id,
                    packed.len()
                );
                if tx.send(&FromWorker::Ready.encode()).is_err() {
                    break; // master gone mid-setup
                }
            }
            Ok(ToWorker::Work(order)) => {
                if cancelled.lock().unwrap().contains(&order.round) {
                    log::debug!(
                        "worker {}: skipping cancelled round {} task {}",
                        config.id,
                        order.round,
                        order.task_id
                    );
                    // Ack the drop: the master keeps its per-worker load
                    // accounting exact by counting one reply per subtask.
                    let skipped = FromWorker::Skipped {
                        round: order.round,
                        task_id: order.task_id,
                    };
                    if tx.send(&skipped.encode()).is_err() {
                        break;
                    }
                    continue;
                }
                let reply = match execute_order(
                    &order,
                    &weights,
                    &specs,
                    &packed,
                    &mut scratch,
                    &config,
                    &mut rng,
                ) {
                    Ok(r) => r,
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                };
                // A failed send means the master has shut down while this
                // worker was draining queued (e.g. rateless LT) subtasks —
                // a normal exit, not an error.
                if tx.send(&reply.encode()).is_err() {
                    log::debug!("worker {}: master gone; exiting", config.id);
                    break;
                }
            }
        }
    }
    // Don't join: the reader may be blocked in recv() until the master
    // drops its link end; it exits on its own then.
    drop(reader);
    result
}

fn execute_order(
    order: &WorkOrder,
    weights: &Option<(String, WeightStore)>,
    specs: &std::collections::BTreeMap<String, crate::conv::ConvSpec>,
    packed: &std::collections::BTreeMap<String, PackedWeights>,
    scratch: &mut Scratch,
    config: &WorkerConfig,
    rng: &mut Rng,
) -> Result<FromWorker> {
    let (_, store) = weights
        .as_ref()
        .context("Work before Setup: no weights loaded")?;
    let spec = order.spec();
    // Sanity: the wire spec must match the preloaded layer's.
    if let Some(known) = specs.get(&order.node_id) {
        anyhow::ensure!(
            known.c_in == spec.c_in && known.c_out == spec.c_out && known.k_w == spec.k_w,
            "order spec mismatch for '{}'",
            order.node_id
        );
    }
    let input = order.input_tensor()?;
    let params = store.get(&order.node_id)?;

    let t0 = std::time::Instant::now();
    // Injected failure: signal the master after "noticing" (half the
    // nominal compute, approximated by the work done so far: zero here,
    // so we charge a small fixed notice delay instead of computing).
    if config.faults.fails(order.round) {
        log::debug!(
            "worker {}: injected failure (round {}, task {})",
            config.id,
            order.round,
            order.task_id
        );
        return Ok(FromWorker::Failed {
            round: order.round,
            task_id: order.task_id,
        });
    }

    // Steady-state execution path: prepacked weights when Setup packed
    // this layer, caller-owned scratch either way (zero per-subtask
    // im2col/panel allocation once buffers reach their high-water mark).
    let out = match packed.get(&order.node_id) {
        Some(pa) => config
            .provider
            .conv_prepacked(&spec, &input, &params.weights, pa, scratch)?,
        None => config
            .provider
            .conv_scratch(&spec, &input, &params.weights, scratch)?,
    };

    // Chronic straggler: stretch compute wall-time by (slowdown − 1)×.
    if config.faults.cmp_slowdown > 1.0 {
        let extra = t0.elapsed().as_secs_f64() * (config.faults.cmp_slowdown - 1.0);
        std::thread::sleep(std::time::Duration::from_secs_f64(extra));
    }
    // Worker-measured execution time (conv + any straggler stretch).
    // Reported to the master so telemetry can split dispatch→reply into
    // execution vs transmission; the injected send delay below is
    // deliberately *excluded* — it models the link, not the device.
    let exec_secs = t0.elapsed().as_secs_f64();
    // Scenario-1 transmission delay.
    let d = config.faults.sample_send_delay(rng);
    if d > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(d));
    }

    Ok(FromWorker::Output {
        round: order.round,
        task_id: order.task_id,
        c: out.c as u32,
        h: out.h as u32,
        w: out.w as u32,
        exec_secs,
        data: out.data,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::FallbackProvider;
    use crate::transport::inproc;
    use crate::transport::split::split_inproc;

    fn spawn_test_worker(
        faults: WorkerFaults,
    ) -> (Box<dyn FrameTx>, Box<dyn FrameRx>, std::thread::JoinHandle<()>) {
        let (master_side, worker_side) = inproc::pair();
        let (mtx, mrx) = split_inproc(master_side);
        let (wtx, wrx) = split_inproc(worker_side);
        let handle = std::thread::spawn(move || {
            run_worker(
                Box::new(wtx),
                Box::new(wrx),
                WorkerConfig {
                    id: 0,
                    provider: Arc::new(FallbackProvider::new()),
                    faults,
                    rng_seed: 1,
                },
            )
            .unwrap();
        });
        (Box::new(mtx), Box::new(mrx), handle)
    }

    #[test]
    fn setup_then_work_roundtrip() {
        let (mut tx, mut rx, handle) = spawn_test_worker(WorkerFaults::none());
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 42,
            }
            .encode(),
        )
        .unwrap();
        let ready = FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap();
        assert_eq!(ready, FromWorker::Ready);

        // conv1 of tinyvgg: 3 -> 32, 3x3 s1. Send a small padded slice.
        let order = WorkOrder {
            round: 0,
            request: 0,
            task_id: 5,
            node_id: "conv1".into(),
            c_in: 3,
            c_out: 32,
            k_w: 3,
            s_w: 1,
            h: 10,
            w: 7,
            data: vec![0.5; 3 * 10 * 7],
        };
        tx.send(&ToWorker::Work(order).encode()).unwrap();
        match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
            FromWorker::Output { round, task_id, c, h, w, exec_secs, data } => {
                assert_eq!((round, task_id), (0, 5));
                assert_eq!((c, h, w), (32, 8, 5));
                assert_eq!(data.len(), 32 * 8 * 5);
                assert!(data.iter().all(|v| v.is_finite()));
                assert!(exec_secs >= 0.0 && exec_secs < 60.0, "exec={exec_secs}");
            }
            other => panic!("expected output, got {other:?}"),
        }
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn injected_failure_signals_master() {
        let (mut tx, mut rx, handle) =
            spawn_test_worker(WorkerFaults::none().fails_in([0]));
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 1,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready
        let order = WorkOrder {
            round: 0,
            request: 0,
            task_id: 2,
            node_id: "conv1".into(),
            c_in: 3,
            c_out: 32,
            k_w: 3,
            s_w: 1,
            h: 5,
            w: 5,
            data: vec![0.0; 75],
        };
        tx.send(&ToWorker::Work(order.clone()).encode()).unwrap();
        assert_eq!(
            FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap(),
            FromWorker::Failed { round: 0, task_id: 2 }
        );
        // Round 1 is fine.
        let order1 = WorkOrder { round: 1, ..order };
        tx.send(&ToWorker::Work(order1).encode()).unwrap();
        assert!(matches!(
            FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap(),
            FromWorker::Output { .. }
        ));
        drop(tx);
        handle.join().unwrap();
    }

    /// A `Cancel` that reaches the worker before a queued `Work` of the
    /// same round makes the worker skip it: only the later round answers.
    #[test]
    fn cancelled_round_is_skipped() {
        let (mut tx, mut rx, handle) = spawn_test_worker(WorkerFaults::none());
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 42,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready
        let order = WorkOrder {
            round: 5,
            request: 0,
            task_id: 1,
            node_id: "conv1".into(),
            c_in: 3,
            c_out: 32,
            k_w: 3,
            s_w: 1,
            h: 10,
            w: 7,
            data: vec![0.25; 3 * 10 * 7],
        };
        // Cancel round 5 first (FIFO: reader records it before the work
        // is dequeued), then send round-5 work and round-6 work.
        tx.send(&ToWorker::Cancel { round: 5 }.encode()).unwrap();
        tx.send(&ToWorker::Work(order.clone()).encode()).unwrap();
        let order6 = WorkOrder { round: 6, ..order };
        tx.send(&ToWorker::Work(order6).encode()).unwrap();
        // Round 5's subtask is dropped from the queue and acked as
        // Skipped; only round 6 produces an Output.
        assert_eq!(
            FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap(),
            FromWorker::Skipped { round: 5, task_id: 1 }
        );
        match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
            FromWorker::Output { round, .. } => assert_eq!(round, 6),
            other => panic!("expected round-6 output, got {other:?}"),
        }
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn work_before_setup_is_error() {
        let (master_side, worker_side) = inproc::pair();
        let (mut mtx, _mrx) = split_inproc(master_side);
        let (wtx, wrx) = split_inproc(worker_side);
        let handle = std::thread::spawn(move || {
            run_worker(
                Box::new(wtx),
                Box::new(wrx),
                WorkerConfig {
                    id: 0,
                    provider: Arc::new(FallbackProvider::new()),
                    faults: WorkerFaults::none(),
                    rng_seed: 1,
                },
            )
        });
        let order = WorkOrder {
            round: 0,
            request: 0,
            task_id: 0,
            node_id: "conv1".into(),
            c_in: 1,
            c_out: 1,
            k_w: 1,
            s_w: 1,
            h: 1,
            w: 1,
            data: vec![0.0],
        };
        mtx.send(&ToWorker::Work(order).encode()).unwrap();
        assert!(handle.join().unwrap().is_err());
    }
}
