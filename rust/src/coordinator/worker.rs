//! Worker runtime: receive encoded subtasks, convolve them with the
//! preloaded layer weights through a [`ConvProvider`], send results back.
//! One `run_worker` call per device (thread in in-proc mode, process in
//! TCP mode). Layer weights are pre-packed into the kernel's layout at
//! `Setup` time and every conv runs through a reusable [`Scratch`]
//! arena, so steady-state subtask execution avoids per-call packing and
//! buffer allocation.
//!
//! Each worker owns a *work queue*: a reader thread drains the link as
//! frames arrive — even while a conv is executing — so a [`ToWorker::Cancel`]
//! from the master (round already decoded elsewhere) immediately marks
//! queued subtasks of that round as dead instead of waiting behind them
//! in the transport FIFO. That is what frees straggler capacity for the
//! pipelined engine's next wave.
//!
//! Execution runs on a small **persistent executor**: `slots` threads
//! (the `--worker-slots` knob) spawned once per worker, each owning its
//! own [`Scratch`] arena and fault-sampling RNG, fed over one shared job
//! channel. With `slots > 1` the device keeps several convs in flight —
//! a queued subtask no longer convoys behind a long-running one — while
//! the cancel-set semantics are preserved: the dispatcher checks the
//! set before enqueueing and the executor re-checks at dequeue, and
//! every dispatched subtask still yields **exactly one** reply (Output /
//! Failed / Skipped), which is what keeps the master's per-worker load
//! accounting exact. A *coalesced* order (multi-payload `WorkOrder`)
//! runs as one batched im2col/GEMM pass over every payload
//! ([`ConvProvider::conv_batch`]) and replies with the concatenated
//! outputs.

use std::collections::{BTreeMap, HashSet};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Result;

use crate::model::{zoo, WeightStore};
use crate::obs::trace::TraceHandle;
use crate::runtime::{ConvProvider, PackedWeights, Scratch};
use crate::transport::{FrameRx, FrameTx};
use crate::util::Rng;

use super::injector::WorkerFaults;
use super::messages::{FromWorker, ToWorker, WorkOrder};

/// Worker identity + behaviour configuration.
pub struct WorkerConfig {
    pub id: usize,
    pub provider: Arc<dyn ConvProvider>,
    pub faults: WorkerFaults,
    /// Seed for the fault-sampling RNG (deterministic runs).
    pub rng_seed: u64,
    /// Conv subtasks this device keeps in flight concurrently (the
    /// `--worker-slots` knob); `0` is treated as `1`. Results are
    /// payload-exact at any setting — only completion *order* can
    /// change.
    pub slots: usize,
    /// Span recorder for executor slot occupancy (in-proc pools share
    /// the master's handle via `MasterConfig::trace`). `None` — the
    /// default — records nothing and costs one branch per subtask.
    pub trace: Option<TraceHandle>,
}

/// Everything `Setup` loads, shared read-only with the executor threads.
struct LoadedModel {
    store: WeightStore,
    specs: BTreeMap<String, crate::conv::ConvSpec>,
    /// Weights packed once at Setup into the kernel's execute-ready
    /// layout (per layer): steady-state subtask execution does no
    /// im2col/packing (re)allocation at all.
    packed: BTreeMap<String, PackedWeights>,
}

/// Why the worker main loop ended — the reconnect loop's branch point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerExit {
    /// The master sent `Shutdown` (or retired this worker): final.
    Shutdown,
    /// The link dropped. An announcing worker (`--connect`) treats this
    /// as "reconnect with backoff"; a spawned in-proc worker as a clean
    /// exit.
    LinkClosed,
}

/// How an announcing worker (`cocoi worker --connect`) introduces
/// itself during the join handshake.
pub struct JoinOptions {
    /// Human-readable name echoed in the master's membership telemetry.
    pub name: String,
    /// Model hint: a non-empty mismatch is rejected by the master
    /// instead of prepacking weights this master will never dispatch
    /// against. Empty = prepack whatever the master serves.
    pub model: String,
}

/// Regenerate + prepack a model's weights (the paper's "preloaded
/// weights" step) — paid once per Setup/JoinAck, never per subtask.
fn load_model(name: &str, weight_seed: u64, config: &WorkerConfig) -> Result<LoadedModel> {
    let spec = zoo::model(name)?;
    let store = WeightStore::generate(&spec, weight_seed)?;
    let specs: BTreeMap<String, crate::conv::ConvSpec> = spec
        .conv_layers()?
        .into_iter()
        .map(|(id, s, _)| (id, s))
        .collect();
    let packed: BTreeMap<String, PackedWeights> = specs
        .iter()
        .filter_map(|(id, s)| {
            let params = store.get(id).ok()?;
            config
                .provider
                .prepack(s, &params.weights)
                .map(|pa| (id.clone(), pa))
        })
        .collect();
    log::debug!(
        "worker {}: loaded {name} ({} layers prepacked)",
        config.id,
        packed.len()
    );
    Ok(LoadedModel { store, specs, packed })
}

/// Events multiplexed into the worker's main loop: link frames from the
/// reader thread, the link closing, and executor-thread failures (the
/// executors hold clones of the sender, so the dispatcher needs an
/// explicit close event rather than relying on channel disconnect).
enum WorkerEvent {
    Msg(ToWorker),
    LinkClosed,
    Error(anyhow::Error),
}

/// Unwind guard for an executor thread: a PANIC (as opposed to a clean
/// `Err` return, which posts its own event) must still surface to the
/// dispatcher — otherwise the worker keeps accepting subtasks that
/// nobody executes and whose one-reply-per-dispatch ack never comes,
/// and the master only notices at its recv timeout.
struct ExecGuard {
    err_tx: mpsc::Sender<WorkerEvent>,
}

impl Drop for ExecGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let _ = self.err_tx.send(WorkerEvent::Error(anyhow::anyhow!(
                "worker executor panicked"
            )));
        }
    }
}

/// Blocking worker main loop for a *provisioned* worker (the master
/// spawned it and sends `Setup` first). Returns when the master shuts
/// the link or sends `Shutdown`.
pub fn run_worker(
    tx: Box<dyn FrameTx>,
    rx: Box<dyn FrameRx>,
    config: WorkerConfig,
) -> Result<()> {
    let tx: Arc<Mutex<Box<dyn FrameTx>>> = Arc::new(Mutex::new(tx));
    run_worker_core(tx, rx, &config, None).map(|_| ())
}

/// Announce-and-serve: join a *running* cluster over an established
/// link. Sends `Join`, waits for `JoinAck` (bails on `JoinReject`),
/// prepacks the master's model, sends `Ready`, spawns the heartbeat
/// thread at the master-assigned cadence, then runs the normal main
/// loop. The returned [`WorkerExit`] tells the caller's reconnect loop
/// whether to dial again.
pub fn run_worker_announcing(
    mut tx: Box<dyn FrameTx>,
    mut rx: Box<dyn FrameRx>,
    mut config: WorkerConfig,
    opts: &JoinOptions,
) -> Result<WorkerExit> {
    tx.send(
        &FromWorker::Join {
            name: opts.name.clone(),
            protocol: super::messages::PROTOCOL_VERSION,
            model: opts.model.clone(),
        }
        .encode(),
    )?;
    let Some(frame) = rx.recv()? else {
        return Ok(WorkerExit::LinkClosed); // master died mid-handshake
    };
    let (worker_id, model_name, weight_seed, heartbeat_ms) = match ToWorker::decode(&frame)? {
        ToWorker::JoinAck {
            worker_id,
            model,
            weight_seed,
            heartbeat_ms,
        } => (worker_id as usize, model, weight_seed, heartbeat_ms),
        ToWorker::JoinReject { reason } => {
            anyhow::bail!("join rejected by master: {reason}")
        }
        other => anyhow::bail!("expected JoinAck, got {other:?}"),
    };
    config.id = worker_id;
    // Prepack BEFORE Ready: the master admits this worker into dispatch
    // targets the moment Ready lands, so it must be execute-ready.
    let model = Arc::new(load_model(&model_name, weight_seed, &config)?);
    let tx: Arc<Mutex<Box<dyn FrameTx>>> = Arc::new(Mutex::new(tx));
    tx.lock().unwrap().send(&FromWorker::Ready.encode())?;

    // Heartbeat thread: one beat per master-assigned interval (a third
    // of the eviction deadline) until the stop channel hangs up. A
    // failed beat means the link died — the main loop notices on its
    // own, so the thread just exits.
    let (stop_tx, stop_rx) = mpsc::channel::<()>();
    let beat_tx = tx.clone();
    let interval = std::time::Duration::from_millis(u64::from(heartbeat_ms.max(1)));
    let beats = std::thread::Builder::new()
        .name(format!("worker-{worker_id}-hb"))
        .spawn(move || {
            let mut seq = 0u64;
            loop {
                match stop_rx.recv_timeout(interval) {
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    _ => break, // stop signal (or sender dropped)
                }
                seq += 1;
                let beat = FromWorker::Heartbeat { seq }.encode();
                if beat_tx.lock().unwrap().send(&beat).is_err() {
                    break;
                }
            }
        })?;
    let out = match run_worker_core(tx, rx, &config, Some(model)) {
        // Post-admission failures (a torn link usually surfaces as a
        // recv/send error, not a clean EOF) end THIS membership, not
        // the worker: the caller's reconnect loop decides whether to
        // dial again. Handshake errors above stay fatal — re-dialing a
        // master that rejected the join would spin forever.
        Err(e) => {
            log::warn!("worker {worker_id}: link lost after join: {e:#}");
            Ok(WorkerExit::LinkClosed)
        }
        ok => ok,
    };
    drop(stop_tx); // hang up: the heartbeat thread exits on its next wake
    let _ = beats.join();
    out
}

/// The shared dispatcher + executor-pool loop behind both entry points.
/// `model` is pre-loaded for announcing workers (JoinAck carried the
/// name/seed); provisioned workers load it from `Setup`.
fn run_worker_core(
    tx: Arc<Mutex<Box<dyn FrameTx>>>,
    mut rx: Box<dyn FrameRx>,
    config: &WorkerConfig,
    mut model: Option<Arc<LoadedModel>>,
) -> Result<WorkerExit> {
    let slots = config.slots.max(1);

    // Reader thread: link frames -> in-memory work queue + cancel set.
    let (queue_tx, queue) = mpsc::channel::<WorkerEvent>();
    let cancelled: Arc<Mutex<HashSet<u64>>> = Arc::new(Mutex::new(HashSet::new()));
    let cancel_set = cancelled.clone();
    let reader_tx = queue_tx.clone();
    let reader = std::thread::Builder::new()
        .name(format!("worker-{}-rx", config.id))
        .spawn(move || loop {
            match rx.recv() {
                Ok(Some(frame)) => match ToWorker::decode(&frame) {
                    Ok(ToWorker::Cancel { round }) => {
                        let mut set = cancel_set.lock().unwrap();
                        // Round ids only grow; bound the set so a
                        // long-lived worker never accumulates forever.
                        // Un-cancelling is harmless: the master ignores
                        // stale outputs.
                        if set.len() > 4096 {
                            set.clear();
                        }
                        set.insert(round);
                    }
                    Ok(msg) => {
                        let stop = matches!(msg, ToWorker::Shutdown);
                        if reader_tx.send(WorkerEvent::Msg(msg)).is_err() || stop {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = reader_tx.send(WorkerEvent::Error(e));
                        break;
                    }
                },
                Ok(None) => {
                    let _ = reader_tx.send(WorkerEvent::LinkClosed);
                    break;
                }
                Err(e) => {
                    let _ = reader_tx.send(WorkerEvent::Error(e));
                    break;
                }
            }
        })?;

    // Persistent executor pool: `slots` threads fed over one shared job
    // channel, spawned ONCE per worker (this also closes the old
    // per-conv `thread::scope` amortization gap at the worker level —
    // steady state spawns no threads at all).
    let (job_tx, job_rx) = mpsc::channel::<(WorkOrder, Arc<LoadedModel>)>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let mut executors = Vec::with_capacity(slots);
    for slot in 0..slots {
        let job_rx = job_rx.clone();
        let tx = tx.clone();
        let cancelled = cancelled.clone();
        let err_tx = queue_tx.clone();
        let provider = config.provider.clone();
        let faults = config.faults.clone();
        let trace = config.trace.clone();
        let id = config.id;
        // Slot 0 inherits the worker's seed verbatim, so a 1-slot
        // executor samples the exact fault sequence the old sequential
        // loop did.
        let mut rng = Rng::new(config.rng_seed ^ (slot as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        executors.push(
            std::thread::Builder::new()
                .name(format!("worker-{id}-exec-{slot}"))
                .spawn(move || {
                    let _guard = ExecGuard {
                        err_tx: err_tx.clone(),
                    };
                    let mut scratch = Scratch::new();
                    loop {
                        // Hold the lock only for the dequeue, not the conv.
                        let job = job_rx.lock().unwrap().recv();
                        let Ok((order, model)) = job else { break };
                        // Re-check the cancel set at dequeue: a Cancel that
                        // raced in while this subtask waited in the job
                        // queue still saves the work. Ack the drop so the
                        // master's one-reply-per-dispatch accounting and
                        // load charges stay exact.
                        if cancelled.lock().unwrap().contains(&order.round) {
                            log::debug!(
                                "worker {id}: skipping cancelled round {} task {}",
                                order.round,
                                order.task_id
                            );
                            let skipped = FromWorker::Skipped {
                                round: order.round,
                                task_id: order.task_id,
                            };
                            if tx.lock().unwrap().send(&skipped.encode()).is_err() {
                                break;
                            }
                            continue;
                        }
                        let exec_started = std::time::Instant::now();
                        let outcome = execute_order(
                            &order, &model, &*provider, &faults, &mut scratch, &mut rng, id,
                        );
                        // Slot occupancy: one pool-level span per executed
                        // order (stalls show as near-zero bars going
                        // silent; the master-side subtask span keeps
                        // running — that gap IS the straggler signature).
                        if let Some(tr) = &trace {
                            tr.pool_span(
                                &format!("exec r{} t{}", order.round, order.task_id),
                                Some(id),
                                exec_started,
                                std::time::Instant::now(),
                            );
                        }
                        match outcome {
                            Ok(Some(reply)) => {
                                // A failed send means the master has shut
                                // down while this worker was draining
                                // queued subtasks — a normal exit.
                                if tx.lock().unwrap().send(&reply.encode()).is_err() {
                                    log::debug!("worker {id}: master gone; exiting");
                                    break;
                                }
                            }
                            // Injected stall: the subtask is swallowed —
                            // no reply ever — but the executor keeps
                            // draining, so the worker stays live (and a
                            // TCP worker keeps heartbeating). Only the
                            // master's watchdog can recover the shard.
                            Ok(None) => {}
                            Err(e) => {
                                let _ = err_tx.send(WorkerEvent::Error(e));
                                break;
                            }
                        }
                    }
                })?,
        );
    }
    drop(queue_tx); // main-loop senders: reader + executors only
    // Drop the dispatcher's own handle on the job receiver: once every
    // executor has exited, `job_tx.send` then actually fails (instead of
    // queueing into a channel nobody will ever drain).
    drop(job_rx);

    let mut result = Ok(WorkerExit::LinkClosed);
    while let Ok(ev) = queue.recv() {
        match ev {
            WorkerEvent::Error(e) => {
                result = Err(e);
                break;
            }
            WorkerEvent::LinkClosed => break, // peer closed: clean exit
            WorkerEvent::Msg(ToWorker::Shutdown) => {
                result = Ok(WorkerExit::Shutdown);
                break;
            }
            // Cancels are absorbed by the reader; tolerate one anyway.
            WorkerEvent::Msg(ToWorker::Cancel { .. }) => {}
            // Handshake frames after admission: harmless, ignore.
            WorkerEvent::Msg(ToWorker::JoinAck { .. } | ToWorker::JoinReject { .. }) => {
                log::warn!("worker {}: stray handshake frame post-join", config.id);
            }
            WorkerEvent::Msg(ToWorker::Setup { model: name, weight_seed }) => {
                model = Some(Arc::new(load_model(&name, weight_seed, config)?));
                log::debug!("worker {}: setup complete ({slots} slots)", config.id);
                if tx.lock().unwrap().send(&FromWorker::Ready.encode()).is_err() {
                    break; // master gone mid-setup
                }
            }
            WorkerEvent::Msg(ToWorker::Work(order)) => {
                let Some(model) = &model else {
                    result = Err(anyhow::anyhow!("Work before Setup: no weights loaded"));
                    break;
                };
                // Already-cancelled rounds never reach the executor;
                // ack the drop here (one reply per dispatch).
                if cancelled.lock().unwrap().contains(&order.round) {
                    log::debug!(
                        "worker {}: skipping cancelled round {} task {}",
                        config.id,
                        order.round,
                        order.task_id
                    );
                    let skipped = FromWorker::Skipped {
                        round: order.round,
                        task_id: order.task_id,
                    };
                    if tx.lock().unwrap().send(&skipped.encode()).is_err() {
                        break;
                    }
                    continue;
                }
                if job_tx.send((order, model.clone())).is_err() {
                    // All executors died; the Error event that killed
                    // them is (or will be) in the queue — surface it.
                    result = Err(anyhow::anyhow!("worker executor pool terminated"));
                    break;
                }
            }
        }
    }
    // Let the executors drain their queue (each remaining subtask still
    // gets its one reply when the master is alive), then reap errors.
    drop(job_tx);
    for exec in executors {
        if exec.join().is_err() && result.is_ok() {
            result = Err(anyhow::anyhow!("worker executor panicked"));
        }
    }
    // Don't join: the reader may be blocked in recv() until the master
    // drops its link end; it exits on its own then.
    drop(reader);
    result
}

/// Execute one (possibly coalesced) work order: a single-payload order
/// runs the classic prepacked single conv; a multi-payload order runs
/// ONE batched pass whose GEMM N dimension spans every payload — each
/// payload's slice is bitwise identical to a solo run — and replies
/// with the concatenated outputs in payload order. `Ok(None)` is the
/// injected *stall* fault: the order was accepted but no reply will
/// ever be sent (breaking the one-reply-per-dispatch contract is the
/// point — it is what the master-side watchdog exists to catch).
fn execute_order(
    order: &WorkOrder,
    model: &LoadedModel,
    provider: &dyn ConvProvider,
    faults: &WorkerFaults,
    scratch: &mut Scratch,
    rng: &mut Rng,
    worker_id: usize,
) -> Result<Option<FromWorker>> {
    let spec = order.spec();
    // Sanity: the wire spec must match the preloaded layer's.
    if let Some(known) = model.specs.get(&order.node_id) {
        anyhow::ensure!(
            known.c_in == spec.c_in && known.c_out == spec.c_out && known.k_w == spec.k_w,
            "order spec mismatch for '{}'",
            order.node_id
        );
    }
    let elems = order.payload_elems();
    anyhow::ensure!(
        order.payloads.iter().all(|p| p.data.len() == elems),
        "order payload length mismatch for '{}'",
        order.node_id
    );
    let inputs: Vec<crate::conv::Tensor> = (0..order.payloads.len())
        .map(|i| order.input_tensor(i))
        .collect::<Result<_>>()?;
    let params = model.store.get(&order.node_id)?;

    let t0 = std::time::Instant::now();
    // Injected stall: accept the subtask and go silent. No Failed, no
    // Output — the worker looks perfectly healthy (heartbeats continue)
    // while this shard black-holes.
    if faults.stalls(order.round) {
        log::debug!(
            "worker {worker_id}: injected stall (round {}, task {})",
            order.round,
            order.task_id
        );
        return Ok(None);
    }
    // Injected failure: signal the master after "noticing" (half the
    // nominal compute, approximated by the work done so far: zero here,
    // so we charge a small fixed notice delay instead of computing).
    if faults.fails(order.round) {
        log::debug!(
            "worker {worker_id}: injected failure (round {}, task {})",
            order.round,
            order.task_id
        );
        return Ok(Some(FromWorker::Failed {
            round: order.round,
            task_id: order.task_id,
        }));
    }

    // Steady-state execution path: prepacked weights when Setup packed
    // this layer, executor-owned scratch either way (zero per-subtask
    // im2col/panel allocation once buffers reach their high-water
    // mark). One pass serves every coalesced payload.
    let input_refs: Vec<&crate::conv::Tensor> = inputs.iter().collect();
    let outs = provider.conv_batch(
        &spec,
        &input_refs,
        &params.weights,
        model.packed.get(&order.node_id),
        scratch,
    )?;

    // Chronic straggler: stretch compute wall-time by (slowdown − 1)×.
    if faults.cmp_slowdown > 1.0 {
        let extra = t0.elapsed().as_secs_f64() * (faults.cmp_slowdown - 1.0);
        std::thread::sleep(std::time::Duration::from_secs_f64(extra));
    }
    // Worker-measured execution time (conv + any straggler stretch) of
    // the WHOLE batched pass — the master normalizes it by the order's
    // coalesced FLOPs. Reported so telemetry can split dispatch→reply
    // into execution vs transmission; the injected send delay below is
    // deliberately *excluded* — it models the link, not the device.
    let exec_secs = t0.elapsed().as_secs_f64();
    // Scenario-1 transmission delay.
    let d = faults.sample_send_delay(rng);
    if d > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(d));
    }

    let (c, h, w) = (outs[0].c, outs[0].h, outs[0].w);
    let mut data = Vec::with_capacity(c * h * w * outs.len());
    for out in outs {
        debug_assert_eq!((out.c, out.h, out.w), (c, h, w));
        data.extend_from_slice(&out.data);
    }
    Ok(Some(FromWorker::Output {
        round: order.round,
        task_id: order.task_id,
        c: c as u32,
        h: h as u32,
        w: w as u32,
        exec_secs,
        data,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::FallbackProvider;
    use crate::transport::inproc;
    use crate::transport::split::split_inproc;

    fn spawn_test_worker_slots(
        faults: WorkerFaults,
        slots: usize,
    ) -> (Box<dyn FrameTx>, Box<dyn FrameRx>, std::thread::JoinHandle<()>) {
        let (master_side, worker_side) = inproc::pair();
        let (mtx, mrx) = split_inproc(master_side);
        let (wtx, wrx) = split_inproc(worker_side);
        let handle = std::thread::spawn(move || {
            run_worker(
                Box::new(wtx),
                Box::new(wrx),
                WorkerConfig {
                    id: 0,
                    provider: Arc::new(FallbackProvider::new()),
                    faults,
                    rng_seed: 1,
                    slots,
                    trace: None,
                },
            )
            .unwrap();
        });
        (Box::new(mtx), Box::new(mrx), handle)
    }

    fn spawn_test_worker(
        faults: WorkerFaults,
    ) -> (Box<dyn FrameTx>, Box<dyn FrameRx>, std::thread::JoinHandle<()>) {
        spawn_test_worker_slots(faults, 1)
    }

    #[test]
    fn setup_then_work_roundtrip() {
        let (mut tx, mut rx, handle) = spawn_test_worker(WorkerFaults::none());
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 42,
            }
            .encode(),
        )
        .unwrap();
        let ready = FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap();
        assert_eq!(ready, FromWorker::Ready);

        // conv1 of tinyvgg: 3 -> 32, 3x3 s1. Send a small padded slice.
        let order = WorkOrder::single(
            0,
            0,
            5,
            "conv1".into(),
            3,
            32,
            3,
            1,
            10,
            7,
            vec![0.5; 3 * 10 * 7],
        );
        tx.send(&ToWorker::Work(order).encode()).unwrap();
        match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
            FromWorker::Output { round, task_id, c, h, w, exec_secs, data } => {
                assert_eq!((round, task_id), (0, 5));
                assert_eq!((c, h, w), (32, 8, 5));
                assert_eq!(data.len(), 32 * 8 * 5);
                assert!(data.iter().all(|v| v.is_finite()));
                assert!(exec_secs >= 0.0 && exec_secs < 60.0, "exec={exec_secs}");
            }
            other => panic!("expected output, got {other:?}"),
        }
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    /// A coalesced (multi-payload) order yields ONE reply whose data is
    /// the per-payload outputs concatenated — each slice bitwise equal
    /// to the single-payload result for the same input.
    #[test]
    fn coalesced_order_concatenates_outputs() {
        let (mut tx, mut rx, handle) = spawn_test_worker(WorkerFaults::none());
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 42,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready

        let data_a = vec![0.5; 3 * 10 * 7];
        let data_b: Vec<f32> = (0..3 * 10 * 7).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
        // Solo runs first.
        let mut solo = Vec::new();
        for (i, d) in [data_a.clone(), data_b.clone()].into_iter().enumerate() {
            let order =
                WorkOrder::single(i as u64, 7, 0, "conv1".into(), 3, 32, 3, 1, 10, 7, d);
            tx.send(&ToWorker::Work(order).encode()).unwrap();
            match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
                FromWorker::Output { data, .. } => solo.push(data),
                other => panic!("expected output, got {other:?}"),
            }
        }
        // One coalesced order with both payloads.
        let mut order =
            WorkOrder::single(10, 40, 1, "conv1".into(), 3, 32, 3, 1, 10, 7, data_a);
        order.payloads.push(super::super::messages::WorkPayload {
            request: 41,
            data: data_b,
        });
        tx.send(&ToWorker::Work(order).encode()).unwrap();
        match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
            FromWorker::Output { round, c, h, w, data, .. } => {
                assert_eq!(round, 10);
                let part = (c * h * w) as usize;
                assert_eq!(data.len(), 2 * part);
                assert_eq!(&data[..part], &solo[0][..], "payload 0 diverged");
                assert_eq!(&data[part..], &solo[1][..], "payload 1 diverged");
            }
            other => panic!("expected output, got {other:?}"),
        }
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn injected_failure_signals_master() {
        let (mut tx, mut rx, handle) =
            spawn_test_worker(WorkerFaults::none().fails_in([0]));
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 1,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready
        let order =
            WorkOrder::single(0, 0, 2, "conv1".into(), 3, 32, 3, 1, 5, 5, vec![0.0; 75]);
        tx.send(&ToWorker::Work(order.clone()).encode()).unwrap();
        assert_eq!(
            FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap(),
            FromWorker::Failed { round: 0, task_id: 2 }
        );
        // Round 1 is fine.
        let order1 = WorkOrder { round: 1, ..order };
        tx.send(&ToWorker::Work(order1).encode()).unwrap();
        assert!(matches!(
            FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap(),
            FromWorker::Output { .. }
        ));
        drop(tx);
        handle.join().unwrap();
    }

    /// The stall fault is a silent black hole: the stalled round never
    /// gets ANY reply, but the worker stays alive and serves later
    /// rounds normally (the exact signature the watchdog must catch).
    #[test]
    fn stalled_round_swallows_reply_but_worker_lives() {
        let (mut tx, mut rx, handle) =
            spawn_test_worker(WorkerFaults::none().stalls_in([0]));
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 1,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready
        let order =
            WorkOrder::single(0, 0, 2, "conv1".into(), 3, 32, 3, 1, 5, 5, vec![0.0; 75]);
        tx.send(&ToWorker::Work(order.clone()).encode()).unwrap();
        // Round 1 right behind it: the FIRST frame back must be round
        // 1's Output — round 0 produced nothing at all.
        let order1 = WorkOrder { round: 1, ..order };
        tx.send(&ToWorker::Work(order1).encode()).unwrap();
        match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
            FromWorker::Output { round, .. } => assert_eq!(round, 1),
            other => panic!("expected round-1 output, got {other:?}"),
        }
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    /// A `Cancel` that reaches the worker before a queued `Work` of the
    /// same round makes the worker skip it: only the later round answers.
    #[test]
    fn cancelled_round_is_skipped() {
        let (mut tx, mut rx, handle) = spawn_test_worker(WorkerFaults::none());
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 42,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready
        let order = WorkOrder::single(
            5,
            0,
            1,
            "conv1".into(),
            3,
            32,
            3,
            1,
            10,
            7,
            vec![0.25; 3 * 10 * 7],
        );
        // Cancel round 5 first (FIFO: reader records it before the work
        // is dequeued), then send round-5 work and round-6 work.
        tx.send(&ToWorker::Cancel { round: 5 }.encode()).unwrap();
        tx.send(&ToWorker::Work(order.clone()).encode()).unwrap();
        let order6 = WorkOrder { round: 6, ..order };
        tx.send(&ToWorker::Work(order6).encode()).unwrap();
        // Round 5's subtask is dropped from the queue and acked as
        // Skipped; only round 6 produces an Output.
        assert_eq!(
            FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap(),
            FromWorker::Skipped { round: 5, task_id: 1 }
        );
        match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
            FromWorker::Output { round, .. } => assert_eq!(round, 6),
            other => panic!("expected round-6 output, got {other:?}"),
        }
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    /// The executor contract at every slot count: N dispatched subtasks
    /// yield exactly N replies (here all Outputs), regardless of the
    /// completion order concurrency allows.
    #[test]
    fn slots_preserve_one_reply_per_dispatch() {
        for slots in [1, 2, 4] {
            let (mut tx, mut rx, handle) =
                spawn_test_worker_slots(WorkerFaults::none(), slots);
            tx.send(
                &ToWorker::Setup {
                    model: "tinyvgg".into(),
                    weight_seed: 42,
                }
                .encode(),
            )
            .unwrap();
            rx.recv().unwrap().unwrap(); // Ready
            let n = 6;
            for t in 0..n {
                let order = WorkOrder::single(
                    t as u64,
                    0,
                    t as u32,
                    "conv1".into(),
                    3,
                    32,
                    3,
                    1,
                    10,
                    7,
                    vec![0.1 * (t + 1) as f32; 3 * 10 * 7],
                );
                tx.send(&ToWorker::Work(order).encode()).unwrap();
            }
            let mut seen: Vec<u64> = (0..n)
                .map(|_| match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
                    FromWorker::Output { round, .. } => round,
                    other => panic!("slots={slots}: expected output, got {other:?}"),
                })
                .collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "slots={slots}");
            tx.send(&ToWorker::Shutdown.encode()).unwrap();
            handle.join().unwrap();
        }
    }

    /// Cancel acks survive concurrency: with multiple slots, a cancelled
    /// round queued behind work still produces exactly one Skipped ack.
    #[test]
    fn slots_ack_cancels_exactly_once() {
        let (mut tx, mut rx, handle) = spawn_test_worker_slots(WorkerFaults::none(), 4);
        tx.send(
            &ToWorker::Setup {
                model: "tinyvgg".into(),
                weight_seed: 42,
            }
            .encode(),
        )
        .unwrap();
        rx.recv().unwrap().unwrap(); // Ready
        tx.send(&ToWorker::Cancel { round: 2 }.encode()).unwrap();
        for round in 0..4u64 {
            let order = WorkOrder::single(
                round,
                0,
                0,
                "conv1".into(),
                3,
                32,
                3,
                1,
                10,
                7,
                vec![0.5; 3 * 10 * 7],
            );
            tx.send(&ToWorker::Work(order).encode()).unwrap();
        }
        let mut outputs = 0;
        let mut skipped = Vec::new();
        for _ in 0..4 {
            match FromWorker::decode(&rx.recv().unwrap().unwrap()).unwrap() {
                FromWorker::Output { .. } => outputs += 1,
                FromWorker::Skipped { round, .. } => skipped.push(round),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(outputs, 3);
        assert_eq!(skipped, vec![2]);
        tx.send(&ToWorker::Shutdown.encode()).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn work_before_setup_is_error() {
        let (master_side, worker_side) = inproc::pair();
        let (mut mtx, _mrx) = split_inproc(master_side);
        let (wtx, wrx) = split_inproc(worker_side);
        let handle = std::thread::spawn(move || {
            run_worker(
                Box::new(wtx),
                Box::new(wrx),
                WorkerConfig {
                    id: 0,
                    provider: Arc::new(FallbackProvider::new()),
                    faults: WorkerFaults::none(),
                    rng_seed: 1,
                    slots: 2,
                    trace: None,
                },
            )
        });
        let order =
            WorkOrder::single(0, 0, 0, "conv1".into(), 1, 1, 1, 1, 1, 1, vec![0.0]);
        mtx.send(&ToWorker::Work(order).encode()).unwrap();
        assert!(handle.join().unwrap().is_err());
    }
}
