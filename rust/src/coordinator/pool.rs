//! Local worker pool: spawn `n` in-process workers (threads) wired to a
//! master via in-proc links — the single-binary analogue of the paper's
//! 1 master + n Raspberry Pi workers.
//!
//! Each spawned worker runs its own work queue + cancel set (see
//! `coordinator::worker`), so the pool serves both execution modes:
//! round-barrier [`Master::infer`] and the pipelined
//! [`Master::infer_batch`] with straggler cancellation.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::ConvProvider;
use crate::transport::inproc;
use crate::transport::split::{split_inproc, LinkPair};

use super::injector::WorkerFaults;
use super::master::{Master, MasterConfig};
use super::worker::{run_worker, WorkerConfig};

/// Handle keeping worker threads joinable.
pub struct LocalCluster {
    pub master: Master,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
}

/// The joinable worker-thread half of a [`LocalCluster`] after
/// [`LocalCluster::into_parts`] hands the master off (e.g. to an
/// `InferenceServer`, whose engine thread owns it).
pub struct WorkerHandles {
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl WorkerHandles {
    /// Join all workers (call after the master sent `Shutdown`). Every
    /// thread is joined even when an early one failed, and the error
    /// names *which* workers died instead of discarding the identity
    /// with the first `?`.
    pub fn join(self) -> Result<()> {
        let mut failures: Vec<String> = Vec::new();
        for w in self.workers {
            let name = w.thread().name().unwrap_or("worker-?").to_string();
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failures.push(format!("{name}: {e:#}")),
                Err(_) => failures.push(format!("{name}: panicked")),
            }
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(anyhow::anyhow!("worker failures: {}", failures.join("; ")))
        }
    }
}

/// Worker-side pool tuning (everything that is not the master's
/// concern).
#[derive(Clone, Copy, Debug)]
pub struct PoolOptions {
    /// Conv subtasks each worker keeps in flight concurrently (the
    /// `--worker-slots` knob; see `coordinator::worker`). 0 = 1.
    pub worker_slots: usize,
}

impl Default for PoolOptions {
    fn default() -> PoolOptions {
        PoolOptions { worker_slots: 1 }
    }
}

impl LocalCluster {
    /// Spawn `n` workers (threads) with the given provider and per-worker
    /// faults, then start a master on `model_name`. Single-slot workers;
    /// see [`LocalCluster::spawn_with`] for the concurrency knob.
    pub fn spawn(
        model_name: &str,
        n: usize,
        config: MasterConfig,
        provider: Arc<dyn ConvProvider>,
        faults: Vec<WorkerFaults>,
    ) -> Result<LocalCluster> {
        Self::spawn_with(model_name, n, config, provider, faults, PoolOptions::default())
    }

    /// [`LocalCluster::spawn`] with explicit [`PoolOptions`].
    pub fn spawn_with(
        model_name: &str,
        n: usize,
        config: MasterConfig,
        provider: Arc<dyn ConvProvider>,
        faults: Vec<WorkerFaults>,
        opts: PoolOptions,
    ) -> Result<LocalCluster> {
        anyhow::ensure!(faults.len() == n, "need one fault plan per worker");
        let mut links: Vec<LinkPair> = Vec::new();
        let mut workers = Vec::new();
        for (i, f) in faults.into_iter().enumerate() {
            let (master_side, worker_side) = inproc::pair();
            let (mtx, mrx) = split_inproc(master_side);
            links.push((Box::new(mtx), Box::new(mrx)));
            let (wtx, wrx) = split_inproc(worker_side);
            let provider = provider.clone();
            // In-proc workers share the master's span recorder, so slot
            // occupancy lands on the same timeline as the request trees.
            let trace = config.trace.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("worker-{i}"))
                    .spawn(move || {
                        run_worker(
                            Box::new(wtx),
                            Box::new(wrx),
                            WorkerConfig {
                                id: i,
                                provider,
                                faults: f,
                                rng_seed: 0xC0C0 + i as u64,
                                slots: opts.worker_slots,
                                trace,
                            },
                        )
                    })?,
            );
        }
        let master = Master::new(model_name, config, links, provider)?;
        Ok(LocalCluster { master, workers })
    }

    /// Split into the master and the joinable worker handles — the shape
    /// the serving front-end wants (`InferenceServer::start` takes the
    /// master by value).
    pub fn into_parts(self) -> (Master, WorkerHandles) {
        (
            self.master,
            WorkerHandles {
                workers: self.workers,
            },
        )
    }

    /// Shut down master and join workers.
    pub fn shutdown(self) -> Result<()> {
        let (master, workers) = self.into_parts();
        master.shutdown();
        workers.join()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `join` keeps joining after a failure and names every worker that
    /// died — it used to stop at the first `?` and discard the identity.
    #[test]
    fn join_reports_which_workers_failed_and_joins_the_rest() {
        let spawn = |name: &str, r: Result<()>| {
            std::thread::Builder::new()
                .name(name.to_string())
                .spawn(move || r)
                .unwrap()
        };
        let handles = WorkerHandles {
            workers: vec![
                spawn("worker-0", Ok(())),
                spawn("worker-1", Err(anyhow::anyhow!("link reset"))),
                std::thread::Builder::new()
                    .name("worker-2".to_string())
                    .spawn(|| -> Result<()> { panic!("injected test panic") })
                    .unwrap(),
                spawn("worker-3", Ok(())),
            ],
        };
        let err = handles.join().unwrap_err().to_string();
        assert!(err.contains("worker-1: link reset"), "{err}");
        assert!(err.contains("worker-2: panicked"), "{err}");
        assert!(!err.contains("worker-0:"), "{err}");
        assert!(!err.contains("worker-3:"), "{err}");
    }
}
