//! Runtime: loads the python-AOT HLO-text artifacts through PJRT and
//! exposes conv execution providers to the coordinator. Python never runs
//! here — the rust binary is self-contained once `artifacts/` exists.

pub mod artifacts;
pub mod pjrt;
pub mod provider;

pub use artifacts::{ConvKey, Manifest};
pub use pjrt::{PjrtHandle, PjrtService, RuntimeStats};
pub use provider::{ConvProvider, FallbackProvider, PackedWeights, PjrtProvider};

pub use crate::conv::Scratch;
