//! Conv execution providers: how a worker actually runs its subtask.
//!
//! * [`FallbackProvider`] — pure-rust im2col + GEMM. Always available
//!   (`cargo test` needs no artifacts), and the master's executor for
//!   remainder pieces and type-2 layers.
//! * [`PjrtProvider`] — the production path: per-shape **fused** AOT
//!   artifacts through the PJRT service; shape-polymorphic **tile** GEMM
//!   artifacts when no fused artifact matches; falls back to pure rust as
//!   the last resort (logged, counted).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::conv::gemm::{self, PackedA, Scratch};
use crate::conv::im2col;
use crate::conv::{ConvSpec, Tensor};

use super::artifacts::{ConvKey, Manifest};
use super::pjrt::PjrtHandle;

/// A layer's weights in a provider-specific execute-ready layout (for
/// the tiled kernel: the packed A-panel format).
pub type PackedWeights = PackedA;

/// Uniform interface: valid conv of an already-padded input partition
/// (pure linear map — no bias/activation; see coding docs).
///
/// The three optional hooks let long-lived executors (the worker loop)
/// amortize work: `prepack` converts a layer's weights into an
/// execute-ready layout once at model-load time; `conv_scratch` /
/// `conv_prepacked` run against a caller-owned [`Scratch`] arena so
/// steady-state subtask execution reuses its buffers instead of
/// reallocating per call. Defaults delegate to `conv`, so providers
/// without a packed format need nothing extra.
pub trait ConvProvider: Send + Sync {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor>;
    fn name(&self) -> &'static str;

    /// Pre-pack a layer's weights at model-load time. `None` means this
    /// provider has no packed format (callers fall back to `conv_scratch`).
    fn prepack(&self, _spec: &ConvSpec, _weights: &[f32]) -> Option<PackedWeights> {
        None
    }

    /// Conv with a caller-owned scratch arena (buffer reuse across calls).
    fn conv_scratch(
        &self,
        spec: &ConvSpec,
        input: &Tensor,
        weights: &[f32],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        self.conv(spec, input, weights)
    }

    /// Conv against weights prepacked by [`ConvProvider::prepack`];
    /// `weights` stays available as the unpacked fallback.
    fn conv_prepacked(
        &self,
        spec: &ConvSpec,
        input: &Tensor,
        weights: &[f32],
        _packed: &PackedWeights,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        self.conv_scratch(spec, input, weights, scratch)
    }

    /// Convolve a coalesced batch of same-shape inputs with one layer's
    /// weights — the worker path for cross-request shard coalescing.
    /// Every output must be bitwise identical to running its input alone
    /// through the matching single-input path; the default loop
    /// guarantees that trivially, the fallback provider overrides it
    /// with one batched im2col/GEMM pass whose N dimension spans all
    /// inputs (bitwise identity proven structurally — see
    /// `conv::gemm::conv_padded_packed_batch`).
    fn conv_batch(
        &self,
        spec: &ConvSpec,
        inputs: &[&Tensor],
        weights: &[f32],
        packed: Option<&PackedWeights>,
        scratch: &mut Scratch,
    ) -> Result<Vec<Tensor>> {
        inputs
            .iter()
            .map(|input| match packed {
                Some(pa) => self.conv_prepacked(spec, input, weights, pa, scratch),
                None => self.conv_scratch(spec, input, weights, scratch),
            })
            .collect()
    }
}

/// Pure-rust provider: im2col + the tiled multithreaded packed GEMM
/// kernel (`conv::gemm`). Always available (`cargo test` needs no
/// artifacts), and the master's executor for remainder pieces and
/// type-2 layers.
#[derive(Clone, Copy, Debug, Default)]
pub struct FallbackProvider {
    /// Kernel threads per conv; 0 = `util::threads::default_threads()`
    /// (the `COCOI_THREADS` env var, else `available_parallelism`).
    /// Thread count never changes results — the kernel is bitwise
    /// deterministic across thread counts.
    threads: usize,
}

impl FallbackProvider {
    /// Default thread configuration (auto).
    pub fn new() -> FallbackProvider {
        FallbackProvider::default()
    }

    /// Explicit kernel thread count (0 = auto).
    pub fn with_threads(threads: usize) -> FallbackProvider {
        FallbackProvider { threads }
    }

    /// Provider for an in-proc pool of `n` workers sharing this host:
    /// splits the default thread budget evenly so concurrent worker
    /// convs don't oversubscribe the machine and skew latency
    /// measurements. A real deployment (one worker per device) wants
    /// the full budget — use [`FallbackProvider::new`] there.
    pub fn for_pool(n: usize) -> FallbackProvider {
        let per = (crate::util::threads::default_threads() / n.max(1)).max(1);
        FallbackProvider { threads: per }
    }

    /// Resolved kernel thread count.
    pub fn threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::threads::default_threads()
        } else {
            self.threads
        }
    }
}

impl ConvProvider for FallbackProvider {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor> {
        let mut scratch = Scratch::new();
        self.conv_scratch(spec, input, weights, &mut scratch)
    }

    fn name(&self) -> &'static str {
        "fallback"
    }

    fn prepack(&self, spec: &ConvSpec, weights: &[f32]) -> Option<PackedWeights> {
        (weights.len() == spec.weight_len())
            .then(|| PackedA::pack(weights, spec.c_out, spec.c_in * spec.k_w * spec.k_w))
    }

    fn conv_scratch(
        &self,
        spec: &ConvSpec,
        input: &Tensor,
        weights: &[f32],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        gemm::conv_padded_tiled(spec, input, weights, self.threads(), scratch)
    }

    fn conv_prepacked(
        &self,
        spec: &ConvSpec,
        input: &Tensor,
        weights: &[f32],
        packed: &PackedWeights,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        if packed.m() != spec.c_out || packed.k() != spec.c_in * spec.k_w * spec.k_w {
            // Shape drift (e.g. a wire spec diverging from the preloaded
            // layer): fall back to the unpacked path rather than erroring.
            return self.conv_scratch(spec, input, weights, scratch);
        }
        gemm::conv_padded_packed(spec, input, packed, self.threads(), scratch)
    }

    fn conv_batch(
        &self,
        spec: &ConvSpec,
        inputs: &[&Tensor],
        weights: &[f32],
        packed: Option<&PackedWeights>,
        scratch: &mut Scratch,
    ) -> Result<Vec<Tensor>> {
        match packed {
            Some(pa) if pa.m() == spec.c_out && pa.k() == spec.c_in * spec.k_w * spec.k_w => {
                gemm::conv_padded_packed_batch(spec, inputs, pa, self.threads(), scratch)
            }
            // No (or shape-drifted) pack: pack once here, then batch.
            _ => {
                anyhow::ensure!(weights.len() == spec.weight_len(), "bad weight length");
                let pa = PackedA::pack(weights, spec.c_out, spec.c_in * spec.k_w * spec.k_w);
                gemm::conv_padded_packed_batch(spec, inputs, &pa, self.threads(), scratch)
            }
        }
    }
}

/// Which execution path a `PjrtProvider` call took (metrics/tests).
#[derive(Debug, Default)]
pub struct ProviderStats {
    pub fused: AtomicU64,
    pub tiled: AtomicU64,
    pub fallback: AtomicU64,
}

/// PJRT-backed provider with fused → tiled → fallback ladder.
pub struct PjrtProvider {
    handle: PjrtHandle,
    manifest: Arc<Manifest>,
    pub stats: Arc<ProviderStats>,
}

impl PjrtProvider {
    pub fn new(handle: PjrtHandle, manifest: Arc<Manifest>) -> PjrtProvider {
        PjrtProvider {
            handle,
            manifest,
            stats: Arc::new(ProviderStats::default()),
        }
    }

    fn try_fused(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Option<Result<Tensor>> {
        let key = ConvKey {
            c_in: spec.c_in,
            c_out: spec.c_out,
            k_w: spec.k_w,
            s_w: spec.s_w,
            h_i: input.h,
            w_i_p: input.w,
        };
        let path = self.manifest.conv_artifact(&key)?;
        let h_o = spec.out_dim_padded(input.h);
        let w_o = spec.out_dim_padded(input.w);
        let result = self
            .handle
            .execute(
                path,
                vec![
                    (vec![input.c, input.h, input.w], input.data.clone()),
                    (
                        vec![spec.c_out, spec.c_in, spec.k_w, spec.k_w],
                        weights.to_vec(),
                    ),
                ],
            )
            .and_then(|flat| Tensor::from_vec(spec.c_out, h_o, w_o, flat));
        Some(result)
    }

    /// Shape-polymorphic path: rust im2col + padding to the artifact's
    /// fixed GEMM tile, accumulating tiles in rust.
    fn try_tiled(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Option<Result<Tensor>> {
        let (tm, tk, tn, path) = self.manifest.best_gemm_tile()?;
        let h_o = spec.out_dim_padded(input.h);
        let w_o = spec.out_dim_padded(input.w);
        let m = spec.c_out;
        let kk = spec.c_in * spec.k_w * spec.k_w;
        let n = h_o * w_o;
        let patches = im2col::im2col(input, spec.k_w, spec.s_w); // (kk, n)

        let pad_to = |x: usize, t: usize| x.div_ceil(t) * t;
        let (pm, pk, pn) = (pad_to(m, tm), pad_to(kk, tk), pad_to(n, tn));
        // Tile-padded copies (row-major).
        let mut a = vec![0f32; pm * pk];
        for i in 0..m {
            a[i * pk..i * pk + kk].copy_from_slice(&weights[i * kk..(i + 1) * kk]);
        }
        let mut b = vec![0f32; pk * pn];
        for i in 0..kk {
            b[i * pn..i * pn + n].copy_from_slice(&patches[i * n..(i + 1) * n]);
        }

        let mut c = vec![0f32; pm * pn];
        let result = (|| -> Result<()> {
            for bi in 0..pm / tm {
                for bj in 0..pn / tn {
                    let mut acc = vec![0f32; tm * tn];
                    for bl in 0..pk / tk {
                        // Gather tiles.
                        let mut at = vec![0f32; tm * tk];
                        for r in 0..tm {
                            let src = (bi * tm + r) * pk + bl * tk;
                            at[r * tk..(r + 1) * tk].copy_from_slice(&a[src..src + tk]);
                        }
                        let mut bt = vec![0f32; tk * tn];
                        for r in 0..tk {
                            let src = (bl * tk + r) * pn + bj * tn;
                            bt[r * tn..(r + 1) * tn].copy_from_slice(&b[src..src + tn]);
                        }
                        let out = self.handle.execute(
                            path,
                            vec![(vec![tm, tk], at), (vec![tk, tn], bt)],
                        )?;
                        for (av, ov) in acc.iter_mut().zip(&out) {
                            *av += ov;
                        }
                    }
                    for r in 0..tm {
                        let dst = (bi * tm + r) * pn + bj * tn;
                        c[dst..dst + tn].copy_from_slice(&acc[r * tn..(r + 1) * tn]);
                    }
                }
            }
            Ok(())
        })();
        if let Err(e) = result {
            return Some(Err(e));
        }
        // Strip padding.
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            out[i * n..(i + 1) * n].copy_from_slice(&c[i * pn..i * pn + n]);
        }
        Some(Tensor::from_vec(spec.c_out, h_o, w_o, out))
    }
}

impl ConvProvider for PjrtProvider {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor> {
        if let Some(r) = self.try_fused(spec, input, weights) {
            self.stats.fused.fetch_add(1, Ordering::Relaxed);
            return r;
        }
        if let Some(r) = self.try_tiled(spec, input, weights) {
            self.stats.tiled.fetch_add(1, Ordering::Relaxed);
            return r;
        }
        log::debug!(
            "no artifact for conv {}x{} k{} s{} h{} w{}; pure-rust fallback",
            spec.c_in,
            spec.c_out,
            spec.k_w,
            spec.s_w,
            input.h,
            input.w
        );
        self.stats.fallback.fetch_add(1, Ordering::Relaxed);
        FallbackProvider::new().conv(spec, input, weights)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fallback_matches_direct() {
        let spec = ConvSpec::new(3, 5, 3, 1, 0);
        let mut rng = Rng::new(2);
        let mut input = Tensor::zeros(3, 8, 11);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut w = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let out = FallbackProvider::new().conv(&spec, &input, &w).unwrap();
        let direct = crate::conv::layer::conv_direct(&spec, &input, &w);
        assert!(out.max_abs_diff(&direct) < 1e-4);
    }

    #[test]
    fn scratch_and_prepacked_paths_agree_bitwise() {
        let spec = ConvSpec::new(4, 9, 3, 1, 0);
        let mut rng = Rng::new(8);
        let mut input = Tensor::zeros(4, 9, 13);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut w = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let p = FallbackProvider::with_threads(2);
        let plain = p.conv(&spec, &input, &w).unwrap();
        let mut scratch = crate::conv::Scratch::new();
        let scratched = p.conv_scratch(&spec, &input, &w, &mut scratch).unwrap();
        let packed = p.prepack(&spec, &w).unwrap();
        let prepacked = p
            .conv_prepacked(&spec, &input, &w, &packed, &mut scratch)
            .unwrap();
        assert_eq!(plain.data, scratched.data);
        assert_eq!(plain.data, prepacked.data);
    }

    /// The coalescing contract at the provider level: a batched call
    /// returns exactly the per-input single-call results, with and
    /// without prepacked weights.
    #[test]
    fn conv_batch_matches_singles_bitwise() {
        let spec = ConvSpec::new(3, 7, 3, 1, 0);
        let mut rng = Rng::new(21);
        let inputs: Vec<Tensor> = (0..3)
            .map(|_| {
                let mut t = Tensor::zeros(3, 9, 11);
                rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
                t
            })
            .collect();
        let mut w = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let p = FallbackProvider::with_threads(2);
        let packed = p.prepack(&spec, &w).unwrap();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let mut scratch = Scratch::new();
        for pack in [Some(&packed), None] {
            let batched = p.conv_batch(&spec, &refs, &w, pack, &mut scratch).unwrap();
            for (input, got) in inputs.iter().zip(&batched) {
                let solo = p
                    .conv_prepacked(&spec, input, &w, &packed, &mut scratch)
                    .unwrap();
                assert_eq!(solo.data, got.data, "pack={:?}", pack.is_some());
            }
        }
    }
}
