//! Conv execution providers: how a worker actually runs its subtask.
//!
//! * [`FallbackProvider`] — pure-rust im2col + GEMM. Always available
//!   (`cargo test` needs no artifacts), and the master's executor for
//!   remainder pieces and type-2 layers.
//! * [`PjrtProvider`] — the production path: per-shape **fused** AOT
//!   artifacts through the PJRT service; shape-polymorphic **tile** GEMM
//!   artifacts when no fused artifact matches; falls back to pure rust as
//!   the last resort (logged, counted).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::conv::im2col;
use crate::conv::{ConvSpec, Tensor};

use super::artifacts::{ConvKey, Manifest};
use super::pjrt::PjrtHandle;

/// Uniform interface: valid conv of an already-padded input partition
/// (pure linear map — no bias/activation; see coding docs).
pub trait ConvProvider: Send + Sync {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor>;
    fn name(&self) -> &'static str;
}

/// Pure-rust provider (im2col + blocked GEMM).
#[derive(Clone, Copy, Debug, Default)]
pub struct FallbackProvider;

impl ConvProvider for FallbackProvider {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor> {
        spec.conv_padded(input, weights)
    }

    fn name(&self) -> &'static str {
        "fallback"
    }
}

/// Which execution path a `PjrtProvider` call took (metrics/tests).
#[derive(Debug, Default)]
pub struct ProviderStats {
    pub fused: AtomicU64,
    pub tiled: AtomicU64,
    pub fallback: AtomicU64,
}

/// PJRT-backed provider with fused → tiled → fallback ladder.
pub struct PjrtProvider {
    handle: PjrtHandle,
    manifest: Arc<Manifest>,
    pub stats: Arc<ProviderStats>,
}

impl PjrtProvider {
    pub fn new(handle: PjrtHandle, manifest: Arc<Manifest>) -> PjrtProvider {
        PjrtProvider {
            handle,
            manifest,
            stats: Arc::new(ProviderStats::default()),
        }
    }

    fn try_fused(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Option<Result<Tensor>> {
        let key = ConvKey {
            c_in: spec.c_in,
            c_out: spec.c_out,
            k_w: spec.k_w,
            s_w: spec.s_w,
            h_i: input.h,
            w_i_p: input.w,
        };
        let path = self.manifest.conv_artifact(&key)?;
        let h_o = spec.out_dim_padded(input.h);
        let w_o = spec.out_dim_padded(input.w);
        let result = self
            .handle
            .execute(
                path,
                vec![
                    (vec![input.c, input.h, input.w], input.data.clone()),
                    (
                        vec![spec.c_out, spec.c_in, spec.k_w, spec.k_w],
                        weights.to_vec(),
                    ),
                ],
            )
            .and_then(|flat| Tensor::from_vec(spec.c_out, h_o, w_o, flat));
        Some(result)
    }

    /// Shape-polymorphic path: rust im2col + padding to the artifact's
    /// fixed GEMM tile, accumulating tiles in rust.
    fn try_tiled(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Option<Result<Tensor>> {
        let (tm, tk, tn, path) = self.manifest.best_gemm_tile()?;
        let h_o = spec.out_dim_padded(input.h);
        let w_o = spec.out_dim_padded(input.w);
        let m = spec.c_out;
        let kk = spec.c_in * spec.k_w * spec.k_w;
        let n = h_o * w_o;
        let patches = im2col::im2col(input, spec.k_w, spec.s_w); // (kk, n)

        let pad_to = |x: usize, t: usize| x.div_ceil(t) * t;
        let (pm, pk, pn) = (pad_to(m, tm), pad_to(kk, tk), pad_to(n, tn));
        // Tile-padded copies (row-major).
        let mut a = vec![0f32; pm * pk];
        for i in 0..m {
            a[i * pk..i * pk + kk].copy_from_slice(&weights[i * kk..(i + 1) * kk]);
        }
        let mut b = vec![0f32; pk * pn];
        for i in 0..kk {
            b[i * pn..i * pn + n].copy_from_slice(&patches[i * n..(i + 1) * n]);
        }

        let mut c = vec![0f32; pm * pn];
        let result = (|| -> Result<()> {
            for bi in 0..pm / tm {
                for bj in 0..pn / tn {
                    let mut acc = vec![0f32; tm * tn];
                    for bl in 0..pk / tk {
                        // Gather tiles.
                        let mut at = vec![0f32; tm * tk];
                        for r in 0..tm {
                            let src = (bi * tm + r) * pk + bl * tk;
                            at[r * tk..(r + 1) * tk].copy_from_slice(&a[src..src + tk]);
                        }
                        let mut bt = vec![0f32; tk * tn];
                        for r in 0..tk {
                            let src = (bl * tk + r) * pn + bj * tn;
                            bt[r * tn..(r + 1) * tn].copy_from_slice(&b[src..src + tn]);
                        }
                        let out = self.handle.execute(
                            path,
                            vec![(vec![tm, tk], at), (vec![tk, tn], bt)],
                        )?;
                        for (av, ov) in acc.iter_mut().zip(&out) {
                            *av += ov;
                        }
                    }
                    for r in 0..tm {
                        let dst = (bi * tm + r) * pn + bj * tn;
                        c[dst..dst + tn].copy_from_slice(&acc[r * tn..(r + 1) * tn]);
                    }
                }
            }
            Ok(())
        })();
        if let Err(e) = result {
            return Some(Err(e));
        }
        // Strip padding.
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            out[i * n..(i + 1) * n].copy_from_slice(&c[i * pn..i * pn + n]);
        }
        Some(Tensor::from_vec(spec.c_out, h_o, w_o, out))
    }
}

impl ConvProvider for PjrtProvider {
    fn conv(&self, spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Result<Tensor> {
        if let Some(r) = self.try_fused(spec, input, weights) {
            self.stats.fused.fetch_add(1, Ordering::Relaxed);
            return r;
        }
        if let Some(r) = self.try_tiled(spec, input, weights) {
            self.stats.tiled.fetch_add(1, Ordering::Relaxed);
            return r;
        }
        log::debug!(
            "no artifact for conv {}x{} k{} s{} h{} w{}; pure-rust fallback",
            spec.c_in,
            spec.c_out,
            spec.k_w,
            spec.s_w,
            input.h,
            input.w
        );
        self.stats.fallback.fetch_add(1, Ordering::Relaxed);
        FallbackProvider.conv(spec, input, weights)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fallback_matches_direct() {
        let spec = ConvSpec::new(3, 5, 3, 1, 0);
        let mut rng = Rng::new(2);
        let mut input = Tensor::zeros(3, 8, 11);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut w = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let out = FallbackProvider.conv(&spec, &input, &w).unwrap();
        let direct = crate::conv::layer::conv_direct(&spec, &input, &w);
        assert!(out.max_abs_diff(&direct) < 1e-4);
    }
}
