//! AOT artifact discovery: `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) mapped to typed entries, with shape-keyed
//! lookup for conv subtasks.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Shape key identifying a conv-subtask artifact (layer-agnostic: two
/// layers with the same geometry share one executable).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ConvKey {
    pub c_in: usize,
    pub c_out: usize,
    pub k_w: usize,
    pub s_w: usize,
    pub h_i: usize,
    pub w_i_p: usize,
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub enum Artifact {
    ConvSubtask { key: ConvKey, file: PathBuf },
    GemmTile { m: usize, k: usize, n: usize, file: PathBuf },
    Encode { n: usize, k: usize, m_len: usize, file: PathBuf },
}

/// Parsed manifest with lookup indices.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub conv: BTreeMap<ConvKey, PathBuf>,
    pub gemm: Vec<(usize, usize, usize, PathBuf)>,
    pub encode: Vec<(usize, usize, usize, PathBuf)>,
}

/// Artifact directory: `$COCOI_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("COCOI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl Manifest {
    /// Load `<dir>/manifest.json`. Missing manifest is an error — callers
    /// that want graceful degradation use [`Manifest::load_or_empty`].
    pub fn load(dir: &Path) -> Result<Manifest> {
        let doc = Json::parse_file(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let mut m = Manifest {
            dir: dir.to_path_buf(),
            ..Default::default()
        };
        for a in doc.req_arr("artifacts")? {
            let file = dir.join(a.req_str("file")?);
            match a.req_str("kind")? {
                "conv_subtask" => {
                    let key = ConvKey {
                        c_in: a.req_usize("c_in")?,
                        c_out: a.req_usize("c_out")?,
                        k_w: a.req_usize("k_w")?,
                        s_w: a.req_usize("s_w")?,
                        h_i: a.req_usize("h_i")?,
                        w_i_p: a.req_usize("w_i_p")?,
                    };
                    m.conv.insert(key, file);
                }
                "gemm_tile" => m.gemm.push((
                    a.req_usize("m")?,
                    a.req_usize("k")?,
                    a.req_usize("n")?,
                    file,
                )),
                "encode" => m.encode.push((
                    a.req_usize("n")?,
                    a.req_usize("k")?,
                    a.req_usize("m_len")?,
                    file,
                )),
                other => anyhow::bail!("unknown artifact kind '{other}'"),
            }
        }
        Ok(m)
    }

    /// Load if present; empty manifest (pure-rust fallback only) if not.
    pub fn load_or_empty(dir: &Path) -> Manifest {
        match Self::load(dir) {
            Ok(m) => m,
            Err(e) => {
                log::warn!("no artifact manifest ({e:#}); using fallback provider only");
                Manifest {
                    dir: dir.to_path_buf(),
                    ..Default::default()
                }
            }
        }
    }

    pub fn conv_artifact(&self, key: &ConvKey) -> Option<&PathBuf> {
        self.conv.get(key)
    }

    /// Largest gemm tile (the provider pads up to it).
    pub fn best_gemm_tile(&self) -> Option<(usize, usize, usize, &PathBuf)> {
        self.gemm
            .iter()
            .max_by_key(|(m, k, n, _)| m * k * n)
            .map(|(m, k, n, p)| (*m, *k, *n, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_synthetic_manifest() {
        let dir = std::env::temp_dir().join("cocoi_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "n_workers": 6, "artifacts": [
              {"kind": "conv_subtask", "name": "c", "file": "c.hlo.txt",
               "c_in": 3, "c_out": 8, "k_w": 3, "s_w": 1, "h_i": 10, "w_i_p": 7,
               "h_o": 8, "w_o_p": 5, "uses": []},
              {"kind": "gemm_tile", "name": "g", "file": "g.hlo.txt",
               "m": 128, "k": 128, "n": 128},
              {"kind": "encode", "name": "e", "file": "e.hlo.txt",
               "n": 6, "k": 3, "m_len": 8192}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let key = ConvKey {
            c_in: 3,
            c_out: 8,
            k_w: 3,
            s_w: 1,
            h_i: 10,
            w_i_p: 7,
        };
        assert!(m.conv_artifact(&key).is_some());
        assert_eq!(m.best_gemm_tile().unwrap().0, 128);
        assert_eq!(m.encode.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_degrades_to_empty() {
        let m = Manifest::load_or_empty(Path::new("/nonexistent/xyz"));
        assert!(m.conv.is_empty());
    }
}
