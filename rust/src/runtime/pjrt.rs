//! PJRT runtime service.
//!
//! The `xla` crate's client/executable wrappers hold raw C++ pointers and
//! are not `Send`; a dedicated **service thread** owns the `PjRtClient`
//! and the compiled-executable cache, and worker threads talk to it
//! through a channel. On this 1-core testbed PJRT executions serialize
//! anyway, so the service thread costs nothing and keeps ownership sound.
//!
//! Artifacts are HLO *text* (`HloModuleProto::from_text_file`), compiled
//! on first use and cached by path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

use anyhow::{Context, Result};

/// A request: run artifact at `path` with the given f32 inputs.
struct ExecRequest {
    path: PathBuf,
    /// (shape, row-major f32 data) per parameter.
    inputs: Vec<(Vec<usize>, Vec<f32>)>,
    reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Exec(ExecRequest),
    /// Pre-compile an artifact (warm the cache).
    Warm(PathBuf, mpsc::Sender<Result<()>>),
    Stats(mpsc::Sender<RuntimeStats>),
    Shutdown,
}

/// Counters exposed for metrics/tests.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub cache_hits: u64,
}

/// Cloneable handle to the PJRT service thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Msg>,
}

/// The service thread plus its join guard.
pub struct PjrtService {
    handle: PjrtHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl PjrtService {
    /// Spawn the service thread (creates the CPU PJRT client inside it).
    pub fn spawn() -> Result<PjrtService> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(rx, ready_tx))
            .context("spawning pjrt service thread")?;
        ready_rx
            .recv()
            .context("pjrt service thread died during init")??;
        Ok(PjrtService {
            handle: PjrtHandle { tx },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> PjrtHandle {
        self.handle.clone()
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl PjrtHandle {
    /// Execute an artifact: inputs are (shape, data) pairs in parameter
    /// order; returns the flattened f32 output (artifacts return a
    /// 1-tuple — `return_tuple=True` at lowering).
    pub fn execute(&self, path: &Path, inputs: Vec<(Vec<usize>, Vec<f32>)>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Exec(ExecRequest {
                path: path.to_path_buf(),
                inputs,
                reply,
            }))
            .map_err(|_| anyhow::anyhow!("pjrt service is gone"))?;
        rx.recv().context("pjrt service dropped the reply")?
    }

    /// Compile (and cache) an artifact without executing it.
    pub fn warm(&self, path: &Path) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Warm(path.to_path_buf(), reply))
            .map_err(|_| anyhow::anyhow!("pjrt service is gone"))?;
        rx.recv().context("pjrt service dropped the reply")?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats(reply))
            .map_err(|_| anyhow::anyhow!("pjrt service is gone"))?;
        Ok(rx.recv()?)
    }
}

fn service_main(rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow::anyhow!("creating PJRT CPU client: {e}")));
            return;
        }
    };
    log::info!(
        "pjrt service up: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let mut cache: HashMap<PathBuf, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut stats = RuntimeStats::default();

    let compile =
        |cache: &mut HashMap<PathBuf, xla::PjRtLoadedExecutable>,
         stats: &mut RuntimeStats,
         path: &PathBuf|
         -> Result<()> {
            if cache.contains_key(path) {
                stats.cache_hits += 1;
                return Ok(());
            }
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
            stats.compiles += 1;
            log::debug!(
                "compiled {} in {:.1} ms",
                path.display(),
                t0.elapsed().as_secs_f64() * 1e3
            );
            cache.insert(path.clone(), exe);
            Ok(())
        };

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Warm(path, reply) => {
                let _ = reply.send(compile(&mut cache, &mut stats, &path));
            }
            Msg::Stats(reply) => {
                let _ = reply.send(stats);
            }
            Msg::Exec(req) => {
                let result = (|| -> Result<Vec<f32>> {
                    compile(&mut cache, &mut stats, &req.path)?;
                    let exe = cache.get(&req.path).unwrap();
                    let literals: Vec<xla::Literal> = req
                        .inputs
                        .iter()
                        .map(|(shape, data)| {
                            let bytes: &[u8] = unsafe {
                                std::slice::from_raw_parts(
                                    data.as_ptr() as *const u8,
                                    data.len() * 4,
                                )
                            };
                            xla::Literal::create_from_shape_and_untyped_data(
                                xla::ElementType::F32,
                                shape,
                                bytes,
                            )
                            .map_err(|e| anyhow::anyhow!("building literal: {e}"))
                        })
                        .collect::<Result<_>>()?;
                    let out = exe
                        .execute::<xla::Literal>(&literals)
                        .map_err(|e| anyhow::anyhow!("executing {}: {e}", req.path.display()))?;
                    stats.executions += 1;
                    let lit = out[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("fetching output: {e}"))?;
                    let inner = lit
                        .to_tuple1()
                        .map_err(|e| anyhow::anyhow!("untupling output: {e}"))?;
                    inner
                        .to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("reading output: {e}"))
                })();
                let _ = req.reply.send(result);
            }
        }
    }
    log::info!("pjrt service shutting down ({stats:?})");
}
