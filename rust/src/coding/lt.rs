//! Luby-Transform (LT) rateless coding over real partitions (paper App. G,
//! benchmarks `LtCoI-k_l` / `LtCoI-k_s`).
//!
//! Degrees are sampled from the Robust Soliton distribution; each encoded
//! symbol is the *sum* of `d` uniformly chosen source partitions, with the
//! 0/1 encoding vector carried alongside. Decoding is incremental Gaussian
//! elimination over the received encoding vectors (the paper's
//! rank-tracking GE, App. G): once rank `k` is reached, the selected
//! independent subset solves for the source outputs.
//!
//! Because LT is rateless, a batch dispatch must pick a symbol budget; the
//! paper streams symbols until rank `k`. We expose `symbol_budget` (default
//! `2k + 16`) — the coordinator can re-issue further rounds if the rank is
//! deficient, matching the paper's "continuously created" coroutine loop.

use super::matrix::{apply_f32, Matrix};
use super::{Decoder, EncodedTask, RedundancyScheme};
use crate::util::Rng;

/// Robust Soliton parameters (standard choices; see Mallick et al. [17]).
pub const SOLITON_C: f64 = 0.1;
pub const SOLITON_DELTA: f64 = 0.05;

/// Robust Soliton probability mass over degrees `1..=k`.
pub fn robust_soliton(k: usize) -> Vec<f64> {
    assert!(k >= 1);
    if k == 1 {
        return vec![1.0];
    }
    let kf = k as f64;
    let r = SOLITON_C * (kf / SOLITON_DELTA).ln() * kf.sqrt();
    let spike = ((kf / r).floor() as usize).clamp(1, k);
    let mut p = vec![0.0; k + 1]; // index = degree
    // Ideal soliton rho.
    p[1] = 1.0 / kf;
    for d in 2..=k {
        p[d] += 1.0 / (d as f64 * (d - 1) as f64);
    }
    // Robust part tau.
    for (d, item) in p.iter_mut().enumerate().take(spike).skip(1) {
        *item += r / (d as f64 * kf);
    }
    p[spike] += r * (r / SOLITON_DELTA).ln() / kf;
    let total: f64 = p.iter().sum();
    p.iter().skip(1).map(|x| x / total).collect()
}

/// LT redundancy scheme with a fixed symbol budget per round.
#[derive(Clone, Debug)]
pub struct LtCode {
    n_workers: usize,
    k: usize,
    budget: usize,
    seed: u64,
    degree_pmf: Vec<f64>,
}

impl LtCode {
    /// `n_workers` is kept for reporting (symbols round-robin over
    /// workers); `k` is the number of source partitions (may exceed
    /// `n_workers` — the paper's `LtCoI-k_l` uses `k = W_O`).
    pub fn new(n_workers: usize, k: usize, seed: u64) -> LtCode {
        assert!(k >= 1 && n_workers >= 1);
        LtCode {
            n_workers,
            k,
            budget: 2 * k + 16,
            seed,
            degree_pmf: robust_soliton(k),
        }
    }

    pub fn with_budget(mut self, budget: usize) -> LtCode {
        assert!(budget >= self.k);
        self.budget = budget;
        self
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn sample_degree(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        let mut acc = 0.0;
        for (i, &p) in self.degree_pmf.iter().enumerate() {
            acc += p;
            if u < acc {
                return i + 1;
            }
        }
        self.k
    }

    /// Deterministic encoding vectors for this round (0/1 rows, one per
    /// symbol). Symbol `i`'s row is reproducible from `seed` — the decoder
    /// regenerates it from the id rather than shipping the vector.
    pub fn encoding_vector(&self, symbol_id: usize) -> Vec<f64> {
        let mut rng = Rng::new(self.seed ^ (symbol_id as u64).wrapping_mul(0x9E37_79B9));
        let d = self.sample_degree(&mut rng);
        let chosen = rng.sample_distinct(self.k, d);
        let mut v = vec![0.0; self.k];
        for c in chosen {
            v[c] = 1.0;
        }
        v
    }
}

impl RedundancyScheme for LtCode {
    fn name(&self) -> String {
        format!("lt(k={},budget={})", self.k, self.budget)
    }

    fn source_count(&self) -> usize {
        self.k
    }

    fn num_subtasks(&self) -> usize {
        self.budget
    }

    fn min_completions(&self) -> usize {
        self.k
    }

    fn encode(&self, sources: &[Vec<f32>]) -> Vec<EncodedTask> {
        assert_eq!(sources.len(), self.k);
        let rows: Vec<&[f32]> = sources.iter().map(|s| s.as_slice()).collect();
        (0..self.budget)
            .map(|id| {
                let v = self.encoding_vector(id);
                let coeff = Matrix::from_rows(&[v]);
                // 0/1 coefficients: the f32 fast path is exact here.
                let payload = super::matrix::apply_f32_fast(&coeff, &rows)
                    .pop()
                    .unwrap();
                EncodedTask { id, payload }
            })
            .collect()
    }

    /// Additions only: expected degree × row length × symbols ≈
    /// `E[d] · budget · m` FLOPs. We use the exact per-round mean degree.
    fn encode_flops(&self, input_len: usize) -> f64 {
        let mean_degree: f64 = self
            .degree_pmf
            .iter()
            .enumerate()
            .map(|(i, p)| (i + 1) as f64 * p)
            .sum();
        mean_degree * self.budget as f64 * input_len as f64
    }

    fn decoder(&self) -> Box<dyn Decoder> {
        Box::new(LtDecoder {
            code: self.clone(),
            reduced: Vec::new(),
            kept: Vec::new(),
        })
    }
}

struct LtDecoder {
    code: LtCode,
    /// Row-reduced copies of accepted encoding vectors (for rank tracking);
    /// `reduced[i]` has its pivot at `pivot[i]` implied by position.
    reduced: Vec<(usize, Vec<f64>)>, // (pivot column, reduced row)
    /// Raw accepted symbols: (encoding vector, output row).
    kept: Vec<(Vec<f64>, Vec<f32>)>,
}

impl LtDecoder {
    /// Reduce `v` against current pivots; returns `Some((pivot, reduced))`
    /// if independent.
    fn reduce(&self, mut v: Vec<f64>) -> Option<(usize, Vec<f64>)> {
        for (p, row) in &self.reduced {
            if v[*p].abs() > 1e-9 {
                let f = v[*p] / row[*p];
                for (x, r) in v.iter_mut().zip(row) {
                    *x -= f * r;
                }
            }
        }
        let pivot = v.iter().position(|x| x.abs() > 1e-9)?;
        Some((pivot, v))
    }
}

impl Decoder for LtDecoder {
    fn add(&mut self, id: usize, output: Vec<f32>) -> bool {
        if self.ready() {
            return true;
        }
        let v = self.code.encoding_vector(id);
        if let Some((pivot, reduced)) = self.reduce(v.clone()) {
            self.reduced.push((pivot, reduced));
            self.kept.push((v, output));
        }
        self.ready()
    }

    fn ready(&self) -> bool {
        self.reduced.len() >= self.code.k
    }

    fn decode(&mut self) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            self.ready(),
            "LT decoder rank {} < k = {}",
            self.reduced.len(),
            self.code.k
        );
        let a = Matrix::from_rows(&self.kept.iter().map(|(v, _)| v.clone()).collect::<Vec<_>>());
        let inv = a.inverse()?;
        let rows: Vec<&[f32]> = self.kept.iter().map(|(_, o)| o.as_slice()).collect();
        Ok(apply_f32(&inv, &rows))
    }

    /// GE solve on k×k plus applying the inverse: ~`2k^2 m` (same order as
    /// MDS decode, eq. 12) — plus the rank-tracking reductions.
    fn decode_flops(&self, output_len: usize) -> f64 {
        2.0 * (self.code.k * self.code.k) as f64 * output_len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn soliton_is_distribution() {
        for k in [1usize, 2, 5, 20, 100] {
            let p = robust_soliton(k);
            assert_eq!(p.len(), k);
            let total: f64 = p.iter().sum();
            assert!((total - 1.0).abs() < 1e-9, "k={k} total={total}");
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn soliton_favors_low_degrees() {
        let p = robust_soliton(50);
        // Degree 2 carries the ideal-soliton bulk.
        assert!(p[1] > 0.2, "p[deg=2]={}", p[1]);
    }

    #[test]
    fn encoding_vectors_deterministic() {
        let code = LtCode::new(4, 8, 1234);
        for id in 0..20 {
            assert_eq!(code.encoding_vector(id), code.encoding_vector(id));
        }
    }

    #[test]
    fn rank_reaches_k_within_budget() {
        prop::check("lt rank reaches k", 40, |rng| {
            let k = 1 + rng.below(32);
            let code = LtCode::new(8, k, rng.next_u64());
            let sources: Vec<Vec<f32>> = (0..k).map(|i| vec![i as f32]).collect();
            let tasks = code.encode(&sources);
            let mut dec = code.decoder();
            let mut done = false;
            for t in &tasks {
                if dec.add(t.id, t.payload.clone()) {
                    done = true;
                    break;
                }
            }
            assert!(done, "k={k}: budget {} insufficient", code.num_subtasks());
            let out = dec.decode().unwrap();
            for (i, o) in out.iter().enumerate() {
                assert!((o[0] - i as f32).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn overhead_is_moderate() {
        // The paper's complaint about LT (higher effective redundancy for
        // small k) shows up as symbols-needed > k; sanity-check the decoder
        // needs less than ~1.7k symbols on average for k = 16.
        let mut total_needed = 0usize;
        let trials = 50;
        for seed in 0..trials {
            let k = 16;
            let code = LtCode::new(8, k, seed as u64 * 7 + 1);
            let sources: Vec<Vec<f32>> = (0..k).map(|i| vec![i as f32]).collect();
            let tasks = code.encode(&sources);
            let mut dec = code.decoder();
            for (used, t) in tasks.iter().enumerate() {
                if dec.add(t.id, t.payload.clone()) {
                    total_needed += used + 1;
                    break;
                }
            }
        }
        let avg = total_needed as f64 / trials as f64;
        assert!(avg > 16.0 && avg < 28.0, "avg symbols needed = {avg}");
    }
}
