//! Uncoded baseline (paper §V, benchmark "Uncoded" [8]).
//!
//! The input splits into `n` pieces, one per worker, no redundancy; the
//! master needs *all* `n` outputs. Device failures are handled above this
//! layer by the coordinator's re-dispatch path (the paper's "re-assign to
//! another worker" rule) — the scheme itself cannot tolerate any loss.

use super::{Decoder, EncodedTask, RedundancyScheme};

/// No-redundancy scheme: `k = n`, identity "code".
#[derive(Clone, Debug)]
pub struct Uncoded {
    n: usize,
}

impl Uncoded {
    pub fn new(n: usize) -> Uncoded {
        assert!(n >= 1);
        Uncoded { n }
    }
}

impl RedundancyScheme for Uncoded {
    fn name(&self) -> String {
        format!("uncoded({})", self.n)
    }

    fn source_count(&self) -> usize {
        self.n
    }

    fn num_subtasks(&self) -> usize {
        self.n
    }

    fn min_completions(&self) -> usize {
        self.n
    }

    fn encode(&self, sources: &[Vec<f32>]) -> Vec<EncodedTask> {
        assert_eq!(sources.len(), self.n);
        sources
            .iter()
            .cloned()
            .enumerate()
            .map(|(id, payload)| EncodedTask { id, payload })
            .collect()
    }

    fn encode_flops(&self, _input_len: usize) -> f64 {
        0.0
    }

    /// Every piece is unique: a failed subtask must always be re-executed
    /// (the paper's uncoded re-assignment rule, §V).
    fn needs_redispatch(
        &self,
        task_id: usize,
        received: &[usize],
        _outstanding: &[usize],
    ) -> bool {
        !received.contains(&task_id)
    }

    fn decoder(&self) -> Box<dyn Decoder> {
        Box::new(UncodedDecoder {
            outputs: vec![None; self.n],
            got: 0,
        })
    }
}

struct UncodedDecoder {
    outputs: Vec<Option<Vec<f32>>>,
    got: usize,
}

impl Decoder for UncodedDecoder {
    fn add(&mut self, id: usize, output: Vec<f32>) -> bool {
        if self.outputs[id].is_none() {
            self.outputs[id] = Some(output);
            self.got += 1;
        }
        self.ready()
    }

    fn ready(&self) -> bool {
        self.got == self.outputs.len()
    }

    fn decode(&mut self) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(self.ready(), "uncoded decoder is missing outputs");
        Ok(self.outputs.iter_mut().map(|o| o.take().unwrap()).collect())
    }

    fn decode_flops(&self, _output_len: usize) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_every_output() {
        let s = Uncoded::new(3);
        let tasks = s.encode(&[vec![1.0], vec![2.0], vec![3.0]]);
        let mut d = s.decoder();
        assert!(!d.add(tasks[0].id, tasks[0].payload.clone()));
        assert!(!d.add(tasks[2].id, tasks[2].payload.clone()));
        assert!(d.decode().is_err());
        assert!(d.add(tasks[1].id, tasks[1].payload.clone()));
        let out = d.decode().unwrap();
        assert_eq!(out, vec![vec![1.0], vec![2.0], vec![3.0]]);
    }
}
