//! Redundancy schemes: MDS (the CoCoI code), LT (App. G), replication,
//! and uncoded — all behind one [`RedundancyScheme`] interface so the
//! coordinator pipeline and the simulator treat them uniformly.
//!
//! All schemes operate on *flattened* partitions (`Vec<f32>` rows): the
//! conv layer is linear in its input, so any linear combination of input
//! partitions convolves to the same linear combination of output
//! partitions — that is the property every scheme here exploits (and why
//! the distributed subtask is the *pure* convolution: bias/activation are
//! applied by the master after decode).

pub mod lt;
pub mod matrix;
pub mod mds;
pub mod replication;
pub mod select;
pub mod uncoded;

pub use lt::LtCode;
pub use mds::MdsCode;
pub use replication::Replication;
pub use select::{SchemeChoice, SchemeKind, SchemeSelector, SelectorConfig};
pub use uncoded::Uncoded;

/// One encoded subtask produced by a scheme's `encode`.
#[derive(Clone, Debug)]
pub struct EncodedTask {
    /// Scheme-local task id in `[0, num_subtasks)`.
    pub id: usize,
    /// Flattened encoded input partition.
    pub payload: Vec<f32>,
}

/// Incremental decoder for one coded computation round.
///
/// The master feeds completed subtask outputs via [`Decoder::add`]; once it
/// returns `true`, [`Decoder::decode`] recovers the `k` source outputs.
pub trait Decoder: Send {
    /// Feed the output of subtask `id`. Returns `true` once the source
    /// outputs are recoverable.
    fn add(&mut self, id: usize, output: Vec<f32>) -> bool;

    /// Whether enough outputs have been gathered.
    fn ready(&self) -> bool;

    /// Recover the `k` source outputs, in source order. Panics or errors if
    /// `!ready()`.
    fn decode(&mut self) -> anyhow::Result<Vec<Vec<f32>>>;

    /// FLOP count of the decode step (for the latency model / metrics).
    fn decode_flops(&self, output_len: usize) -> f64;
}

/// A redundancy scheme: how `k` source partitions become `num_subtasks`
/// dispatched payloads, and how outputs decode back.
pub trait RedundancyScheme: Send + Sync {
    /// Short name used in tables ("mds", "uncoded", "rep2", "lt").
    fn name(&self) -> String;

    /// Number of source partitions `k` the input must be split into.
    fn source_count(&self) -> usize;

    /// Number of subtasks dispatched to workers.
    fn num_subtasks(&self) -> usize;

    /// Minimum number of completed subtasks that can possibly decode
    /// (used by the scheduler to size its first wait).
    fn min_completions(&self) -> usize;

    /// Encode `k` flattened source partitions into subtask payloads.
    /// All sources must have equal length.
    fn encode(&self, sources: &[Vec<f32>]) -> Vec<EncodedTask>;

    /// After subtask `task_id` failed: must the master re-dispatch it for
    /// the round to stay completable? `received` are task ids already
    /// delivered, `outstanding` are dispatched-and-alive task ids
    /// (excluding the failed one).
    ///
    /// Default (coded schemes): re-dispatch only when the pool of
    /// received + outstanding can no longer reach `min_completions`.
    fn needs_redispatch(
        &self,
        _task_id: usize,
        received: &[usize],
        outstanding: &[usize],
    ) -> bool {
        received.len() + outstanding.len() < self.min_completions()
    }

    /// FLOP count of the encode step (eq. 8 for MDS).
    fn encode_flops(&self, input_len: usize) -> f64;

    /// Fresh decoder for one round.
    fn decoder(&self) -> Box<dyn Decoder>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    /// Every scheme must satisfy: encoding k random sources, completing a
    /// random sufficient subset of subtasks through a *linear* map, then
    /// decoding, recovers the mapped sources. The linear map stands in for
    /// the convolution.
    fn roundtrip_property(scheme: &dyn RedundancyScheme, rng: &mut Rng) {
        let k = scheme.source_count();
        let len = 1 + rng.below(64);
        let sources: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..len).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
            .collect();
        let tasks = scheme.encode(&sources);
        assert_eq!(tasks.len(), scheme.num_subtasks());

        // Linear "computation": y = 2x (element-wise), keeps lengths equal.
        let mut decoder = scheme.decoder();
        let mut order: Vec<usize> = (0..tasks.len()).collect();
        rng.shuffle(&mut order);
        let mut done = false;
        for &t in &order {
            let out: Vec<f32> = tasks[t].payload.iter().map(|x| 2.0 * x).collect();
            if decoder.add(tasks[t].id, out) {
                done = true;
                break;
            }
        }
        assert!(done, "scheme {} never became decodable", scheme.name());
        let decoded = decoder.decode().unwrap();
        assert_eq!(decoded.len(), k);
        for (d, s) in decoded.iter().zip(&sources) {
            for (a, b) in d.iter().zip(s.iter()) {
                assert!(
                    (a - 2.0 * b).abs() < 1e-3,
                    "scheme {} decode mismatch: {a} vs {}",
                    scheme.name(),
                    2.0 * b
                );
            }
        }
    }

    #[test]
    fn all_schemes_roundtrip() {
        prop::check("scheme roundtrips", 48, |rng| {
            let n = 4 + rng.below(7); // 4..=10
            let k = 1 + rng.below(n); // 1..=n
            roundtrip_property(&MdsCode::new(n, k), rng);
            roundtrip_property(&Uncoded::new(n), rng);
            if n >= 2 {
                roundtrip_property(&Replication::new(n), rng);
            }
            let kl = 1 + rng.below(2 * n);
            roundtrip_property(&LtCode::new(n, kl, rng.next_u64()), rng);
        });
    }
}
