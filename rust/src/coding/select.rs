//! Per-layer, per-request coding-scheme selection — the decision layer
//! between the planner and the wire.
//!
//! [`SchemeKind`] names the redundancy schemes the coordinator can put
//! on a round (it used to live in `coordinator::master`; it moved here
//! so the model plan can carry a per-layer scheme without depending on
//! the coordinator). [`SchemeSelector`] is the policy that picks one:
//!
//! * **k-circ MDS** is the default mid-range choice — the paper's
//!   mean-optimal split.
//! * **Replication** wins when the fitted profile says the master's
//!   encode/decode cost outweighs replication's larger-shard
//!   transmission — compute-light ("tiny") layers on fast links, or a
//!   master busy enough that coding FLOPs are the bottleneck.
//!   Replication encodes by memcpy and decodes by picking the surviving
//!   copy: zero master FLOPs.
//! * **LT (rateless)** wins under churn and impossible deadlines: a
//!   round completes the moment *any* k' useful symbols arrive, so a
//!   mid-round eviction needs no re-dispatch and a joiner needs no
//!   (n, k) re-solve — symbols just keep streaming.
//!
//! Redundancy under a deadline is Dutta-style: instead of a fixed
//! (n, k) split, the largest k whose fitted *tail quantile*
//! ([`l_tail_quantile`]) fits the request's remaining slack is used —
//! and when even k = 1 misses, the layer flips to LT.

use super::lt::robust_soliton;
use super::{LtCode, MdsCode, RedundancyScheme, Replication, Uncoded};
use crate::latency::approx::{l_integer, l_tail_quantile};
use crate::latency::order_stats::harmonic_factor;
use crate::latency::phases::{LayerDims, SystemProfile};
use crate::planner::deadline::solve_deadline_k;

/// Redundancy scheme selector (the §V method column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// CoCoI: (n, k)-MDS with planner-chosen k.
    Mds,
    /// Uncoded [8]: k = n, re-dispatch on failure.
    Uncoded,
    /// Replication [15]: k = ⌊n/2⌋, two copies each.
    Replication,
    /// LtCoI-k_l: LT with finest split k_l = W_O.
    LtFine,
    /// LtCoI-k_s: LT with the planner's k (≤ n).
    LtCoarse,
    /// Per-layer, per-request selection by [`SchemeSelector`]: the
    /// master resolves this to one of the concrete kinds above at each
    /// round from fitted profiles, churn, and deadline slack.
    Auto,
}

impl SchemeKind {
    /// Instantiate for one layer round. `Auto` must be resolved by the
    /// selector before a round is prepared; as a defensive fallback it
    /// instantiates the MDS default.
    pub fn make(
        &self,
        n_workers: usize,
        k_planned: usize,
        w_o: usize,
        seed: u64,
    ) -> Box<dyn RedundancyScheme> {
        match self {
            SchemeKind::Mds | SchemeKind::Auto => {
                Box::new(MdsCode::new(n_workers, k_planned.min(n_workers)))
            }
            SchemeKind::Uncoded => Box::new(Uncoded::new(n_workers.min(w_o).max(1))),
            SchemeKind::Replication => Box::new(Replication::new(n_workers.max(2))),
            SchemeKind::LtFine => Box::new(LtCode::new(n_workers, w_o, seed)),
            SchemeKind::LtCoarse => {
                Box::new(LtCode::new(n_workers, k_planned.min(n_workers), seed))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Mds => "cocoi-mds",
            SchemeKind::Uncoded => "uncoded",
            SchemeKind::Replication => "replication",
            SchemeKind::LtFine => "ltcoi-kl",
            SchemeKind::LtCoarse => "ltcoi-ks",
            SchemeKind::Auto => "auto",
        }
    }
}

/// Selector tuning.
#[derive(Clone, Copy, Debug)]
pub struct SelectorConfig {
    /// Membership events (join/evict/retire) inside the master's recent
    /// churn window that flip distributed layers to rateless LT.
    pub churn_threshold: usize,
    /// Normal-style quantile score the deadline rule budgets for
    /// (1.65 ≈ p95): redundancy is sized so the layer's *tail*, not its
    /// mean, fits the remaining slack.
    pub z_quantile: f64,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig {
            churn_threshold: 2,
            z_quantile: 1.65,
        }
    }
}

/// One resolved choice: the scheme, its split, and the predicted layer
/// latency the choice was ranked by (seconds; the replanner's
/// hysteresis compares these).
#[derive(Clone, Copy, Debug)]
pub struct SchemeChoice {
    pub kind: SchemeKind,
    pub k: usize,
    pub predicted: f64,
}

/// The per-layer scheme policy. Deterministic: same inputs, same choice.
#[derive(Clone, Copy, Debug, Default)]
pub struct SchemeSelector {
    pub config: SelectorConfig,
}

/// Symbols an LT decoder at split `k` typically needs before the GE
/// rank reaches `k` (robust-soliton overhead ≈ O(√k·ln²) — matches the
/// repo's measured ~1.2–1.7k for small k).
pub fn lt_symbols_needed(k: usize) -> usize {
    k + (2.0 * (k as f64).sqrt()).ceil() as usize + 2
}

/// The dispatch budget [`LtCode::new`] uses for split `k` (kept in sync
/// with `coding::lt`).
pub fn lt_budget(k: usize) -> usize {
    2 * k + 16
}

impl SchemeSelector {
    pub fn new(config: SelectorConfig) -> SchemeSelector {
        SchemeSelector { config }
    }

    /// Predicted expected latency (seconds) of one round of `kind` at
    /// split `k` on an `n`-worker pool — the ranking function behind
    /// [`SchemeSelector::choose`]. Mirrors [`l_integer`]'s phase model,
    /// extended with the per-message floor `θ_msg` (which is what makes
    /// fine-grained LT pay for its symbol count) and each scheme's own
    /// master-side encode/decode cost.
    pub fn predict(
        &self,
        kind: SchemeKind,
        dims: &LayerDims,
        p: &SystemProfile,
        n: usize,
        k: usize,
    ) -> f64 {
        let n = n.max(1);
        let cap = n.min(dims.w_o).max(1);
        let k = k.clamp(1, cap);
        let kf = k as f64;
        let worker_theta = |kf: f64| {
            dims.n_rec(kf) * p.theta_rec
                + dims.n_cmp(kf) * p.theta_cmp
                + dims.n_sen(kf) * p.theta_sen
                + 2.0 * p.theta_msg
        };
        let worker_mu = |kf: f64| {
            dims.n_rec(kf) / p.mu_rec + dims.n_cmp(kf) / p.mu_cmp + dims.n_sen(kf) / p.mu_sen
        };
        match kind {
            SchemeKind::Mds | SchemeKind::Auto => {
                let enc_dec =
                    (dims.n_enc(n, kf) + dims.n_dec(kf)) * (1.0 / p.mu_m + p.theta_m);
                enc_dec + worker_theta(kf) + worker_mu(kf) * harmonic_factor(n, k)
            }
            SchemeKind::Uncoded => {
                // All n_u = min(n, W_O) pieces needed: the order factor
                // is the max (H_{n_u}); no master coding at all.
                let nu = n.min(dims.w_o).max(1);
                let nf = nu as f64;
                worker_theta(nf) + worker_mu(nf) * harmonic_factor(nu, nu)
            }
            SchemeKind::Replication => {
                // k_rep = ⌊n/2⌋ sources, two copies each. Each pair's
                // min-of-2 halves the exponential scale; completion is
                // the max over the k_rep pairs ⇒ H_{k_rep}/2. Encode is
                // a memcpy and decode picks the surviving copy: zero
                // master FLOPs — replication's whole appeal.
                let k_rep = (n / 2).max(1).min(cap);
                let kf = k_rep as f64;
                worker_theta(kf) + worker_mu(kf) * harmonic_factor(k_rep, k_rep) / 2.0
            }
            SchemeKind::LtFine | SchemeKind::LtCoarse => {
                let k = if kind == SchemeKind::LtFine { cap } else { k };
                let kf = k as f64;
                let budget = lt_budget(k) as f64;
                let pmf = robust_soliton(k);
                let mean_degree: f64 = pmf
                    .iter()
                    .enumerate()
                    .map(|(i, pr)| (i + 1) as f64 * pr)
                    .sum();
                // Encode: budget symbols, each a mean-degree-deep sum
                // over rows of n_rec(k)/4 f32 elements; decode: GE of
                // the same order as MDS decode.
                let row_elems = dims.n_rec(kf) / 4.0;
                let master = (mean_degree * budget * row_elems + dims.n_dec(kf))
                    * (1.0 / p.mu_m + p.theta_m);
                // Workers stream ~budget/n symbols each; the round ends
                // when `needed` useful symbols arrived. Each extra wave
                // of symbols costs another full per-symbol service (and
                // another message), which is exactly the §V-C
                // "excessive transmission overhead" of fine-grained LT.
                let needed = lt_symbols_needed(k);
                let waves = needed.div_ceil(n) as f64;
                let order = harmonic_factor(n, needed.min(n));
                master + (worker_theta(kf) + worker_mu(kf) * order) * waves
            }
        }
    }

    /// The full per-layer policy (replanner cadence + plan seeding):
    /// LT under churn, deadline-fitted MDS (or LT when no split fits)
    /// under slack pressure, otherwise the cheaper of k-circ MDS and
    /// replication by predicted latency.
    pub fn choose(
        &self,
        dims: &LayerDims,
        p: &SystemProfile,
        n: usize,
        k_planned: usize,
        slack: Option<f64>,
        churn_events: usize,
    ) -> SchemeChoice {
        let cap = n.min(dims.w_o).max(1);
        let k = k_planned.clamp(1, cap);
        let pick = |kind: SchemeKind, k: usize| SchemeChoice {
            kind,
            k,
            predicted: self.predict(kind, dims, p, n, k),
        };
        if n <= 1 {
            return pick(SchemeKind::Uncoded, 1);
        }
        if churn_events >= self.config.churn_threshold {
            return pick(SchemeKind::LtCoarse, k);
        }
        if let Some(s) = slack {
            return match solve_deadline_k(dims, p, n, k, s, self.config.z_quantile) {
                Some(kd) => pick(SchemeKind::Mds, kd),
                // Even maximum redundancy misses the deadline: go
                // rateless and take whatever symbols arrive in time.
                None => pick(SchemeKind::LtCoarse, k),
            };
        }
        let mds = pick(SchemeKind::Mds, k);
        let rep = pick(SchemeKind::Replication, (n / 2).max(1).min(cap));
        if rep.predicted < mds.predicted {
            rep
        } else {
            mds
        }
    }

    /// Per-round refinement of a plan-held base choice: churn and
    /// deadline pressure override it for *this* round; otherwise the
    /// (hysteresis-stable) base stands. The deadline rule only tightens
    /// — it never raises k above the base.
    #[allow(clippy::too_many_arguments)]
    pub fn refine(
        &self,
        base_kind: SchemeKind,
        base_k: usize,
        dims: &LayerDims,
        p: &SystemProfile,
        n: usize,
        slack: Option<f64>,
        churn_events: usize,
    ) -> (SchemeKind, usize) {
        let cap = n.min(dims.w_o).max(1);
        let k = base_k.clamp(1, cap);
        if n <= 1 {
            return (SchemeKind::Uncoded, 1);
        }
        if churn_events >= self.config.churn_threshold {
            return (SchemeKind::LtCoarse, k);
        }
        if let Some(s) = slack {
            return match solve_deadline_k(dims, p, n, k, s, self.config.z_quantile) {
                Some(kd) => (SchemeKind::Mds, kd),
                None => (SchemeKind::LtCoarse, k),
            };
        }
        (base_kind, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;

    fn heavy() -> LayerDims {
        // VGG-class: compute- and transmission-heavy.
        LayerDims::new(ConvSpec::new(128, 128, 3, 1, 1), 112, 112)
    }

    #[test]
    fn calm_midrange_layer_picks_kcirc_mds() {
        let sel = SchemeSelector::default();
        let p = SystemProfile::paper_default();
        let c = sel.choose(&heavy(), &p, 8, 6, None, 0);
        assert_eq!(c.kind, SchemeKind::Mds);
        assert_eq!(c.k, 6);
    }

    #[test]
    fn churn_flips_to_lt_and_single_worker_to_uncoded() {
        let sel = SchemeSelector::default();
        let p = SystemProfile::paper_default();
        let c = sel.choose(&heavy(), &p, 8, 6, None, 3);
        assert_eq!(c.kind, SchemeKind::LtCoarse);
        assert_eq!(c.k, 6);
        let c1 = sel.choose(&heavy(), &p, 1, 6, None, 0);
        assert_eq!((c1.kind, c1.k), (SchemeKind::Uncoded, 1));
    }

    #[test]
    fn master_bound_profile_picks_replication() {
        // Fast links + a master whose coding FLOPs are the bottleneck:
        // replication's zero encode/decode wins even though its shards
        // are larger. This is the "tiny layer / fast link" regime.
        let sel = SchemeSelector::default();
        let mut p = SystemProfile::paper_default();
        p.mu_rec = 1e12;
        p.mu_sen = 1e12;
        p.theta_rec = 1e-12;
        p.theta_sen = 1e-12;
        p.mu_m = 1e7;
        p.theta_m = 1e-7;
        let c = sel.choose(&heavy(), &p, 8, 6, None, 0);
        assert_eq!(c.kind, SchemeKind::Replication);
        assert_eq!(c.k, 4);
        assert!(c.predicted < sel.predict(SchemeKind::Mds, &heavy(), &p, 8, 6));
    }

    #[test]
    fn deadline_rule_tightens_k_then_flips_to_lt() {
        let sel = SchemeSelector::default();
        let p = SystemProfile::paper_default();
        let d = heavy();
        let (n, k) = (8, 6);
        // Generous slack: keep the mean-optimal split.
        let roomy = l_tail_quantile(&d, &p, n, k, sel.config.z_quantile) * 2.0;
        let c = sel.choose(&d, &p, n, k, Some(roomy), 0);
        assert_eq!((c.kind, c.k), (SchemeKind::Mds, k));
        // Slack between the k=1 and k=6 tails: k must drop below 6.
        let k1 = l_tail_quantile(&d, &p, n, 1, sel.config.z_quantile);
        let k6 = l_tail_quantile(&d, &p, n, 6, sel.config.z_quantile);
        if k1 < k6 {
            let c = sel.choose(&d, &p, n, k, Some((k1 + k6) / 2.0), 0);
            assert_eq!(c.kind, SchemeKind::Mds);
            assert!(c.k < 6, "slack pressure must add redundancy, got k={}", c.k);
        }
        // Impossible slack: rateless.
        let c = sel.choose(&d, &p, n, k, Some(1e-9), 0);
        assert_eq!(c.kind, SchemeKind::LtCoarse);
        // refine() applies the same rules on a plan-held base.
        let (kind, _) =
            sel.refine(SchemeKind::Replication, 4, &d, &p, n, Some(1e-9), 0);
        assert_eq!(kind, SchemeKind::LtCoarse);
        let (kind, k_r) = sel.refine(SchemeKind::Replication, 4, &d, &p, n, None, 0);
        assert_eq!((kind, k_r), (SchemeKind::Replication, 4));
    }

    #[test]
    fn auto_makes_a_usable_scheme_defensively() {
        let s = SchemeKind::Auto.make(4, 3, 16, 1);
        assert_eq!(s.source_count(), 3);
        assert_eq!(s.num_subtasks(), 4);
        assert_eq!(SchemeKind::Auto.name(), "auto");
    }

    #[test]
    fn lt_prediction_penalizes_fine_splits() {
        // θ_msg makes symbol count expensive: the finest split must
        // predict worse than the planner-k split (§V-C).
        let sel = SchemeSelector::default();
        let p = SystemProfile::paper_default();
        let d = heavy();
        let fine = sel.predict(SchemeKind::LtFine, &d, &p, 8, 6);
        let coarse = sel.predict(SchemeKind::LtCoarse, &d, &p, 8, 6);
        assert!(coarse < fine, "coarse={coarse} fine={fine}");
    }
}
