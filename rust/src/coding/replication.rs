//! Replication baseline (paper §V, "Replication" [15]).
//!
//! The task splits into `k = ⌊n/2⌋` pieces, each dispatched to two workers
//! (the last piece gets a third copy when `n` is odd so every worker is
//! used). The master takes the first copy of each piece — tolerant to one
//! failure per replica pair, at 2× compute redundancy.

use super::{Decoder, EncodedTask, RedundancyScheme};

/// 2× replication scheme.
#[derive(Clone, Debug)]
pub struct Replication {
    n: usize,
    k: usize,
}

impl Replication {
    pub fn new(n: usize) -> Replication {
        assert!(n >= 2, "replication needs at least 2 workers");
        Replication { n, k: n / 2 }
    }

    /// Source index computed from a subtask id: round-robin over sources.
    pub fn source_of(&self, task_id: usize) -> usize {
        task_id % self.k
    }
}

impl RedundancyScheme for Replication {
    fn name(&self) -> String {
        format!("rep2({})", self.n)
    }

    fn source_count(&self) -> usize {
        self.k
    }

    fn num_subtasks(&self) -> usize {
        self.n
    }

    fn min_completions(&self) -> usize {
        self.k
    }

    fn encode(&self, sources: &[Vec<f32>]) -> Vec<EncodedTask> {
        assert_eq!(sources.len(), self.k);
        (0..self.n)
            .map(|id| EncodedTask {
                id,
                payload: sources[self.source_of(id)].clone(),
            })
            .collect()
    }

    fn encode_flops(&self, _input_len: usize) -> f64 {
        0.0 // replication copies; no arithmetic
    }

    /// Re-dispatch only when the failed task's *source* has no received
    /// copy and no alive outstanding replica.
    fn needs_redispatch(
        &self,
        task_id: usize,
        received: &[usize],
        outstanding: &[usize],
    ) -> bool {
        let src = self.source_of(task_id);
        let covered = received.iter().any(|&t| self.source_of(t) == src)
            || outstanding.iter().any(|&t| self.source_of(t) == src);
        !covered
    }

    fn decoder(&self) -> Box<dyn Decoder> {
        Box::new(ReplicationDecoder {
            k: self.k,
            outputs: vec![None; self.k],
            got: 0,
        })
    }
}

struct ReplicationDecoder {
    k: usize,
    outputs: Vec<Option<Vec<f32>>>,
    got: usize,
}

impl Decoder for ReplicationDecoder {
    fn add(&mut self, id: usize, output: Vec<f32>) -> bool {
        let src = id % self.k;
        if self.outputs[src].is_none() {
            self.outputs[src] = Some(output);
            self.got += 1;
        }
        self.ready()
    }

    fn ready(&self) -> bool {
        self.got == self.k
    }

    fn decode(&mut self) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(self.ready(), "replication decoder is missing pieces");
        Ok(self.outputs.iter_mut().map(|o| o.take().unwrap()).collect())
    }

    fn decode_flops(&self, _output_len: usize) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_cover_all_sources() {
        for n in 2..=11 {
            let s = Replication::new(n);
            let mut cover = vec![0usize; s.source_count()];
            for id in 0..s.num_subtasks() {
                cover[s.source_of(id)] += 1;
            }
            assert!(cover.iter().all(|&c| c >= 2), "n={n}: {cover:?}");
        }
    }

    #[test]
    fn one_copy_per_source_suffices() {
        let s = Replication::new(6); // k = 3
        let sources = vec![vec![1.0f32], vec![2.0], vec![3.0]];
        let tasks = s.encode(&sources);
        let mut d = s.decoder();
        // Feed only replica ids 3, 4, 5 (the second copies).
        assert!(!d.add(tasks[3].id, tasks[3].payload.clone()));
        assert!(!d.add(tasks[4].id, tasks[4].payload.clone()));
        assert!(d.add(tasks[5].id, tasks[5].payload.clone()));
        assert_eq!(d.decode().unwrap(), sources);
    }

    #[test]
    fn survives_one_failure_per_pair() {
        let s = Replication::new(4); // k = 2, pairs {0,2},{1,3}
        let sources = vec![vec![5.0f32], vec![7.0]];
        let tasks = s.encode(&sources);
        let mut d = s.decoder();
        // Workers 2 and 1 "fail": first copies arrive from 0 and 3.
        assert!(!d.add(tasks[0].id, tasks[0].payload.clone()));
        assert!(d.add(tasks[3].id, tasks[3].payload.clone()));
        assert_eq!(d.decode().unwrap(), sources);
    }
}
