//! `(n, k)`-MDS coding over real-valued feature-map partitions (paper §II-B).
//!
//! The generator is a Vandermonde matrix (eq. 3) over `n` distinct
//! evaluation nodes. The paper uses `g_i = i`-style nodes; we spread the
//! nodes evenly over `[-1, 1]` instead, which keeps every `k×k` submatrix
//! comfortably conditioned up to the `n = 20` range the paper evaluates
//! (float Vandermonde with integer nodes is numerically hopeless past
//! `k ≈ 8`). Any `k` of the `n` encoded outputs decode via `G_S^{-1}`
//! (eq. 4).

use super::matrix::{apply_f32, Matrix};
use super::{Decoder, EncodedTask, RedundancyScheme};

/// MDS (Vandermonde) redundancy scheme.
#[derive(Clone, Debug)]
pub struct MdsCode {
    n: usize,
    k: usize,
    g: Matrix,
}

impl MdsCode {
    /// Evaluation nodes: `n` points evenly spaced in `[-1, 1]`.
    pub fn nodes(n: usize) -> Vec<f64> {
        if n == 1 {
            return vec![1.0];
        }
        (0..n)
            .map(|i| -1.0 + 2.0 * i as f64 / (n - 1) as f64)
            .collect()
    }

    pub fn new(n: usize, k: usize) -> MdsCode {
        assert!(k >= 1 && k <= n, "require 1 <= k <= n (got n={n}, k={k})");
        let g = Matrix::vandermonde(&Self::nodes(n), k);
        MdsCode { n, k, g }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The generator matrix (n×k).
    pub fn generator(&self) -> &Matrix {
        &self.g
    }
}

impl RedundancyScheme for MdsCode {
    fn name(&self) -> String {
        format!("mds({},{})", self.n, self.k)
    }

    fn source_count(&self) -> usize {
        self.k
    }

    fn num_subtasks(&self) -> usize {
        self.n
    }

    fn min_completions(&self) -> usize {
        self.k
    }

    fn encode(&self, sources: &[Vec<f32>]) -> Vec<EncodedTask> {
        assert_eq!(sources.len(), self.k, "expected {} sources", self.k);
        let rows: Vec<&[f32]> = sources.iter().map(|s| s.as_slice()).collect();
        // f32-accumulation fast path: encode coefficients are bounded
        // Vandermonde powers (see matrix::apply_f32_fast docs).
        let encoded = super::matrix::apply_f32_fast(&self.g, &rows);
        encoded
            .into_iter()
            .enumerate()
            .map(|(id, payload)| EncodedTask { id, payload })
            .collect()
    }

    /// Paper eq. (8): `N_enc = 2 k n m` FLOPs for row length `m`.
    fn encode_flops(&self, input_len: usize) -> f64 {
        2.0 * self.k as f64 * self.n as f64 * input_len as f64
    }

    fn decoder(&self) -> Box<dyn Decoder> {
        Box::new(MdsDecoder {
            k: self.k,
            g: self.g.clone(),
            received: Vec::new(),
        })
    }
}

struct MdsDecoder {
    k: usize,
    g: Matrix,
    /// `(subtask id, output)` for the first `k` completions.
    received: Vec<(usize, Vec<f32>)>,
}

impl Decoder for MdsDecoder {
    fn add(&mut self, id: usize, output: Vec<f32>) -> bool {
        if self.received.len() < self.k && !self.received.iter().any(|(i, _)| *i == id) {
            self.received.push((id, output));
        }
        self.ready()
    }

    fn ready(&self) -> bool {
        self.received.len() >= self.k
    }

    fn decode(&mut self) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(self.ready(), "decoder needs {} outputs", self.k);
        let idx: Vec<usize> = self.received.iter().map(|(i, _)| *i).collect();
        let gs = self.g.select_rows(&idx);
        let inv = gs.inverse()?;
        let rows: Vec<&[f32]> = self.received.iter().map(|(_, o)| o.as_slice()).collect();
        Ok(apply_f32(&inv, &rows))
    }

    /// Paper eq. (12): `N_dec = 2 k^2 m` FLOPs (the `G_S` inversion is
    /// `O(k^3)` with `k ≤ 20` — negligible next to the `k^2 m` apply).
    fn decode_flops(&self, output_len: usize) -> f64 {
        2.0 * (self.k * self.k) as f64 * output_len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn nodes_distinct_and_bounded() {
        for n in 1..=24 {
            let nodes = MdsCode::nodes(n);
            assert_eq!(nodes.len(), n);
            for i in 0..n {
                assert!(nodes[i].abs() <= 1.0);
                for j in 0..i {
                    assert!((nodes[i] - nodes[j]).abs() > 1e-9);
                }
            }
        }
    }

    #[test]
    fn decode_from_any_k_subset_exact() {
        prop::check("mds any-k-subset", 64, |rng| {
            let n = 2 + rng.below(12);
            let k = 1 + rng.below(n);
            let code = MdsCode::new(n, k);
            let len = 1 + rng.below(128);
            let sources: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..len).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
                .collect();
            let tasks = code.encode(&sources);
            let subset = rng.sample_distinct(n, k);
            let mut dec = code.decoder();
            let mut complete = false;
            for &t in &subset {
                complete = dec.add(tasks[t].id, tasks[t].payload.clone());
            }
            assert!(complete);
            let decoded = dec.decode().unwrap();
            for (d, s) in decoded.iter().zip(&sources) {
                for (a, b) in d.iter().zip(s.iter()) {
                    assert!((a - b).abs() < 2e-3, "decode error {a} vs {b} (n={n} k={k})");
                }
            }
        });
    }

    #[test]
    fn identity_when_k_equals_one() {
        let code = MdsCode::new(3, 1);
        let tasks = code.encode(&[vec![1.0, 2.0]]);
        assert_eq!(tasks.len(), 3);
        // k=1 Vandermonde row is [g^0] = [1] for every node.
        for t in &tasks {
            assert_eq!(t.payload, vec![1.0, 2.0]);
        }
    }

    #[test]
    fn duplicate_adds_ignored() {
        let code = MdsCode::new(4, 2);
        let tasks = code.encode(&[vec![1.0], vec![2.0]]);
        let mut dec = code.decoder();
        assert!(!dec.add(0, tasks[0].payload.clone()));
        assert!(!dec.add(0, tasks[0].payload.clone())); // same id again
        assert!(dec.add(2, tasks[2].payload.clone()));
    }

    #[test]
    fn flops_match_paper_formulas() {
        let code = MdsCode::new(10, 4);
        assert_eq!(code.encode_flops(1000), 2.0 * 4.0 * 10.0 * 1000.0);
        let dec = code.decoder();
        assert_eq!(dec.decode_flops(500), 2.0 * 16.0 * 500.0);
    }
}
