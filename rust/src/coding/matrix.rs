//! Dense matrix substrate for the coding layer.
//!
//! Small, row-major `f64` matrices: Vandermonde construction, partial-pivot
//! LU inversion (for the `k×k` decode submatrix `G_S`, eq. 4), and blocked
//! application of coefficient matrices to wide `f32` data rows (the actual
//! encode/decode hot loop — coefficients in f64, data in f32, accumulation
//! in f64 for decode stability).

use anyhow::{bail, Result};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix {
            rows: r,
            cols: c,
            data: rows.concat(),
        }
    }

    /// Vandermonde matrix over the given evaluation nodes:
    /// row `i` = `[g_i^(k-1), g_i^(k-2), ..., g_i^0]` (paper eq. 3 layout).
    pub fn vandermonde(nodes: &[f64], k: usize) -> Matrix {
        let mut m = Matrix::zeros(nodes.len(), k);
        for (i, &g) in nodes.iter().enumerate() {
            let mut p = 1.0;
            // Fill right-to-left: last column is g^0.
            for j in (0..k).rev() {
                m[(i, j)] = p;
                p *= g;
            }
        }
        m
    }

    /// Select a subset of rows (decode submatrix `G_S`).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            assert!(i < self.rows, "row index out of range");
            m.data[r * self.cols..(r + 1) * self.cols]
                .copy_from_slice(&self.data[i * self.cols..(i + 1) * self.cols]);
        }
        m
    }

    /// Plain matmul (small matrices only; the wide data path uses
    /// [`apply_f32`]).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a = self[(i, l)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(l, j)];
                }
            }
        }
        out
    }

    /// Inverse via LU with partial pivoting. Errors on (near-)singular
    /// input — the MDS property guarantees this never fires for valid
    /// Vandermonde submatrices with distinct nodes.
    pub fn inverse(&self) -> Result<Matrix> {
        if self.rows != self.cols {
            bail!("inverse of non-square {}x{}", self.rows, self.cols);
        }
        let n = self.rows;
        // Augmented [A | I] Gauss-Jordan with partial pivoting.
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            // Pivot: largest |a[r][col]| for r >= col.
            let (pivot_row, pivot_val) = (col..n)
                .map(|r| (r, a[(r, col)].abs()))
                .max_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
                .unwrap();
            if pivot_val < 1e-12 {
                bail!("matrix is singular (pivot {pivot_val:.3e} at column {col})");
            }
            if pivot_row != col {
                a.swap_rows(pivot_row, col);
                inv.swap_rows(pivot_row, col);
            }
            let p = a[(col, col)];
            for j in 0..n {
                a[(col, j)] /= p;
                inv[(col, j)] /= p;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    a[(r, j)] -= f * a[(col, j)];
                    inv[(r, j)] -= f * inv[(col, j)];
                }
            }
        }
        Ok(inv)
    }

    fn swap_rows(&mut self, r0: usize, r1: usize) {
        if r0 == r1 {
            return;
        }
        for j in 0..self.cols {
            self.data.swap(r0 * self.cols + j, r1 * self.cols + j);
        }
    }

    /// Max |a_ij| — used in conditioning sanity tests.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// Fast variant of [`apply_f32`]: f32 accumulation (axpy), ~2× faster on
/// this core. Safe for the **encode** direction, where coefficients are
/// Vandermonde powers in `[-1, 1]` and `k ≤ ~20` terms keep the rounding
/// at ~1e-6 relative; the **decode** direction must stay in f64
/// ([`apply_f32`]) because inverse-Vandermonde coefficients are large and
/// alternating. §Perf in EXPERIMENTS.md has the before/after.
pub fn apply_f32_fast(coeff: &Matrix, rows: &[&[f32]]) -> Vec<Vec<f32>> {
    assert_eq!(coeff.cols, rows.len(), "coeff cols != row count");
    let width = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(rows.iter().all(|r| r.len() == width), "ragged data rows");
    let mut out: Vec<Vec<f32>> = Vec::with_capacity(coeff.rows);
    for i in 0..coeff.rows {
        // First non-zero term writes (no zero-init read-modify pass)...
        let first = (0..rows.len()).find(|&j| coeff[(i, j)] != 0.0);
        let mut out_row = match first {
            None => vec![0f32; width],
            Some(j0) => {
                let c = coeff[(i, j0)] as f32;
                rows[j0].iter().map(|&x| c * x).collect()
            }
        };
        // ...remaining terms accumulate (axpy).
        if let Some(j0) = first {
            for (j, row) in rows.iter().enumerate().skip(j0 + 1) {
                let c = coeff[(i, j)] as f32;
                if c == 0.0 {
                    continue;
                }
                for (o, &x) in out_row.iter_mut().zip(*row) {
                    *o += c * x;
                }
            }
        }
        out.push(out_row);
    }
    out
}

/// Apply a `p×k` coefficient matrix to `k` wide f32 data rows, producing
/// `p` output rows of the same width. This is the encode/decode hot loop:
/// `out[i] = sum_j coeff[i][j] * rows[j]`, accumulated in f64.
///
/// Blocked over the width so each pass stays in cache; the coefficient
/// loop is innermost-hoisted (axpy style) so the compiler can vectorize.
pub fn apply_f32(coeff: &Matrix, rows: &[&[f32]]) -> Vec<Vec<f32>> {
    assert_eq!(coeff.cols, rows.len(), "coeff cols != row count");
    let width = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(rows.iter().all(|r| r.len() == width), "ragged data rows");

    const BLOCK: usize = 4096;
    let mut out = vec![vec![0f32; width]; coeff.rows];
    let mut acc = vec![0f64; BLOCK.min(width.max(1))];
    for start in (0..width).step_by(BLOCK) {
        let end = (start + BLOCK).min(width);
        let len = end - start;
        for i in 0..coeff.rows {
            let acc = &mut acc[..len];
            acc.fill(0.0);
            for (j, row) in rows.iter().enumerate() {
                let c = coeff[(i, j)];
                if c == 0.0 {
                    continue;
                }
                let src = &row[start..end];
                for (a, &x) in acc.iter_mut().zip(src) {
                    *a += c * x as f64;
                }
            }
            let dst = &mut out[i][start..end];
            for (d, &a) in dst.iter_mut().zip(acc.iter()) {
                *d = a as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn vandermonde_layout() {
        let m = Matrix::vandermonde(&[2.0, 3.0], 3);
        // row = [g^2, g^1, g^0]
        assert_eq!(m.data, vec![4.0, 2.0, 1.0, 9.0, 3.0, 1.0]);
    }

    #[test]
    fn identity_inverse() {
        let i = Matrix::identity(4);
        assert_eq!(i.inverse().unwrap(), i);
    }

    #[test]
    fn inverse_times_self_is_identity() {
        let mut rng = Rng::new(99);
        for n in [1usize, 2, 3, 5, 8] {
            let mut m = Matrix::zeros(n, n);
            for v in m.data.iter_mut() {
                *v = rng.uniform_range(-1.0, 1.0);
            }
            // Diagonal dominance to guarantee invertibility.
            for i in 0..n {
                m[(i, i)] += n as f64;
            }
            let inv = m.inverse().unwrap();
            let prod = m.matmul(&inv);
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (prod[(i, j)] - expect).abs() < 1e-9,
                        "prod[{i}][{j}]={}",
                        prod[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn singular_rejected() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(m.inverse().is_err());
    }

    #[test]
    fn apply_f32_matches_naive() {
        prop::check("apply_f32 == naive", 32, |rng| {
            let k = 1 + rng.below(5);
            let p = 1 + rng.below(5);
            let w = 1 + rng.below(9000); // crosses the 4096 block boundary
            let mut coeff = Matrix::zeros(p, k);
            for v in coeff.data.iter_mut() {
                *v = rng.uniform_range(-2.0, 2.0);
            }
            let rows: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..w).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let out = apply_f32(&coeff, &refs);
            for i in 0..p {
                for x in 0..w.min(64) {
                    let naive: f64 = (0..k).map(|j| coeff[(i, j)] * rows[j][x] as f64).sum();
                    assert!(
                        (out[i][x] as f64 - naive).abs() < 1e-4,
                        "mismatch at ({i},{x})"
                    );
                }
            }
        });
    }

    #[test]
    fn vandermonde_submatrix_invertible_for_spread_nodes() {
        // The node layout used by MdsCode: evenly spaced in [-1, 1].
        let n = 10;
        let k = 7;
        let nodes: Vec<f64> = (0..n)
            .map(|i| -1.0 + 2.0 * i as f64 / (n - 1) as f64)
            .collect();
        let g = Matrix::vandermonde(&nodes, k);
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let idx = rng.sample_distinct(n, k);
            let gs = g.select_rows(&idx);
            let inv = gs.inverse().expect("every k-row submatrix invertible");
            let prod = gs.matmul(&inv);
            for i in 0..k {
                assert!((prod[(i, i)] - 1.0).abs() < 1e-6);
            }
        }
    }
}
