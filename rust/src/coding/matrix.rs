//! Dense matrix substrate for the coding layer.
//!
//! Small, row-major `f64` matrices: Vandermonde construction, partial-pivot
//! LU inversion (for the `k×k` decode submatrix `G_S`, eq. 4), and blocked
//! application of coefficient matrices to wide `f32` data rows (the actual
//! encode/decode hot loop — coefficients in f64, data in f32, accumulation
//! in f64 for decode stability).

use anyhow::{bail, Result};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix {
            rows: r,
            cols: c,
            data: rows.concat(),
        }
    }

    /// Vandermonde matrix over the given evaluation nodes:
    /// row `i` = `[g_i^(k-1), g_i^(k-2), ..., g_i^0]` (paper eq. 3 layout).
    pub fn vandermonde(nodes: &[f64], k: usize) -> Matrix {
        let mut m = Matrix::zeros(nodes.len(), k);
        for (i, &g) in nodes.iter().enumerate() {
            let mut p = 1.0;
            // Fill right-to-left: last column is g^0.
            for j in (0..k).rev() {
                m[(i, j)] = p;
                p *= g;
            }
        }
        m
    }

    /// Select a subset of rows (decode submatrix `G_S`).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            assert!(i < self.rows, "row index out of range");
            m.data[r * self.cols..(r + 1) * self.cols]
                .copy_from_slice(&self.data[i * self.cols..(i + 1) * self.cols]);
        }
        m
    }

    /// Plain matmul (small matrices only; the wide data path uses
    /// [`apply_f32`]).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a = self[(i, l)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(l, j)];
                }
            }
        }
        out
    }

    /// Inverse via LU with partial pivoting. Errors on (near-)singular
    /// input — the MDS property guarantees this never fires for valid
    /// Vandermonde submatrices with distinct nodes.
    pub fn inverse(&self) -> Result<Matrix> {
        if self.rows != self.cols {
            bail!("inverse of non-square {}x{}", self.rows, self.cols);
        }
        let n = self.rows;
        // Augmented [A | I] Gauss-Jordan with partial pivoting.
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            // Pivot: largest |a[r][col]| for r >= col.
            let (pivot_row, pivot_val) = (col..n)
                .map(|r| (r, a[(r, col)].abs()))
                .max_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
                .unwrap();
            if pivot_val < 1e-12 {
                bail!("matrix is singular (pivot {pivot_val:.3e} at column {col})");
            }
            if pivot_row != col {
                a.swap_rows(pivot_row, col);
                inv.swap_rows(pivot_row, col);
            }
            let p = a[(col, col)];
            for j in 0..n {
                a[(col, j)] /= p;
                inv[(col, j)] /= p;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    a[(r, j)] -= f * a[(col, j)];
                    inv[(r, j)] -= f * inv[(col, j)];
                }
            }
        }
        Ok(inv)
    }

    fn swap_rows(&mut self, r0: usize, r1: usize) {
        if r0 == r1 {
            return;
        }
        for j in 0..self.cols {
            self.data.swap(r0 * self.cols + j, r1 * self.cols + j);
        }
    }

    /// Max |a_ij| — used in conditioning sanity tests.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// Column block width shared by every apply path. Fixed — independent of
/// thread count — so each output element's summation order (and
/// therefore the bitwise result) is identical whether the apply runs
/// sequentially or chunk-parallel over any number of threads.
const BLOCK: usize = 4096;

/// Minimum total output elements (`p × width`) before spawning scoped
/// threads pays for itself. Depends only on the shape.
const PAR_ELEMS_MIN: usize = 1 << 17;

/// One output row × one column chunk, f32 axpy accumulation (encode
/// direction). First non-zero term writes, later terms accumulate —
/// identical arithmetic to the historical unchunked loop.
fn apply_row_f32(coeff: &Matrix, rows: &[&[f32]], i: usize, start: usize, dst: &mut [f32]) {
    let len = dst.len();
    let mut wrote = false;
    for (j, row) in rows.iter().enumerate() {
        let c = coeff[(i, j)];
        if c == 0.0 {
            continue;
        }
        let c = c as f32;
        let src = &row[start..start + len];
        if wrote {
            for (d, &x) in dst.iter_mut().zip(src) {
                *d += c * x;
            }
        } else {
            for (d, &x) in dst.iter_mut().zip(src) {
                *d = c * x;
            }
            wrote = true;
        }
    }
    if !wrote {
        dst.fill(0.0);
    }
}

/// One output row × one column chunk, f64 accumulation (decode
/// direction). `acc` must hold at least `dst.len()` slots.
fn apply_row_f64(coeff: &Matrix, rows: &[&[f32]], i: usize, start: usize, dst: &mut [f32], acc: &mut [f64]) {
    let len = dst.len();
    let acc = &mut acc[..len];
    acc.fill(0.0);
    for (j, row) in rows.iter().enumerate() {
        let c = coeff[(i, j)];
        if c == 0.0 {
            continue;
        }
        let src = &row[start..start + len];
        for (a, &x) in acc.iter_mut().zip(src) {
            *a += c * x as f64;
        }
    }
    for (d, &a) in dst.iter_mut().zip(acc.iter()) {
        *d = a as f32;
    }
}

/// Drive the chunked apply over `out`, parallel when the shape warrants
/// it. Chunk boundaries are fixed at [`BLOCK`] columns regardless of
/// thread count; threads take disjoint contiguous chunk ranges, so the
/// result is bitwise identical at any thread count.
fn apply_chunked(coeff: &Matrix, rows: &[&[f32]], out: &mut [Vec<f32>], threads: usize, f64_acc: bool) {
    let width = rows.first().map(|r| r.len()).unwrap_or(0);
    if width == 0 || out.is_empty() {
        return;
    }
    let threads = if threads == 0 {
        crate::util::threads::default_threads()
    } else {
        threads
    };
    let nchunks = width.div_ceil(BLOCK);
    let t = threads.min(nchunks);
    if t <= 1 || out.len() * width < PAR_ELEMS_MIN {
        let mut acc = if f64_acc { vec![0f64; BLOCK.min(width)] } else { Vec::new() };
        for ci in 0..nchunks {
            let start = ci * BLOCK;
            let len = BLOCK.min(width - start);
            for (i, out_row) in out.iter_mut().enumerate() {
                let dst = &mut out_row[start..start + len];
                if f64_acc {
                    apply_row_f64(coeff, rows, i, start, dst, &mut acc);
                } else {
                    apply_row_f32(coeff, rows, i, start, dst);
                }
            }
        }
        return;
    }
    // Group each chunk's per-row slices, then hand contiguous chunk
    // ranges to scoped threads.
    let p = out.len();
    let mut groups: Vec<Vec<&mut [f32]>> = (0..nchunks).map(|_| Vec::with_capacity(p)).collect();
    for row in out.iter_mut() {
        for (ci, chunk) in row.chunks_mut(BLOCK).enumerate() {
            groups[ci].push(chunk);
        }
    }
    let per = nchunks.div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = groups;
        let mut ci0 = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let batch: Vec<Vec<&mut [f32]>> = rest.drain(..take).collect();
            let start0 = ci0 * BLOCK;
            ci0 += take;
            s.spawn(move || {
                let mut acc = if f64_acc { vec![0f64; BLOCK] } else { Vec::new() };
                for (bi, chunk_rows) in batch.into_iter().enumerate() {
                    let start = start0 + bi * BLOCK;
                    for (i, dst) in chunk_rows.into_iter().enumerate() {
                        if f64_acc {
                            apply_row_f64(coeff, rows, i, start, dst, &mut acc);
                        } else {
                            apply_row_f32(coeff, rows, i, start, dst);
                        }
                    }
                }
            });
        }
    });
}

/// Fast variant of [`apply_f32`]: f32 accumulation (axpy), ~2× faster on
/// this core. Safe for the **encode** direction, where coefficients are
/// Vandermonde powers in `[-1, 1]` and `k ≤ ~20` terms keep the rounding
/// at ~1e-6 relative; the **decode** direction must stay in f64
/// ([`apply_f32`]) because inverse-Vandermonde coefficients are large and
/// alternating. Long rows are chunk-parallelized over the default thread
/// pool (see [`apply_f32_fast_threads`]). §Perf in EXPERIMENTS.md.
pub fn apply_f32_fast(coeff: &Matrix, rows: &[&[f32]]) -> Vec<Vec<f32>> {
    apply_f32_fast_threads(coeff, rows, 0)
}

/// [`apply_f32_fast`] with an explicit thread count (`0` = default).
/// Bitwise identical results at any thread count.
pub fn apply_f32_fast_threads(coeff: &Matrix, rows: &[&[f32]], threads: usize) -> Vec<Vec<f32>> {
    assert_eq!(coeff.cols, rows.len(), "coeff cols != row count");
    let width = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(rows.iter().all(|r| r.len() == width), "ragged data rows");
    let mut out = vec![vec![0f32; width]; coeff.rows];
    apply_chunked(coeff, rows, &mut out, threads, false);
    out
}

/// Apply a `p×k` coefficient matrix to `k` wide f32 data rows, producing
/// `p` output rows of the same width. This is the encode/decode hot loop:
/// `out[i] = sum_j coeff[i][j] * rows[j]`, accumulated in f64.
///
/// Blocked over the width ([`BLOCK`] columns) so each pass stays in
/// cache, with the blocks spread over scoped threads for long feature
/// rows — same bits at any thread count.
pub fn apply_f32(coeff: &Matrix, rows: &[&[f32]]) -> Vec<Vec<f32>> {
    apply_f32_threads(coeff, rows, 0)
}

/// [`apply_f32`] with an explicit thread count (`0` = default).
pub fn apply_f32_threads(coeff: &Matrix, rows: &[&[f32]], threads: usize) -> Vec<Vec<f32>> {
    assert_eq!(coeff.cols, rows.len(), "coeff cols != row count");
    let width = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(rows.iter().all(|r| r.len() == width), "ragged data rows");
    let mut out = vec![vec![0f32; width]; coeff.rows];
    apply_chunked(coeff, rows, &mut out, threads, true);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn vandermonde_layout() {
        let m = Matrix::vandermonde(&[2.0, 3.0], 3);
        // row = [g^2, g^1, g^0]
        assert_eq!(m.data, vec![4.0, 2.0, 1.0, 9.0, 3.0, 1.0]);
    }

    #[test]
    fn identity_inverse() {
        let i = Matrix::identity(4);
        assert_eq!(i.inverse().unwrap(), i);
    }

    #[test]
    fn inverse_times_self_is_identity() {
        let mut rng = Rng::new(99);
        for n in [1usize, 2, 3, 5, 8] {
            let mut m = Matrix::zeros(n, n);
            for v in m.data.iter_mut() {
                *v = rng.uniform_range(-1.0, 1.0);
            }
            // Diagonal dominance to guarantee invertibility.
            for i in 0..n {
                m[(i, i)] += n as f64;
            }
            let inv = m.inverse().unwrap();
            let prod = m.matmul(&inv);
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (prod[(i, j)] - expect).abs() < 1e-9,
                        "prod[{i}][{j}]={}",
                        prod[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn singular_rejected() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(m.inverse().is_err());
    }

    #[test]
    fn apply_f32_matches_naive() {
        prop::check("apply_f32 == naive", 32, |rng| {
            let k = 1 + rng.below(5);
            let p = 1 + rng.below(5);
            let w = 1 + rng.below(9000); // crosses the 4096 block boundary
            let mut coeff = Matrix::zeros(p, k);
            for v in coeff.data.iter_mut() {
                *v = rng.uniform_range(-2.0, 2.0);
            }
            let rows: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..w).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let out = apply_f32(&coeff, &refs);
            for i in 0..p {
                for x in 0..w.min(64) {
                    let naive: f64 = (0..k).map(|j| coeff[(i, j)] * rows[j][x] as f64).sum();
                    assert!(
                        (out[i][x] as f64 - naive).abs() < 1e-4,
                        "mismatch at ({i},{x})"
                    );
                }
            }
        });
    }

    #[test]
    fn apply_threads_bitwise_identical() {
        // Wide enough to cross several 4096-column blocks and clear the
        // parallelism gate (p * width >= 2^17).
        let mut rng = Rng::new(0xAB17);
        let (p, k, w) = (6, 5, 6 * 4096 + 123); // p·w clears PAR_ELEMS_MIN
        let mut coeff = Matrix::zeros(p, k);
        for v in coeff.data.iter_mut() {
            *v = rng.uniform_range(-3.0, 3.0);
        }
        coeff[(2, 1)] = 0.0; // exercise the sparsity skip
        coeff[(4, 0)] = 0.0;
        let rows: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..w).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let seq = apply_f32_threads(&coeff, &refs, 1);
        for t in [2, 3, 8] {
            assert_eq!(seq, apply_f32_threads(&coeff, &refs, t), "f64 path t={t}");
        }
        let seq_fast = apply_f32_fast_threads(&coeff, &refs, 1);
        for t in [2, 3, 8] {
            assert_eq!(
                seq_fast,
                apply_f32_fast_threads(&coeff, &refs, t),
                "f32 path t={t}"
            );
        }
    }

    #[test]
    fn apply_fast_zero_row_and_short_rows() {
        // An all-zero coefficient row must produce an all-zero output row,
        // and sub-block widths stay on the sequential path.
        let coeff = Matrix::from_rows(&[vec![0.0, 0.0], vec![2.0, -1.0]]);
        let rows: Vec<Vec<f32>> = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let out = apply_f32_fast(&coeff, &refs);
        assert_eq!(out[0], vec![0.0, 0.0, 0.0]);
        assert_eq!(out[1], vec![-2.0, -1.0, 0.0]);
    }

    #[test]
    fn vandermonde_submatrix_invertible_for_spread_nodes() {
        // The node layout used by MdsCode: evenly spaced in [-1, 1].
        let n = 10;
        let k = 7;
        let nodes: Vec<f64> = (0..n)
            .map(|i| -1.0 + 2.0 * i as f64 / (n - 1) as f64)
            .collect();
        let g = Matrix::vandermonde(&nodes, k);
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let idx = rng.sample_distinct(n, k);
            let gs = g.select_rows(&idx);
            let inv = gs.inverse().expect("every k-row submatrix invertible");
            let prod = gs.matmul(&inv);
            for i in 0..k {
                assert!((prod[(i, i)] - 1.0).abs() < 1e-6);
            }
        }
    }
}
