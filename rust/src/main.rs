//! `cocoi` — the CoCoI leader binary.
//!
//! Subcommands (hand-rolled arg parsing; no clap in the vendor set):
//!
//! ```text
//! cocoi infer  --model tinyvgg --workers 4 [--scheme auto|mds|uncoded|rep|lt|lt-fine]
//!              [--k N] [--lambda-tr X] [--fail N] [--pjrt] [--runs R] [--pipeline]
//!              [--adaptive]                         # telemetry-driven replanning
//!              [--telemetry PATH]                   # dump registry/plan JSON after the runs
//!              [--threads T]                        # GEMM kernel threads (0 = auto)
//!              [--stream N]                         # open-loop serving: N requests via InferenceServer
//!              [--rate R]                           # Poisson arrival rate, req/s (0 = back-to-back)
//!              [--deadline-ms D]                    # per-request deadline (shed when unmeetable)
//!              [--queue-cap C]                      # admission bound (QueueFull backpressure)
//!              [--concurrent M]                     # engine concurrency limit (0 = unlimited)
//!              [--tenant-quota Q]                   # open requests per tenant (0 = unlimited)
//!              [--tenant-weight a=2,b=1]            # DRR fair-share weights; --stream round-robins the named tenants
//!              [--coalesce C]                       # merge ≤C same-layer requests per round (1 = off)
//!              [--worker-slots S]                   # convs in flight per worker (1 = sequential)
//!              [--hedge-quantile Q]                 # watchdog hedge quantile (0 = no hedging)
//!              [--retry-budget B]                   # extra dispatches per round = B x subtasks
//!              [--local-fallback on|off]            # master computes undeliverable shards
//!              [--fallback-concurrency N]           # concurrent fallback shards (default 4; 1 = serial)
//!              [--trace PATH]                       # record span trees, write Chrome trace JSON
//!              [--trace-cap N]                      # trace ring capacity in spans (default 8192)
//!              [--trace-sample N]                   # trace 1-in-N requests (default 1 = all)
//!              [--metrics PATH]                     # write a Prometheus text scrape after the runs
//! cocoi worker --listen 0.0.0.0:9090 [--pjrt] [--threads T] [--slots S]   # TCP worker process
//! cocoi worker --connect host:9095 [--name N] [--model M]                 # announce to a running master
//!              [--retry-initial-ms 200] [--retry-max-ms 5000] [--retries 0]  # reconnect backoff (0 = forever)
//! cocoi infer  --tcp host:9090,host:9091 ...        # master over TCP (fixed pool)
//! cocoi infer  --listen 0.0.0.0:9095 --stream N     # elastic master: workers join/leave at runtime
//! cocoi plan   --model vgg16 --workers 10           # show the split plan
//! cocoi experiment <gemm|fig4|fig5|fig6|fig7|fig8|fig9|fig10|table1|theory|throughput|adaptive|serving|all>
//! ```
//!
//! `--threads` (or the `COCOI_THREADS` env var) caps the tiled GEMM
//! kernel's threads; results are bitwise identical at any setting.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use cocoi::bench::experiments as exp;
use cocoi::conv::Tensor;
use cocoi::coordinator::{
    ExecMode, LocalCluster, MasterConfig, ScenarioFaults, SchemeKind, WorkerFaults,
};
use cocoi::latency::SystemProfile;
use cocoi::model::zoo;
use cocoi::planner::SplitPolicy;
use cocoi::runtime::{ConvProvider, FallbackProvider, Manifest, PjrtProvider, PjrtService};
use cocoi::transport::split::split_tcp;
use cocoi::util::Rng;

/// Minimal `--flag value` / `--flag` parser.
struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}")),
        }
    }

    fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}")),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

/// Parse `--tenant-weight a=2,b=1` (a bare name means weight 1) into
/// `MasterConfig::tenant_weights`.
fn parse_tenant_weights(spec: Option<&str>) -> Result<Vec<(String, f64)>> {
    let Some(spec) = spec else { return Ok(Vec::new()) };
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, weight) = match part.split_once('=') {
            Some((n, w)) => (
                n.trim(),
                w.trim()
                    .parse::<f64>()
                    .with_context(|| format!("--tenant-weight {part}"))?,
            ),
            None => (part.trim(), 1.0),
        };
        if name.is_empty() {
            bail!("--tenant-weight {part}: empty tenant name");
        }
        if !(weight.is_finite() && weight > 0.0) {
            bail!("--tenant-weight {part}: weight must be positive and finite");
        }
        out.push((name.to_string(), weight));
    }
    Ok(out)
}

fn scheme_from_str(s: &str) -> Result<SchemeKind> {
    Ok(match s {
        "mds" | "cocoi" => SchemeKind::Mds,
        "uncoded" => SchemeKind::Uncoded,
        "rep" | "replication" => SchemeKind::Replication,
        "lt" | "lt-coarse" | "lt-ks" => SchemeKind::LtCoarse,
        "lt-fine" | "lt-kl" => SchemeKind::LtFine,
        "auto" => SchemeKind::Auto,
        other => bail!("unknown scheme '{other}'"),
    })
}

/// Build the provider (+ keep the PJRT service alive if used).
/// `threads` configures the pure-rust tiled GEMM kernel (0 = auto).
fn make_provider(
    use_pjrt: bool,
    threads: usize,
) -> Result<(Arc<dyn ConvProvider>, Option<PjrtService>)> {
    if use_pjrt {
        let service = PjrtService::spawn()?;
        let manifest = Arc::new(Manifest::load_or_empty(
            &cocoi::runtime::artifacts::default_dir(),
        ));
        let provider = Arc::new(PjrtProvider::new(service.handle(), manifest));
        Ok((provider, Some(service)))
    } else {
        Ok((Arc::new(FallbackProvider::with_threads(threads)), None))
    }
}

fn cmd_infer(args: &Args) -> Result<()> {
    let model_name = args.get("model").unwrap_or("tinyvgg").to_string();
    let n = args.get_usize("workers", 4)?;
    let scheme = scheme_from_str(args.get("scheme").unwrap_or("mds"))?;
    let runs = args.get_usize("runs", 1)?;
    let lambda_tr = args.get_f64("lambda-tr", 0.0)?;
    let n_f = args.get_usize("fail", 0)?;
    let (provider, _service) = make_provider(args.has("pjrt"), args.get_usize("threads", 0)?)?;

    let mut rng = Rng::new(args.get_usize("seed", 1)? as u64);
    let faults = if n_f > 0 {
        ScenarioFaults::failures(n, n_f, 1024, &mut rng)
    } else if lambda_tr > 0.0 {
        // 5 ms mean transmission estimate for the injected delay scale.
        ScenarioFaults::straggling(n, lambda_tr, 0.005)
    } else {
        (0..n).map(|_| WorkerFaults::none()).collect()
    };

    // `--trace PATH` turns the span recorder on; the handle is shared
    // with the master (and, for in-proc pools, the workers) and drained
    // into Chrome trace-event JSON after the runs.
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let trace_cap = args.get_usize("trace-cap", 8192)?;
    let trace_handle = trace_path
        .as_ref()
        .map(|_| cocoi::obs::trace::TraceHandle::new(trace_cap));
    let metrics_path = args.get("metrics").map(std::path::PathBuf::from);

    let config = MasterConfig {
        scheme,
        policy: match args.get("k") {
            Some(k) => SplitPolicy::Fixed(k.parse()?),
            None => SplitPolicy::KCircle,
        },
        mode: if args.has("pipeline") {
            ExecMode::Pipelined
        } else {
            ExecMode::RoundBarrier
        },
        adaptive: args.has("adaptive"),
        coalesce: args.get_usize("coalesce", 1)?,
        hedge_quantile: args.get_f64("hedge-quantile", MasterConfig::default().hedge_quantile)?,
        retry_budget: args.get_usize("retry-budget", MasterConfig::default().retry_budget)?,
        local_fallback: match args.get("local-fallback") {
            None => MasterConfig::default().local_fallback,
            Some("on") | Some("true") | Some("1") => true,
            Some("off") | Some("false") | Some("0") => false,
            Some(v) => bail!("--local-fallback {v}: expected on|off"),
        },
        fallback_concurrency: args.get_usize(
            "fallback-concurrency",
            MasterConfig::default().fallback_concurrency,
        )?,
        trace: trace_handle.clone(),
        trace_sample: args.get_usize("trace-sample", MasterConfig::default().trace_sample)?,
        tenant_weights: parse_tenant_weights(args.get("tenant-weight"))?,
        ..Default::default()
    };
    let telemetry_path = args.get("telemetry").map(std::path::PathBuf::from);

    // Build the master: elastic (workers announce themselves), fixed
    // TCP pool, or a local in-proc pool.
    let (mut master, workers) = if let Some(listen_addr) = args.get("listen") {
        let mut master =
            cocoi::coordinator::Master::new_elastic(&model_name, config, n.max(1), provider)?;
        let bound = master.listen(listen_addr)?;
        println!("elastic master: membership listener on {bound} (inference waits for joins)");
        (master, None)
    } else if let Some(addrs) = args.get("tcp") {
        let mut links: Vec<cocoi::transport::LinkPair> = Vec::new();
        for addr in addrs.split(',') {
            let stream = std::net::TcpStream::connect(addr.trim())
                .with_context(|| format!("connecting to worker {addr}"))?;
            let (tx, rx) = split_tcp(stream)?;
            links.push((Box::new(tx), Box::new(rx)));
        }
        let master = cocoi::coordinator::Master::new(&model_name, config, links, provider)?;
        (master, None)
    } else {
        let cluster = LocalCluster::spawn_with(
            &model_name,
            n,
            config,
            provider,
            faults,
            cocoi::coordinator::PoolOptions {
                worker_slots: args.get_usize("worker-slots", 1)?,
            },
        )?;
        let (master, workers) = cluster.into_parts();
        (master, Some(workers))
    };

    if args.has("stream") {
        master = run_stream(master, &model_name, args)?;
    } else {
        run_inferences(&mut master, &model_name, runs)?;
        // The streamed path scrapes through the server front-end (which
        // adds its own counters); batch runs scrape the hub directly.
        if let Some(path) = &metrics_path {
            let mut snap = cocoi::obs::export::Snapshot::new();
            master.metrics_hub().export_into(&mut snap);
            std::fs::write(path, snap.to_prometheus())
                .with_context(|| format!("writing {}", path.display()))?;
            println!("metrics scrape -> {}", path.display());
        }
    }
    if let (Some(path), Some(tr)) = (trace_path.as_deref(), trace_handle.as_ref()) {
        tr.export_chrome().write_file(path)?;
        println!(
            "trace -> {} ({} request trees kept, {} dropped; load in Perfetto / chrome://tracing)",
            path.display(),
            tr.requests().len(),
            tr.dropped_requests()
        );
        for v in tr.violations() {
            log::warn!("trace invariant violated: {v}");
        }
    }
    dump_telemetry(&master, telemetry_path.as_deref())?;
    master.shutdown();
    if let Some(workers) = workers {
        workers.join()?;
    }
    Ok(())
}

/// `--stream N`: open-loop serving through the `InferenceServer`
/// front-end — non-blocking submits (Poisson-paced by `--rate`),
/// completions collected out of order, percentile/shed/backpressure
/// report at the end. Returns the master for telemetry dump + shutdown.
fn run_stream(
    master: cocoi::coordinator::Master,
    model_name: &str,
    args: &Args,
) -> Result<cocoi::coordinator::Master> {
    use cocoi::coordinator::{InferenceRequest, InferenceServer, ServeError, ServerConfig};
    use cocoi::sim::percentile;
    use std::time::Duration;

    let requests = match args.get("stream") {
        Some("true") | None => 32,
        Some(v) => v.parse().with_context(|| format!("--stream {v}"))?,
    };
    let rate = args.get_f64("rate", 0.0)?;
    let deadline = args.get_f64("deadline-ms", 0.0)?;
    let deadline = (deadline > 0.0).then(|| Duration::from_secs_f64(deadline / 1e3));
    let server = InferenceServer::start(
        master,
        ServerConfig {
            queue_capacity: args.get_usize("queue-cap", 64)?,
            max_concurrent: args.get_usize("concurrent", 0)?,
            tenant_quota: args.get_usize("tenant-quota", 0)?,
        },
    );

    let model = zoo::model(model_name)?;
    // With `--tenant-weight a=2,b=1`, stream requests round-robin across
    // the named tenants so the DRR/quota path is exercisable from the CLI.
    let tenants: Vec<String> = parse_tenant_weights(args.get("tenant-weight"))?
        .into_iter()
        .map(|(name, _)| name)
        .collect();
    let mut rng = Rng::new(args.get_usize("seed", 1)? as u64 ^ 0x57EA);
    let mut handles = Vec::new();
    let mut rejected = 0usize;
    for i in 0..requests {
        if rate > 0.0 && i > 0 {
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
        }
        let mut input = Tensor::zeros(model.input.0, model.input.1, model.input.2);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut req = InferenceRequest::new(input);
        if let Some(d) = deadline {
            req = req.with_deadline(d);
        }
        if !tenants.is_empty() {
            req = req.with_tenant(&tenants[i % tenants.len()]);
        }
        match server.submit(req) {
            Ok(h) => handles.push(h),
            Err(e) => {
                log::warn!("request {i} refused: {e}");
                rejected += 1;
            }
        }
    }

    // Sojourns are engine-stamped, so collecting in submission order
    // still measures each request exactly.
    let mut lats = Vec::new();
    let mut shed = 0usize;
    for h in handles {
        let (res, sojourn) = h.wait_timed();
        match res {
            Ok(_) => lats.push(sojourn.as_secs_f64()),
            Err(ServeError::DeadlineShed { .. }) => shed += 1,
            Err(e) => anyhow::bail!("streamed request failed: {e}"),
        }
    }

    println!(
        "streamed {requests} requests: {} served, {shed} shed (deadline), \
         {rejected} refused (backpressure)",
        lats.len()
    );
    if !lats.is_empty() {
        println!(
            "sojourn: p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms  mean {:.1}ms",
            percentile(&lats, 0.50) * 1e3,
            percentile(&lats, 0.95) * 1e3,
            percentile(&lats, 0.99) * 1e3,
            lats.iter().sum::<f64>() / lats.len() as f64 * 1e3,
        );
    }
    let stats = server.stats();
    println!(
        "server: {} submitted, {} completed, {} shed, {} failed, {} queue-full, \
         {} tenant-quota",
        stats.submitted,
        stats.completed,
        stats.shed,
        stats.failed,
        stats.rejected_queue_full,
        stats.rejected_tenant_quota
    );
    if let Some(path) = args.get("metrics") {
        let path = std::path::Path::new(path);
        std::fs::write(path, server.scrape().to_prometheus())
            .with_context(|| format!("writing {}", path.display()))?;
        println!("metrics scrape -> {}", path.display());
    }
    server.shutdown()
}

/// Write the master's telemetry dump (fitted capacities, quarantine log,
/// plan in force) to `path` when `--telemetry` was given.
fn dump_telemetry(
    master: &cocoi::coordinator::Master,
    path: Option<&std::path::Path>,
) -> Result<()> {
    if let Some(path) = path {
        master.telemetry_json().write_file(path)?;
        println!("telemetry dump -> {}", path.display());
    }
    Ok(())
}

fn run_inferences(
    master: &mut cocoi::coordinator::Master,
    model_name: &str,
    runs: usize,
) -> Result<()> {
    let model = zoo::model(model_name)?;
    let mut rng = Rng::new(99);
    for run in 0..runs {
        let mut input = Tensor::zeros(model.input.0, model.input.1, model.input.2);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let (out, metrics) = master.infer(&input)?;
        println!("run {run}: output shape {:?}", out.shape());
        println!("{}", metrics.table());
        println!(
            "coding overhead {:.1}% of distributed-layer time; {} failures, {} redispatches",
            100.0 * metrics.coding_seconds() / metrics.distributed_layer_seconds().max(1e-12),
            metrics.failures(),
            metrics.redispatches()
        );
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let slots = args.get_usize("slots", 1)?;
    let (provider, _service) = make_provider(args.has("pjrt"), args.get_usize("threads", 0)?)?;
    if let Some(addr) = args.get("connect") {
        return worker_announce_loop(addr, args, provider);
    }
    let listen = args.get("listen").unwrap_or("127.0.0.1:9090").to_string();
    cocoi::transport::tcp::serve(&listen, move |link| {
        let provider = provider.clone();
        let (tx, rx) = split_tcp(link.into_stream())?;
        cocoi::coordinator::worker::run_worker(
            Box::new(tx),
            Box::new(rx),
            cocoi::coordinator::worker::WorkerConfig {
                id: 0,
                provider,
                faults: WorkerFaults::none(),
                rng_seed: 0xDEC0DE,
                slots,
                trace: None,
            },
        )
    })
}

/// `--connect`: dial a running master's membership listener, join, and
/// serve. On link loss, reconnect with capped exponential backoff (the
/// master assigns a fresh worker id each join). Exits cleanly when the
/// master shuts this worker down, or errors once a dial exhausts
/// `--retries` attempts (0 = keep trying forever).
fn worker_announce_loop(
    addr: &str,
    args: &Args,
    provider: Arc<dyn ConvProvider>,
) -> Result<()> {
    use cocoi::coordinator::{run_worker_announcing, JoinOptions, WorkerConfig, WorkerExit};
    use cocoi::transport::tcp::{connect_with_backoff, Backoff};
    use std::time::Duration;

    let opts = JoinOptions {
        name: args
            .get("name")
            .map(str::to_string)
            .unwrap_or_else(|| format!("worker-pid{}", std::process::id())),
        model: args.get("model").unwrap_or("").to_string(),
    };
    let slots = args.get_usize("slots", 1)?;
    let backoff = Backoff {
        initial: Duration::from_millis(args.get_usize("retry-initial-ms", 200)? as u64),
        max: Duration::from_millis(args.get_usize("retry-max-ms", 5000)? as u64),
        factor: 2.0,
        retries: args.get_usize("retries", 0)? as u32,
    };
    loop {
        let link = connect_with_backoff(addr, &backoff)?;
        let (tx, rx) = split_tcp(link.into_stream())?;
        let exit = run_worker_announcing(
            Box::new(tx),
            Box::new(rx),
            WorkerConfig {
                id: 0, // reassigned from JoinAck
                provider: provider.clone(),
                faults: WorkerFaults::none(),
                rng_seed: 0xDEC0DE,
                slots,
                trace: None,
            },
            &opts,
        )?;
        match exit {
            WorkerExit::Shutdown => return Ok(()),
            WorkerExit::LinkClosed => log::warn!("link to {addr} lost; reconnecting"),
        }
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model_name = args.get("model").unwrap_or("vgg16");
    let n = args.get_usize("workers", 10)?;
    let model = zoo::model(model_name)?;
    let profile = SystemProfile::paper_default();
    let mut rng = Rng::new(1);
    let plan = cocoi::model::ModelPlan::build(
        &model,
        &profile,
        n,
        SplitPolicy::KCircle,
        &mut rng,
    )?;
    println!("split plan for {model_name} with n={n} workers:");
    println!(
        "{:<12} {:>5} {:>6} {:>12} {:>12} {:>6}",
        "layer", "k0", "type", "est local", "est dist", "gain"
    );
    for c in &plan.convs {
        println!(
            "{:<12} {:>5} {:>6} {:>11.2}s {:>11.2}s {:>5.1}%",
            c.node_id,
            c.k,
            if c.distributed { "1" } else { "2" },
            c.est_local,
            c.est_distributed,
            100.0 * (1.0 - c.est_distributed / c.est_local)
        );
    }
    println!(
        "estimated conv latency: {:.2}s ({} of {} layers distributed)",
        plan.estimated_conv_latency(),
        plan.type1_ids().len(),
        plan.convs.len()
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let scale = if args.has("full") {
        exp::Scale::full()
    } else if args.has("quick") {
        exp::Scale::quick()
    } else {
        exp::Scale::from_env()
    };
    match which {
        "gemm" => exp::gemm(scale)?,
        "fig4" => exp::fig4(scale)?,
        "fig5" => exp::fig5(scale)?,
        "fig6" => exp::fig6(scale)?,
        "fig7" => exp::fig7()?,
        "fig8" => exp::fig8()?,
        "fig9" => exp::fig9(scale)?,
        "fig10" => exp::fig10(scale)?,
        "table1" => exp::table1(scale)?,
        "theory" => exp::theory()?,
        "throughput" => exp::throughput(scale)?,
        "adaptive" => exp::adaptive(scale)?,
        "serving" => exp::serving(scale)?,
        "all" => {
            exp::gemm(scale)?;
            exp::fig7()?;
            exp::fig8()?;
            exp::fig4(scale)?;
            exp::table1(scale)?;
            exp::fig5(scale)?;
            exp::fig6(scale)?;
            exp::fig9(scale)?;
            exp::fig10(scale)?;
            exp::theory()?;
            exp::throughput(scale)?;
            exp::adaptive(scale)?;
            exp::serving(scale)?;
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn main() -> Result<()> {
    cocoi::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("infer") => cmd_infer(&args),
        Some("worker") => cmd_worker(&args),
        Some("plan") => cmd_plan(&args),
        Some("experiment") => cmd_experiment(&args),
        _ => {
            eprintln!(
                "usage: cocoi <infer|worker|plan|experiment> [flags]\n\
                 see rust/src/main.rs header for the full flag list"
            );
            std::process::exit(2);
        }
    }
}
