//! Splitting a duplex link into independently-owned send/recv halves —
//! the master runs one reader thread per worker, so the halves must move
//! to different threads.

use std::net::TcpStream;
use std::sync::mpsc;

use anyhow::Result;

use super::inproc::{DelayModel, InprocLink};
use super::tcp::TcpLink;
#[allow(unused_imports)]
use super::Link; // trait methods on TcpLink

pub trait FrameTx: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
}

pub trait FrameRx: Send {
    /// Blocking receive; `Ok(None)` = peer closed.
    fn recv(&mut self) -> Result<Option<Vec<u8>>>;
}

// ---- in-proc halves ------------------------------------------------------

pub struct InprocTx(pub(crate) mpsc::Sender<Vec<u8>>);
pub struct InprocRx {
    pub(crate) rx: mpsc::Receiver<Vec<u8>>,
    pub(crate) delay: DelayModel,
}

impl FrameTx for InprocTx {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.0
            .send(frame.to_vec())
            .map_err(|_| anyhow::anyhow!("peer closed"))
    }
}

impl FrameRx for InprocRx {
    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        match self.rx.recv() {
            Ok(frame) => {
                let d = self.delay.delay_for(frame.len());
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
                Ok(Some(frame))
            }
            Err(_) => Ok(None),
        }
    }
}

/// Split an in-proc link into owned halves.
pub fn split_inproc(link: InprocLink) -> (InprocTx, InprocRx) {
    let (tx, rx, delay) = link.into_parts();
    (InprocTx(tx), InprocRx { rx, delay })
}

// ---- tcp halves ----------------------------------------------------------

pub struct TcpTx(TcpLink);
pub struct TcpRx(TcpLink);

impl TcpRx {
    /// Bound how long `recv` may block (see [`TcpLink::set_read_timeout`]).
    /// The master arms this with the heartbeat deadline on joined workers
    /// so a silent peer surfaces as link death instead of wedging the
    /// reader thread.
    pub fn set_read_timeout(&self, dur: Option<std::time::Duration>) -> Result<()> {
        self.0.set_read_timeout(dur)
    }
}

impl FrameTx for TcpTx {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.0.send(frame)
    }
}

impl FrameRx for TcpRx {
    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        self.0.recv()
    }
}

/// Split a TCP link via `try_clone` (kernel-level duplex).
pub fn split_tcp(stream: TcpStream) -> Result<(TcpTx, TcpRx)> {
    let clone = stream.try_clone()?;
    Ok((
        TcpTx(TcpLink::from_stream(clone)),
        TcpRx(TcpLink::from_stream(stream)),
    ))
}

/// Boxed pair used by the master.
pub type LinkPair = (Box<dyn FrameTx>, Box<dyn FrameRx>);

/// Convenience: a connected in-proc (master-pair, worker-link) with a
/// receive-delay model on the worker->master direction.
pub fn inproc_pair_with_delay(master_rx_delay: DelayModel) -> (LinkPair, InprocLink) {
    let (mut master_side, worker_side) = super::inproc::pair();
    master_side.rx_delay = master_rx_delay;
    let (tx, rx) = split_inproc(master_side);
    ((Box::new(tx), Box::new(rx)), worker_side)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_halves_work_across_threads() {
        let ((mut tx, mut rx), mut worker) = inproc_pair_with_delay(DelayModel::default());
        let t = std::thread::spawn(move || {
            let got = worker.recv().unwrap().unwrap();
            worker.send(&got).unwrap();
        });
        tx.send(b"ping").unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), b"ping");
        t.join().unwrap();
    }
}
