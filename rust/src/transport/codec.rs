//! Hand-rolled binary codec for coordinator messages (no `serde` in the
//! offline vendor set). Little-endian, length-prefixed containers.

use anyhow::{bail, ensure, Result};

/// Byte-stream writer with the primitives our messages need.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Encoder {
        Encoder { buf: Vec::new() }
    }

    /// Exact-capacity constructor for the hot dispatch paths: when the
    /// frame length is known up front (see `WorkOrder::encoded_len`),
    /// the buffer is allocated once with zero slack — no grow-by-
    /// doubling, and no over-reserve kept alive by the master's
    /// re-dispatch frame cache.
    pub fn with_capacity(capacity: usize) -> Encoder {
        Encoder {
            buf: Vec::with_capacity(capacity),
        }
    }

    /// Pre-size the buffer for a known payload (hot dispatch path: one
    /// allocation per frame instead of grow-by-doubling).
    pub fn reserve(&mut self, additional: usize) -> &mut Self {
        self.buf.reserve(additional);
        self
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn usize(&mut self, v: usize) -> &mut Self {
        self.u64(v as u64)
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    pub fn f32s(&mut self, xs: &[f32]) -> &mut Self {
        self.u64(xs.len() as u64);
        // Bulk copy — the payload path (feature-map partitions) is hot.
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        self.buf.extend_from_slice(bytes);
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Matching reader.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Decoder<'a> {
        Decoder { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "short message");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A count/index encoded as u64 on the wire. Checked conversion: on
    /// a 32-bit host (the paper's Raspberry Pi 4B testbed commonly runs
    /// 32-bit userland) a plain `as usize` cast would silently truncate
    /// a malicious/corrupt value to its low 32 bits instead of erroring.
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        v.try_into()
            .map_err(|_| anyhow::anyhow!("u64 value {v} does not fit in usize on this host"))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        ensure!(len < 1 << 20, "implausible string length");
        Ok(String::from_utf8(self.take(len)?.to_vec())?)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()?;
        // Validate against the remaining buffer *before* the multiply and
        // the allocation: a malformed frame must not trigger a multi-GiB
        // reservation (the old `len < 1 << 32` check admitted a 16 GiB
        // request) or a usize overflow on 32-bit hosts.
        let remaining = (self.buf.len() - self.pos) as u64;
        ensure!(
            len.checked_mul(4).is_some_and(|bytes| bytes <= remaining),
            "f32 vector length {len} exceeds the {remaining} remaining bytes"
        );
        let len = len as usize;
        let bytes = self.take(len * 4)?;
        let mut out = vec![0f32; len];
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        Ok(out)
    }

    /// Bytes not yet consumed — lets callers validate a claimed element
    /// count against what the frame can actually hold before allocating.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn primitive_roundtrip() {
        prop::check("codec roundtrip", 64, |rng| {
            let a = rng.next_u64();
            let b = rng.uniform();
            let s: String = (0..rng.below(20))
                .map(|_| char::from(b'a' + rng.below(26) as u8))
                .collect();
            let xs: Vec<f32> = (0..rng.below(1000))
                .map(|_| rng.uniform() as f32)
                .collect();
            let mut e = Encoder::new();
            e.u64(a).f64(b).str(&s).f32s(&xs).u8(7);
            let bytes = e.finish();
            let mut d = Decoder::new(&bytes);
            assert_eq!(d.u64().unwrap(), a);
            assert_eq!(d.f64().unwrap(), b);
            assert_eq!(d.str().unwrap(), s);
            assert_eq!(d.f32s().unwrap(), xs);
            assert_eq!(d.u8().unwrap(), 7);
            d.done().unwrap();
        });
    }

    #[test]
    fn reserve_does_not_change_encoding() {
        let mut a = Encoder::new();
        a.u32(7).str("x").f32s(&[1.0, 2.0]);
        let mut b = Encoder::new();
        b.reserve(128);
        b.u32(7).str("x").f32s(&[1.0, 2.0]);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn short_input_errors() {
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.u64().is_err());
    }

    #[test]
    fn with_capacity_does_not_change_encoding() {
        let mut a = Encoder::new();
        a.u32(7).str("x").f32s(&[1.0, 2.0]);
        let mut b = Encoder::with_capacity(64);
        b.u32(7).str("x").f32s(&[1.0, 2.0]);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.finish(), b.finish());
    }

    /// Regression: `Decoder::usize` was `u64 as usize` unchecked — on a
    /// 32-bit host a wire value ≥ 2^32 silently truncated to its low 32
    /// bits (e.g. `1 << 32` decoded as 0). The conversion is now
    /// checked: out-of-range values error, in-range values round-trip.
    #[test]
    fn usize_decode_is_bounds_checked_not_truncating() {
        let mut e = Encoder::new();
        e.usize(7).usize(0);
        let mut d = Decoder::new(&e.finish());
        assert_eq!(d.usize().unwrap(), 7);
        assert_eq!(d.usize().unwrap(), 0);
        d.done().unwrap();
        // A value past u32::MAX: errors where usize is 32-bit, decodes
        // losslessly where it fits — never truncates.
        let wide: u64 = u64::from(u32::MAX) + 1;
        let mut e = Encoder::new();
        e.u64(wide);
        let bytes = e.finish();
        match usize::try_from(wide) {
            Ok(v) => assert_eq!(Decoder::new(&bytes).usize().unwrap(), v),
            Err(_) => assert!(Decoder::new(&bytes).usize().is_err()),
        }
    }

    #[test]
    fn oversized_f32s_length_rejected_before_alloc() {
        // A 16 GiB-style claim (admitted by the old `len < 1 << 32` check).
        let mut e = Encoder::new();
        e.u64((1u64 << 32) - 1);
        assert!(Decoder::new(&e.finish()).f32s().is_err());
        // A length whose `* 4` overflows u64.
        let mut e = Encoder::new();
        e.u64(u64::MAX / 2);
        assert!(Decoder::new(&e.finish()).f32s().is_err());
        // A modest length that still exceeds the remaining payload.
        let mut e = Encoder::new();
        e.u64(10).u32(0); // claims 10 floats, carries 4 bytes
        assert!(Decoder::new(&e.finish()).f32s().is_err());
        // The boundary case still decodes.
        let mut e = Encoder::new();
        e.f32s(&[1.5]);
        assert_eq!(Decoder::new(&e.finish()).f32s().unwrap(), vec![1.5]);
    }
}
