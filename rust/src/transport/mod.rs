//! Transports: how master and workers exchange frames.
//!
//! * [`inproc`] — channel-based duplex links inside one process, with an
//!   optional wall-clock delay injector (the testbed's "manually slept
//!   devices" / added WiFi delay, §V scenario 1).
//! * [`tcp`] — length-prefixed frames over TCP for true multi-process
//!   deployment (`cocoi worker` / `cocoi infer --workers tcp:...`).

pub mod codec;
pub mod inproc;
pub mod split;
pub mod tcp;

pub use split::{FrameRx, FrameTx, LinkPair};

use anyhow::Result;

/// A duplex, blocking frame link. Frames are opaque byte vectors
/// (encoded coordinator messages).
pub trait Link: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    /// Blocking receive; `Ok(None)` means the peer closed down.
    fn recv(&mut self) -> Result<Option<Vec<u8>>>;
}
