//! TCP transport: 4-byte little-endian length prefix + payload per frame.
//! Used by the `cocoi worker --listen/--connect` / `--workers tcp:` /
//! `infer --listen` deployment modes, the closest analogue of the paper's
//! WiFi testbed.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::Link;

/// Frame cap (a full VGG16 conv1 partition is ~13 MB; 256 MB is generous).
const MAX_FRAME: u32 = 256 << 20;

/// A TCP frame link.
pub struct TcpLink {
    stream: TcpStream,
}

impl TcpLink {
    pub fn connect(addr: &str) -> Result<TcpLink> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(TcpLink { stream })
    }

    pub fn from_stream(stream: TcpStream) -> TcpLink {
        stream.set_nodelay(true).ok();
        TcpLink { stream }
    }

    /// Recover the raw stream (e.g. to re-split into tx/rx halves).
    pub fn into_stream(self) -> TcpStream {
        self.stream
    }

    /// Bound how long `recv` may block waiting for the peer. A silent
    /// peer then surfaces as `Err` (kind `WouldBlock`/`TimedOut`), which
    /// reader threads treat as link death — the heartbeat deadline.
    /// `None` restores indefinite blocking.
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(dur)?;
        Ok(())
    }
}

impl Link for TcpLink {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let len = frame.len() as u32;
        anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(frame)?;
        self.stream.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        let mut len4 = [0u8; 4];
        match self.stream.read_exact(&mut len4) {
            Ok(()) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::UnexpectedEof
                    || e.kind() == std::io::ErrorKind::ConnectionReset =>
            {
                return Ok(None)
            }
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len4);
        anyhow::ensure!(len <= MAX_FRAME, "peer announced oversized frame: {len}");
        let mut buf = vec![0u8; len as usize];
        match self.stream.read_exact(&mut buf) {
            Ok(()) => Ok(Some(buf)),
            // EOF/reset *mid-frame* is still a peer disconnect (the peer
            // died while writing) — classify it like the prefix-boundary
            // case so the link-death path fires instead of surfacing a
            // generic io::Error.
            Err(e)
                if e.kind() == std::io::ErrorKind::UnexpectedEof
                    || e.kind() == std::io::ErrorKind::ConnectionReset =>
            {
                log::warn!("peer disconnected mid-frame ({len} byte frame): {e}");
                Ok(None)
            }
            Err(e) => Err(e.into()),
        }
    }
}

/// Capped exponential backoff policy for [`connect_with_backoff`].
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    /// First retry delay.
    pub initial: Duration,
    /// Delay cap.
    pub max: Duration,
    /// Multiplier applied after each failed attempt.
    pub factor: f64,
    /// Max connection attempts; `0` = retry forever.
    pub retries: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff {
            initial: Duration::from_millis(200),
            max: Duration::from_secs(5),
            factor: 2.0,
            retries: 0,
        }
    }
}

/// Dial `addr`, retrying with capped exponential backoff until connected
/// (or until `backoff.retries` attempts are exhausted, when non-zero).
pub fn connect_with_backoff(addr: &str, backoff: &Backoff) -> Result<TcpLink> {
    let mut delay = backoff.initial;
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        match TcpLink::connect(addr) {
            Ok(link) => return Ok(link),
            Err(e) => {
                if backoff.retries != 0 && attempt >= backoff.retries {
                    return Err(e.context(format!(
                        "giving up on {addr} after {attempt} attempts"
                    )));
                }
                log::warn!(
                    "connect to {addr} failed (attempt {attempt}): {e:#}; retrying in {delay:?}"
                );
                std::thread::sleep(delay);
                let next = delay.as_secs_f64() * backoff.factor;
                delay = Duration::from_secs_f64(next.min(backoff.max.as_secs_f64()));
            }
        }
    }
}

/// Accept loop helper: bind and serve one `TcpLink` per connection, each
/// on its own thread. A handler error affects only that connection — it
/// is logged, never propagated (a single bad peer must not kill the
/// accept loop). Never returns except on a bind error.
pub fn serve<F>(addr: &str, handler: F) -> Result<()>
where
    F: Fn(TcpLink) -> Result<()> + Send + Sync + 'static,
{
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    serve_listener(listener, handler)
}

/// [`serve`] over an already-bound listener (tests bind port 0 first).
pub fn serve_listener<F>(listener: TcpListener, handler: F) -> Result<()>
where
    F: Fn(TcpLink) -> Result<()> + Send + Sync + 'static,
{
    log::info!("listening on {}", listener.local_addr()?);
    let handler = Arc::new(handler);
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log::warn!("accept failed: {e}");
                continue;
            }
        };
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        let handler = Arc::clone(&handler);
        std::thread::Builder::new()
            .name(format!("conn-{peer}"))
            .spawn(move || {
                if let Err(e) = handler(TcpLink::from_stream(stream)) {
                    log::warn!("connection {peer} handler failed: {e:#}");
                }
            })
            .map_err(|e| anyhow::anyhow!("spawning connection thread: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut link = TcpLink::from_stream(stream);
            let got = link.recv().unwrap().unwrap();
            link.send(&got).unwrap(); // echo
            assert!(link.recv().unwrap().is_none()); // peer closes
        });
        let mut client = TcpLink::connect(&addr.to_string()).unwrap();
        let payload: Vec<u8> = (0..100_000).map(|i| (i % 251) as u8).collect();
        client.send(&payload).unwrap();
        assert_eq!(client.recv().unwrap().unwrap(), payload);
        drop(client);
        server.join().unwrap();
    }

    #[test]
    fn eof_mid_frame_is_peer_disconnect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let killer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // Announce a 64-byte frame, deliver 10 bytes, then die.
            stream.write_all(&64u32.to_le_bytes()).unwrap();
            stream.write_all(&[0u8; 10]).unwrap();
            stream.flush().unwrap();
        });
        let mut client = TcpLink::connect(&addr.to_string()).unwrap();
        killer.join().unwrap();
        // Mid-frame EOF must classify as clean peer-disconnect, not Err.
        assert!(client.recv().unwrap().is_none());
    }

    #[test]
    fn read_timeout_surfaces_as_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let holder = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            // Hold the connection open, silently, long enough for the
            // client's timeout to fire.
            std::thread::sleep(Duration::from_millis(400));
            drop(stream);
        });
        let mut client = TcpLink::connect(&addr.to_string()).unwrap();
        client
            .set_read_timeout(Some(Duration::from_millis(50)))
            .unwrap();
        let t0 = std::time::Instant::now();
        assert!(client.recv().is_err(), "silent peer must surface as Err");
        assert!(t0.elapsed() < Duration::from_millis(350));
        holder.join().unwrap();
    }

    #[test]
    fn serve_survives_bad_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _srv = std::thread::spawn(move || {
            serve_listener(listener, |mut link: TcpLink| {
                let frame = link.recv()?.ok_or_else(|| anyhow::anyhow!("no frame"))?;
                if frame == b"boom" {
                    anyhow::bail!("handler exploded");
                }
                link.send(&frame)?;
                Ok(())
            })
            .unwrap();
        });
        // First connection makes its handler fail...
        let mut bad = TcpLink::connect(&addr.to_string()).unwrap();
        bad.send(b"boom").unwrap();
        // ...the listener must still serve subsequent connections.
        for _ in 0..3 {
            let mut good = TcpLink::connect(&addr.to_string()).unwrap();
            good.send(b"ok").unwrap();
            assert_eq!(good.recv().unwrap().unwrap(), b"ok");
        }
    }

    #[test]
    fn backoff_reconnects_after_rebind() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener); // nothing bound yet: first attempts must fail
        let rebinder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let listener = TcpListener::bind(addr).unwrap();
            let (stream, _) = listener.accept().unwrap();
            let mut link = TcpLink::from_stream(stream);
            assert_eq!(link.recv().unwrap().unwrap(), b"hello");
        });
        let backoff = Backoff {
            initial: Duration::from_millis(50),
            max: Duration::from_millis(200),
            factor: 2.0,
            retries: 0,
        };
        let mut link = connect_with_backoff(&addr.to_string(), &backoff).unwrap();
        link.send(b"hello").unwrap();
        rebinder.join().unwrap();

        // Bounded retries against a dead address give up with an error.
        let dead = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead_addr = dead.local_addr().unwrap();
        drop(dead);
        let bounded = Backoff {
            initial: Duration::from_millis(10),
            max: Duration::from_millis(20),
            factor: 2.0,
            retries: 2,
        };
        assert!(connect_with_backoff(&dead_addr.to_string(), &bounded).is_err());
    }
}
