//! TCP transport: 4-byte little-endian length prefix + payload per frame.
//! Used by the `cocoi worker --listen` / `--workers tcp:` deployment mode,
//! the closest analogue of the paper's WiFi testbed.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{Context, Result};

use super::Link;

/// Frame cap (a full VGG16 conv1 partition is ~13 MB; 256 MB is generous).
const MAX_FRAME: u32 = 256 << 20;

/// A TCP frame link.
pub struct TcpLink {
    stream: TcpStream,
}

impl TcpLink {
    pub fn connect(addr: &str) -> Result<TcpLink> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(TcpLink { stream })
    }

    pub fn from_stream(stream: TcpStream) -> TcpLink {
        stream.set_nodelay(true).ok();
        TcpLink { stream }
    }

    /// Recover the raw stream (e.g. to re-split into tx/rx halves).
    pub fn into_stream(self) -> TcpStream {
        self.stream
    }
}

impl Link for TcpLink {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let len = frame.len() as u32;
        anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(frame)?;
        self.stream.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        let mut len4 = [0u8; 4];
        match self.stream.read_exact(&mut len4) {
            Ok(()) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::UnexpectedEof
                    || e.kind() == std::io::ErrorKind::ConnectionReset =>
            {
                return Ok(None)
            }
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_le_bytes(len4);
        anyhow::ensure!(len <= MAX_FRAME, "peer announced oversized frame: {len}");
        let mut buf = vec![0u8; len as usize];
        self.stream.read_exact(&mut buf)?;
        Ok(Some(buf))
    }
}

/// Accept loop helper: bind and yield one `TcpLink` per connection.
pub fn serve<F: FnMut(TcpLink) -> Result<()>>(addr: &str, mut handler: F) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    log::info!("worker listening on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        handler(TcpLink::from_stream(stream?))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut link = TcpLink::from_stream(stream);
            let got = link.recv().unwrap().unwrap();
            link.send(&got).unwrap(); // echo
            assert!(link.recv().unwrap().is_none()); // peer closes
        });
        let mut client = TcpLink::connect(&addr.to_string()).unwrap();
        let payload: Vec<u8> = (0..100_000).map(|i| (i % 251) as u8).collect();
        client.send(&payload).unwrap();
        assert_eq!(client.recv().unwrap().unwrap(), payload);
        drop(client);
        server.join().unwrap();
    }
}
