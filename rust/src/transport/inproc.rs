//! In-process transport: a pair of mpsc channels per worker, with an
//! optional per-byte + fixed delay injector emulating the testbed's
//! wireless link (delays are applied on the *receiving* side so the
//! sender never blocks, like a buffered NIC).

use std::sync::mpsc;
use std::time::Duration;

use anyhow::Result;

use super::Link;

/// Wall-clock delay model for one direction of a link.
#[derive(Clone, Copy, Debug, Default)]
pub struct DelayModel {
    /// Seconds per byte (1/bandwidth).
    pub per_byte: f64,
    /// Fixed floor per frame (propagation).
    pub fixed: f64,
}

impl DelayModel {
    pub fn delay_for(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(self.fixed + self.per_byte * bytes as f64)
    }
}

/// One endpoint of an in-process duplex link.
pub struct InprocLink {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    /// Delay applied to *incoming* frames.
    pub rx_delay: DelayModel,
}

impl InprocLink {
    /// Decompose into raw parts (see `transport::split`).
    pub fn into_parts(
        self,
    ) -> (mpsc::Sender<Vec<u8>>, mpsc::Receiver<Vec<u8>>, DelayModel) {
        (self.tx, self.rx, self.rx_delay)
    }
}

/// Create a connected (master-side, worker-side) pair.
pub fn pair() -> (InprocLink, InprocLink) {
    let (a_tx, b_rx) = mpsc::channel();
    let (b_tx, a_rx) = mpsc::channel();
    (
        InprocLink {
            tx: a_tx,
            rx: a_rx,
            rx_delay: DelayModel::default(),
        },
        InprocLink {
            tx: b_tx,
            rx: b_rx,
            rx_delay: DelayModel::default(),
        },
    )
}

impl Link for InprocLink {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        match self.rx.recv() {
            Ok(frame) => {
                let d = self.rx_delay.delay_for(frame.len());
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
                Ok(Some(frame))
            }
            Err(_) => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let (mut a, mut b) = pair();
        a.send(b"hello").unwrap();
        assert_eq!(b.recv().unwrap().unwrap(), b"hello");
        b.send(b"world").unwrap();
        assert_eq!(a.recv().unwrap().unwrap(), b"world");
    }

    #[test]
    fn close_detected() {
        let (a, mut b) = pair();
        drop(a);
        assert!(b.recv().unwrap().is_none());
    }

    #[test]
    fn delay_applies() {
        let (mut a, mut b) = pair();
        b.rx_delay = DelayModel {
            per_byte: 0.0,
            fixed: 0.05,
        };
        a.send(b"x").unwrap();
        let t0 = std::time::Instant::now();
        b.recv().unwrap().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(45));
    }
}
