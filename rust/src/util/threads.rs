//! Crate-wide compute-thread configuration.
//!
//! `default_threads()` resolves once per process: the `COCOI_THREADS`
//! env var if set (and > 0), else `std::thread::available_parallelism()`.
//!
//! Thread count never affects results: every parallel kernel in this
//! crate (`conv::gemm`, `coding::matrix`) partitions *output elements*
//! over fixed-size blocks, so the floating-point summation order — and
//! therefore the bitwise output — is identical at any thread count. The
//! setting only trades wall-clock for cores.

use std::sync::OnceLock;

/// Default worker-thread count for compute kernels.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("COCOI_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_one_and_stable() {
        let a = default_threads();
        assert!(a >= 1);
        assert_eq!(a, default_threads());
    }
}
