//! Shared substrates: PRNG, statistics, JSON, logging, property testing.
//!
//! These exist because the offline vendor set ships no `rand`, `serde`,
//! `criterion`, or `proptest`; each submodule is a small, tested,
//! dependency-free replacement (see DESIGN.md §3).

pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threads;

pub use rng::Rng;

/// Harmonic number `H_m = sum_{i=1..m} 1/i`, with `H_0 = 0`.
///
/// Order-statistics expectations of exponentials are differences of
/// harmonic numbers (David & Nagaraja [25]); used throughout `latency`.
pub fn harmonic(m: usize) -> f64 {
    // Exact summation is fine for the m <= 10^4 range the planner touches.
    (1..=m).map(|i| 1.0 / i as f64).sum()
}

/// `ceil(a / b)` for positive integers.
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harmonic_basics() {
        assert_eq!(harmonic(0), 0.0);
        assert!((harmonic(1) - 1.0).abs() < 1e-12);
        assert!((harmonic(2) - 1.5).abs() < 1e-12);
        // H_n ~ ln n + gamma
        let n = 100_000;
        let approx = (n as f64).ln() + 0.577_215_664_901_532_9;
        assert!((harmonic(n) - approx).abs() < 1e-4);
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 10), 1);
    }
}
