//! Minimal property-testing substrate (no `proptest` in the vendor set).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNGs; on failure
//! it reports the failing seed so the case can be replayed exactly. The
//! seed base can be pinned with `COCOI_PROP_SEED` for reproduction.
//!
//! This is deliberately tiny: no shrinking, but deterministic seeds make
//! failures replayable, which is what matters for CI debugging.

use super::rng::Rng;

/// Number of cases to run per property (default; override per call site).
pub const DEFAULT_CASES: usize = 128;

/// Run `f` for `cases` deterministic random cases. Panics with the seed on
/// the first failing case.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    let base = std::env::var("COCOI_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0C0_1D5E);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case} (replay with COCOI_PROP_SEED={base} \
                 case seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 32, |rng| {
            count += 1;
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_| panic!("boom"));
    }
}
