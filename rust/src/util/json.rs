//! Minimal JSON parser/serializer.
//!
//! The vendor set has no `serde`/`serde_json`; this module covers what the
//! repo actually exchanges with the python build layer: the
//! `artifacts/models.json` zoo export, `artifacts/manifest.json`, system
//! latency profiles, and experiment result dumps. Full RFC 8259 value
//! grammar (objects, arrays, strings with escapes incl. `\uXXXX`, numbers,
//! bools, null); numbers are parsed as f64 (all our payloads fit).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

// No `thiserror` in the vendor set: Display/Error are hand-implemented.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn usize_arr(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors -----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x.round() as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Required-field helpers with contextual errors.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    // ---- parse ---------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ---- serialize -----------------------------------------------------
    fn write(&self, out: &mut String, indent: usize, cur: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if indent > 0 {
                        out.push('\n');
                        out.push_str(&" ".repeat(cur + indent));
                    }
                    item.write(out, indent, cur + indent);
                }
                if indent > 0 {
                    out.push('\n');
                    out.push_str(&" ".repeat(cur));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if indent > 0 {
                        out.push('\n');
                        out.push_str(&" ".repeat(cur + indent));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    v.write(out, indent, cur + indent);
                }
                if indent > 0 {
                    out.push('\n');
                    out.push_str(&" ".repeat(cur));
                }
                out.push('}');
            }
        }
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, 0);
        s
    }

    /// Pretty (2-space) serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 2, 0);
        s
    }

    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string_pretty())?;
        Ok(())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // Consume one UTF-8 code point verbatim.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.pos + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[self.pos..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null, "x\n\"y\""], "c": {"d": []}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 5);
        assert_eq!(v.get("b").as_arr().unwrap()[4].as_str(), Some("x\n\"y\""));
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 4, "s": "hi"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(v.req_f64("missing").is_err());
    }
}
