//! Deterministic PRNG + distribution sampling.
//!
//! xoshiro256++ seeded via SplitMix64. Deterministic seeding keeps every
//! experiment in EXPERIMENTS.md exactly reproducible; the vendor set has no
//! `rand` crate, so sampling (uniform, exponential, shift-exponential,
//! normal) is implemented here and unit-tested against analytic moments.

/// xoshiro256++ generator (Blackman & Vigna). Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per worker thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias is < 2^-64, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard exponential with rate `lambda` (mean `1/lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // Inverse CDF; 1 - uniform() is in (0, 1].
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Standard normal via Box–Muller (polar-free, two uniforms).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.uniform(); // (0, 1]
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform values in `[lo, hi)` — weight init etc.
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = lo + (hi - lo) * self.uniform() as f32;
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::new(7);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Rng::new(11);
        let lambda = 2.5;
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.exponential(lambda)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / (lambda * lambda)).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let s = rng.sample_distinct(10, 6);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 6);
            assert!(sorted.iter().all(|&i| i < 10));
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
