//! Summary statistics used by the bench harness and experiment drivers.

/// Linear-interpolation percentile of an (unsorted) sample, `q` in
/// [0, 1]; NaN on empty. THE percentile implementation — shared by
/// [`Summary::quantile`] and the serving tables (`sim::percentile`), so
/// every latency report interpolates the same way.
///
/// NaN samples (a failed/shed request folded into a latency table) are
/// filtered out explicitly rather than fed to the comparator: the old
/// `partial_cmp(..).unwrap()` sort panicked the whole bench driver on a
/// single NaN. An all-NaN sample propagates NaN, like the empty one.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if s.is_empty() {
        return f64::NAN;
    }
    s.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = pos - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Online/summary statistics over a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { xs: Vec::new() }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        Summary { xs: xs.to_vec() }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Unbiased sample variance.
    pub fn var(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Quantile by linear interpolation on the sorted sample, `q` in [0,1].
    pub fn quantile(&self, q: f64) -> f64 {
        percentile(&self.xs, q)
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        self.std() / (self.xs.len() as f64).sqrt()
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Empirical CDF points `(x_i, i/n)` of a sample — used for Fig. 8.
/// NaN samples are dropped (a NaN x-coordinate would break the
/// monotone-x invariant the plot relies on); the CDF is over the
/// remaining observations.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    s.sort_by(f64::total_cmp);
    let n = s.len() as f64;
    s.iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.quantile(1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone() {
        let pts = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(pts.len(), 3);
        assert!(pts.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 < w[1].1));
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.quantile(0.5).is_nan());
    }

    /// Regression: a single NaN sample used to panic the sort inside
    /// `percentile` (`partial_cmp(..).unwrap()`), taking the whole
    /// bench/experiment driver down mid-sweep. NaNs are now filtered;
    /// the percentile is over the remaining finite samples, and an
    /// all-NaN table propagates NaN instead of panicking.
    #[test]
    fn nan_samples_are_filtered_not_panicking() {
        let with_nan = [3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        assert_eq!(percentile(&with_nan, 0.0), 1.0);
        assert_eq!(percentile(&with_nan, 1.0), 3.0);
        assert!((percentile(&with_nan, 0.5) - 2.0).abs() < 1e-12);
        assert!(percentile(&[f64::NAN, f64::NAN], 0.5).is_nan());
        let pts = ecdf(&[f64::NAN, 2.0, 1.0]);
        assert_eq!(pts.len(), 2);
        assert!(pts.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
        // Infinities still order deterministically under total_cmp.
        assert_eq!(percentile(&[f64::INFINITY, 1.0], 1.0), f64::INFINITY);
    }
}
