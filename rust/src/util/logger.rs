//! Tiny `log`-facade backend: stderr, level from `COCOI_LOG` (error..trace).
//!
//! No `env_logger` in the vendor set; this is the subset we need — leveled,
//! timestamped (relative to process start) lines on stderr.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed();
        eprintln!(
            "[{:>9.3}s {:<5} {}] {}",
            t.as_secs_f64(),
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level defaults to `info`; override with
/// `COCOI_LOG=trace|debug|info|warn|error|off`.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let _ = START.get_or_init(Instant::now); // anchor t=0 at install time
    let level = match std::env::var("COCOI_LOG").as_deref() {
        Ok("trace") => log::LevelFilter::Trace,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke line");
    }
}
