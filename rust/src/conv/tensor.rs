//! Minimal CHW feature-map tensor (batch size is 1 throughout, as in the
//! paper's sparse-edge-request setting, §II-B).

use anyhow::{ensure, Result};

/// A `(C, H, W)` f32 feature map, dense CHW layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(c: usize, h: usize, w: usize) -> Tensor {
        Tensor {
            c,
            h,
            w,
            data: vec![0.0; c * h * w],
        }
    }

    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<f32>) -> Result<Tensor> {
        ensure!(
            data.len() == c * h * w,
            "shape ({c},{h},{w}) wants {} elements, got {}",
            c * h * w,
            data.len()
        );
        Ok(Tensor { c, h, w, data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut f32 {
        &mut self.data[(c * self.h + y) * self.w + x]
    }

    /// Zero-pad spatially by `p` on every side.
    pub fn pad(&self, p: usize) -> Tensor {
        if p == 0 {
            return self.clone();
        }
        let mut out = Tensor::zeros(self.c, self.h + 2 * p, self.w + 2 * p);
        for c in 0..self.c {
            for y in 0..self.h {
                let src = &self.data[(c * self.h + y) * self.w..][..self.w];
                let base = (c * out.h + y + p) * out.w + p;
                out.data[base..base + self.w].copy_from_slice(src);
            }
        }
        out
    }

    /// Copy of columns `[a, b)` across all channels/rows — the width-slice
    /// primitive behind input splitting (paper eq. 2 ranges).
    pub fn slice_w(&self, a: usize, b: usize) -> Tensor {
        assert!(a < b && b <= self.w, "slice [{a},{b}) of width {}", self.w);
        let w = b - a;
        let mut out = Tensor::zeros(self.c, self.h, w);
        for c in 0..self.c {
            for y in 0..self.h {
                let src = &self.data[(c * self.h + y) * self.w + a..][..w];
                let dst = &mut out.data[(c * self.h + y) * w..][..w];
                dst.copy_from_slice(src);
            }
        }
        out
    }

    /// Concatenate along width. All parts must agree on `(c, h)`.
    pub fn concat_w(parts: &[Tensor]) -> Result<Tensor> {
        ensure!(!parts.is_empty(), "concat of zero tensors");
        let (c, h) = (parts[0].c, parts[0].h);
        ensure!(
            parts.iter().all(|p| p.c == c && p.h == h),
            "concat_w with mismatched channel/height"
        );
        let w: usize = parts.iter().map(|p| p.w).sum();
        let mut out = Tensor::zeros(c, h, w);
        for ci in 0..c {
            for y in 0..h {
                let mut x0 = 0;
                for p in parts {
                    let src = &p.data[(ci * h + y) * p.w..][..p.w];
                    let dst = &mut out.data[(ci * out.h + y) * out.w + x0..][..p.w];
                    dst.copy_from_slice(src);
                    x0 += p.w;
                }
            }
        }
        Ok(out)
    }

    /// Flatten to a vector (row-major CHW — matches python `flatten`).
    pub fn flatten(&self) -> Vec<f32> {
        self.data.clone()
    }

    pub fn from_flat(c: usize, h: usize, w: usize, flat: Vec<f32>) -> Result<Tensor> {
        Tensor::from_vec(c, h, w, flat)
    }

    /// Element-wise ReLU in place.
    pub fn relu_inplace(&mut self) {
        for v in self.data.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Add a per-channel bias in place.
    pub fn add_bias_inplace(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.c);
        let plane = self.h * self.w;
        for (c, &b) in bias.iter().enumerate() {
            for v in &mut self.data[c * plane..(c + 1) * plane] {
                *v += b;
            }
        }
    }

    /// Element-wise sum (ResNet skip connections).
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        ensure!(self.shape() == other.shape(), "add with mismatched shapes");
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Tensor::from_vec(self.c, self.h, self.w, data)
    }

    /// Max absolute difference vs another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pad_places_content() {
        let t = Tensor::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let p = t.pad(1);
        assert_eq!(p.shape(), (1, 4, 4));
        assert_eq!(p.at(0, 0, 0), 0.0);
        assert_eq!(p.at(0, 1, 1), 1.0);
        assert_eq!(p.at(0, 2, 2), 4.0);
        assert_eq!(p.at(0, 3, 3), 0.0);
    }

    #[test]
    fn slice_concat_roundtrip() {
        prop::check("slice_w/concat_w roundtrip", 64, |rng| {
            let c = 1 + rng.below(4);
            let h = 1 + rng.below(6);
            let w = 2 + rng.below(20);
            let mut t = Tensor::zeros(c, h, w);
            rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
            // Random cut points.
            let cut = 1 + rng.below(w - 1);
            let left = t.slice_w(0, cut);
            let right = t.slice_w(cut, w);
            let back = Tensor::concat_w(&[left, right]).unwrap();
            assert_eq!(back, t);
        });
    }

    #[test]
    fn flatten_roundtrip() {
        let t = Tensor::from_vec(2, 1, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let f = t.flatten();
        let back = Tensor::from_flat(2, 1, 2, f).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn bias_and_relu() {
        let mut t = Tensor::from_vec(2, 1, 2, vec![-1.0, 1.0, -2.0, 2.0]).unwrap();
        t.add_bias_inplace(&[0.5, -0.5]);
        t.relu_inplace();
        assert_eq!(t.data, vec![0.0, 1.5, 0.0, 1.5]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let a = Tensor::zeros(1, 2, 2);
        let b = Tensor::zeros(1, 2, 3);
        assert!(a.add(&b).is_err());
        assert!(Tensor::from_vec(1, 2, 2, vec![0.0; 3]).is_err());
    }
}
