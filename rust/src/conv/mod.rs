//! Convolution substrate: CHW tensors, conv-layer math (eqs. 8–12 FLOP
//! scalings), the CoCoI width-split geometry (eqs. 1–2), and the
//! im2col+GEMM execution path.

pub mod gemm;
pub mod im2col;
pub mod layer;
pub mod split;
pub mod tensor;

pub use gemm::{PackedA, Scratch};
pub use layer::ConvSpec;
pub use split::{SplitPlan, WidthRange};
pub use tensor::Tensor;
