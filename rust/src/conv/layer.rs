//! 2D convolution layer: configuration, output geometry, the paper's
//! FLOP/byte scalings (eqs. 8–12), and reference CPU execution.

use anyhow::{ensure, Result};

use super::im2col;
use super::tensor::Tensor;

/// Configuration of a 2D conv layer (square kernel, equal stride on both
/// dims — the paper's setting; `k_w`/`s_w` name the width-dimension values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    pub c_in: usize,
    pub c_out: usize,
    /// kernel_size `K_W` (square).
    pub k_w: usize,
    /// stride `S_W`.
    pub s_w: usize,
    /// symmetric zero padding.
    pub pad: usize,
}

impl ConvSpec {
    pub fn new(c_in: usize, c_out: usize, k_w: usize, s_w: usize, pad: usize) -> ConvSpec {
        ConvSpec {
            c_in,
            c_out,
            k_w,
            s_w,
            pad,
        }
    }

    /// Output width for a *padded* input width (paper:
    /// `W_O = floor((W_I − K_W + 1 − 1)/S_W) + 1` — standard conv arithmetic).
    pub fn out_dim_padded(&self, in_dim_padded: usize) -> usize {
        assert!(in_dim_padded >= self.k_w, "input smaller than kernel");
        (in_dim_padded - self.k_w) / self.s_w + 1
    }

    /// Output width/height for an *unpadded* input dimension.
    pub fn out_dim(&self, in_dim: usize) -> usize {
        self.out_dim_padded(in_dim + 2 * self.pad)
    }

    /// Weight tensor element count `(C_O, C_I, K, K)`.
    pub fn weight_len(&self) -> usize {
        self.c_out * self.c_in * self.k_w * self.k_w
    }

    // ---- paper scalings (k-split versions live in conv::split) ---------

    /// eq. (9): FLOPs of a conv producing `(C_O, H_O, W_O)`.
    pub fn flops(&self, h_o: usize, w_o: usize) -> f64 {
        (self.c_out * h_o * w_o) as f64 * 2.0 * (self.c_in * self.k_w * self.k_w) as f64
    }

    /// eq. (10): transmission bytes of an input partition `(C_I, H_I, W)`.
    pub fn input_bytes(&self, h_i: usize, w: usize) -> f64 {
        4.0 * (self.c_in * h_i * w) as f64
    }

    /// eq. (11): transmission bytes of an output partition `(C_O, H_O, W)`.
    pub fn output_bytes(&self, h_o: usize, w: usize) -> f64 {
        4.0 * (self.c_out * h_o * w) as f64
    }

    /// Shared validity checks for a conv over an already-padded input —
    /// used by both the scalar oracle below and the tiled kernel paths
    /// in [`super::gemm`].
    pub(crate) fn check_padded_input(&self, input: &Tensor) -> Result<()> {
        ensure!(input.c == self.c_in, "input channels {} != {}", input.c, self.c_in);
        ensure!(
            input.h >= self.k_w && input.w >= self.k_w,
            "padded input {}x{} smaller than kernel {}",
            input.h,
            input.w,
            self.k_w
        );
        Ok(())
    }

    /// Reference convolution on an already-padded input: the *pure linear*
    /// map distributed to workers (no bias / activation — see coding docs).
    ///
    /// Uses im2col + the scalar GEMM oracle; the production path is the
    /// tiled multithreaded kernel in [`super::gemm`] (via
    /// `runtime::FallbackProvider`). The direct triple-loop lives in
    /// tests as an oracle for this oracle.
    pub fn conv_padded(&self, input: &Tensor, weights: &[f32]) -> Result<Tensor> {
        self.check_padded_input(input)?;
        ensure!(weights.len() == self.weight_len(), "bad weight length");
        let h_o = self.out_dim_padded(input.h);
        let w_o = self.out_dim_padded(input.w);
        let patches = im2col::im2col(input, self.k_w, self.s_w); // (CKK, HoWo)
        let out = im2col::gemm(
            weights,
            self.c_out,
            self.c_in * self.k_w * self.k_w,
            &patches,
            h_o * w_o,
        );
        Tensor::from_vec(self.c_out, h_o, w_o, out)
    }

    /// Full layer on an unpadded input: pad → conv → (+bias).
    pub fn forward(&self, input: &Tensor, weights: &[f32], bias: Option<&[f32]>) -> Result<Tensor> {
        let padded = input.pad(self.pad);
        let mut out = self.conv_padded(&padded, weights)?;
        if let Some(b) = bias {
            out.add_bias_inplace(b);
        }
        Ok(out)
    }
}

/// Direct (naive) convolution — test oracle for `conv_padded`.
pub fn conv_direct(spec: &ConvSpec, input: &Tensor, weights: &[f32]) -> Tensor {
    let h_o = spec.out_dim_padded(input.h);
    let w_o = spec.out_dim_padded(input.w);
    let mut out = Tensor::zeros(spec.c_out, h_o, w_o);
    let kk = spec.k_w;
    for co in 0..spec.c_out {
        for oy in 0..h_o {
            for ox in 0..w_o {
                let mut acc = 0.0f32;
                for ci in 0..spec.c_in {
                    for ky in 0..kk {
                        for kx in 0..kk {
                            let iy = oy * spec.s_w + ky;
                            let ix = ox * spec.s_w + kx;
                            let wgt = weights[((co * spec.c_in + ci) * kk + ky) * kk + kx];
                            acc += wgt * input.at(ci, iy, ix);
                        }
                    }
                }
                *out.at_mut(co, oy, ox) = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn output_geometry() {
        // VGG 3x3/s1/p1 preserves size.
        let s = ConvSpec::new(3, 64, 3, 1, 1);
        assert_eq!(s.out_dim(224), 224);
        // ResNet stem: 7x7/s2/p3 on 224 -> 112.
        let stem = ConvSpec::new(3, 64, 7, 2, 3);
        assert_eq!(stem.out_dim(224), 112);
        // 3x3/s2/p1 on 56 -> 28.
        let down = ConvSpec::new(64, 128, 3, 2, 1);
        assert_eq!(down.out_dim(56), 28);
    }

    #[test]
    fn flops_formula() {
        let s = ConvSpec::new(64, 128, 3, 1, 1);
        // 2 * C_O*H_O*W_O * C_I*K^2
        assert_eq!(s.flops(10, 10), 2.0 * (128 * 100) as f64 * (64 * 9) as f64);
    }

    #[test]
    fn im2col_conv_matches_direct() {
        prop::check("conv im2col == direct", 24, |rng| {
            let c_in = 1 + rng.below(4);
            let c_out = 1 + rng.below(5);
            let k = [1, 3, 5][rng.below(3)];
            let s = 1 + rng.below(2);
            let spec = ConvSpec::new(c_in, c_out, k, s, 0);
            let h = k + rng.below(6);
            let w = k + rng.below(10);
            let mut input = Tensor::zeros(c_in, h, w);
            rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
            let mut weights = vec![0.0f32; spec.weight_len()];
            rng.fill_uniform_f32(&mut weights, -1.0, 1.0);
            let fast = spec.conv_padded(&input, &weights).unwrap();
            let slow = conv_direct(&spec, &input, &weights);
            assert_eq!(fast.shape(), slow.shape());
            assert!(fast.max_abs_diff(&slow) < 1e-4);
        });
    }

    #[test]
    fn forward_applies_pad_and_bias() {
        let spec = ConvSpec::new(1, 1, 3, 1, 1);
        let input = Tensor::from_vec(1, 1, 1, vec![1.0]).unwrap();
        let weights = vec![0.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0];
        let out = spec.forward(&input, &weights, Some(&[0.5])).unwrap();
        assert_eq!(out.shape(), (1, 1, 1));
        assert!((out.data[0] - 2.5).abs() < 1e-6);
    }
}
