//! im2col + GEMM: the shape-polymorphic conv execution path.
//!
//! `im2col` lowers the sliding-window convolution to a matrix product
//! `W (C_O × C_I·K²) @ patches (C_I·K² × H_O·W_O)` — the same lowering the
//! L1 Pallas GEMM kernel consumes, and the fallback pure-rust provider.

use super::tensor::Tensor;

/// Extract conv patches of a *padded* input into a `(C_I·K·K, H_O·W_O)`
/// row-major matrix.
pub fn im2col(input: &Tensor, k: usize, s: usize) -> Vec<f32> {
    let mut out = Vec::new();
    im2col_into(input, k, s, &mut out);
    out
}

/// [`im2col`] into a reusable buffer (resized to exactly the patch
/// matrix; every element is overwritten, so buffer reuse is safe).
pub fn im2col_into(input: &Tensor, k: usize, s: usize, out: &mut Vec<f32>) {
    let h_o = (input.h - k) / s + 1;
    let w_o = (input.w - k) / s + 1;
    let rows = input.c * k * k;
    let cols = h_o * w_o;
    if out.len() != rows * cols {
        out.resize(rows * cols, 0.0);
    }
    // 1×1 stride-1 fast path: the patch matrix *is* the flattened input
    // (geometry proven by `im2col_identity_kernel_geometry`); skip the
    // loop nest entirely.
    if k == 1 && s == 1 {
        out.copy_from_slice(&input.data);
        return;
    }
    for c in 0..input.c {
        for ky in 0..k {
            for kx in 0..k {
                let row = (c * k + ky) * k + kx;
                let dst = &mut out[row * cols..(row + 1) * cols];
                for oy in 0..h_o {
                    let iy = oy * s + ky;
                    let src_base = (c * input.h + iy) * input.w + kx;
                    let dst_base = oy * w_o;
                    if s == 1 {
                        // Contiguous fast path: stride-1 gather is a memcpy.
                        dst[dst_base..dst_base + w_o]
                            .copy_from_slice(&input.data[src_base..src_base + w_o]);
                    } else {
                        for ox in 0..w_o {
                            dst[dst_base + ox] = input.data[src_base + ox * s];
                        }
                    }
                }
            }
        }
    }
}

/// Row-major GEMM: `C (m×n) = A (m×kk) · B (kk×n)`, f32.
///
/// ikj loop order with the innermost axpy over contiguous `B`/`C` rows.
/// This is the **scalar test oracle** for the tiled multithreaded kernel
/// in [`super::gemm`] (the production path). Dense weights make a
/// zero-skip branch pure overhead here — sparsity-aware skipping lives
/// only in `coding::matrix`, where coefficient matrices really are
/// sparse.
pub fn gemm(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * kk, "A shape mismatch");
    assert_eq!(b.len(), kk * n, "B shape mismatch");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let a_row = &a[i * kk..(i + 1) * kk];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (l, &aval) in a_row.iter().enumerate() {
            let b_row = &b[l * n..(l + 1) * n];
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += aval * bv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn gemm_naive(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..kk {
                    acc += a[i * kk + l] * b[l * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        prop::check("gemm == naive", 32, |rng| {
            let m = 1 + rng.below(8);
            let kk = 1 + rng.below(16);
            let n = 1 + rng.below(64);
            let mut a = vec![0.0f32; m * kk];
            let mut b = vec![0.0f32; kk * n];
            rng.fill_uniform_f32(&mut a, -1.0, 1.0);
            rng.fill_uniform_f32(&mut b, -1.0, 1.0);
            let fast = gemm(&a, m, kk, &b, n);
            let slow = gemm_naive(&a, m, kk, &b, n);
            for (x, y) in fast.iter().zip(&slow) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn im2col_identity_kernel_geometry() {
        // 1x1 kernel, stride 1: im2col is exactly the flattened input.
        let mut rng = Rng::new(5);
        let mut t = Tensor::zeros(3, 4, 5);
        rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
        let cols = im2col(&t, 1, 1);
        assert_eq!(cols, t.data);
    }

    #[test]
    fn im2col_strided_shapes() {
        let t = Tensor::zeros(2, 7, 9);
        let k = 3;
        let s = 2;
        let h_o = (7 - 3) / 2 + 1; // 3
        let w_o = (9 - 3) / 2 + 1; // 4
        let cols = im2col(&t, k, s);
        assert_eq!(cols.len(), 2 * k * k * h_o * w_o);
    }
}
