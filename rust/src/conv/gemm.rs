//! Cache-blocked, register-tiled, multithreaded f32 GEMM — the compute
//! backbone behind every conv subtask a CoCoI worker executes.
//!
//! The scalar ikj loop in [`super::im2col::gemm`] stays as the test
//! oracle; this module is the production path:
//!
//! * **Packing** — `A` (the weight matrix) is repacked into `MR`-row
//!   panels, `B` (the im2col patches) into `NR`-column panels, both
//!   blocked along `k` in [`KC`]-deep slabs, so the micro-kernel streams
//!   contiguous memory only.
//! * **Register tiling** — the micro-kernel keeps an `MR×NR` accumulator
//!   tile in registers across the whole `KC` slab (LLVM auto-vectorizes
//!   the inner `NR` loop; no intrinsics, no dependencies).
//! * **Threading** — `std::thread::scope` splits output *row panels*
//!   over threads (B packing splits *k slabs*). Every output element is
//!   owned by exactly one thread and its summation order is fixed by the
//!   `KC` blocking alone, so results are **bitwise identical across
//!   thread counts** — asserted in `rust/tests/gemm_kernel.rs`.
//! * **Scratch reuse** — [`Scratch`] owns the im2col buffer and both
//!   packed panels; steady-state subtask execution reuses them
//!   call-over-call (only the output tensor, which is moved into the
//!   reply frame, is freshly allocated). [`PackedA`] lets layer weights
//!   be packed once at model-load time (see `runtime::provider::
//!   ConvProvider::prepack`) instead of per subtask.

use anyhow::{ensure, Result};

use crate::util::threads::default_threads;

use super::im2col;
use super::layer::ConvSpec;
use super::tensor::Tensor;

/// Micro-tile rows (A panel height).
pub const MR: usize = 4;
/// Micro-tile columns (B panel width).
pub const NR: usize = 8;
/// k-dimension cache block. Fixed regardless of thread count so the f32
/// summation order — and therefore the bitwise result — never depends on
/// parallelism.
pub const KC: usize = 256;

/// Below this many FLOPs the kernel stays single-threaded (spawning
/// costs more than it buys). Depends only on the shape, never on the
/// configured thread count, and the arithmetic is identical either way.
const PAR_FLOPS_MIN: usize = 1 << 21;

/// Reusable buffers for the im2col + packed-GEMM conv path. All buffers
/// grow to the high-water mark and are fully overwritten on every use,
/// so reuse cannot perturb results.
#[derive(Debug, Default)]
pub struct Scratch {
    im2col: Vec<f32>,
    packed_a: Vec<f32>,
    packed_b: Vec<f32>,
    /// Column-concatenated im2col matrix of a coalesced batch (see
    /// [`conv_padded_packed_batch`]).
    im2col_batch: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

/// Weights of one layer packed into the kernel's A-panel layout
/// (`MR`-row panels within `KC` slabs, zero-padded to whole panels).
#[derive(Clone, Debug)]
pub struct PackedA {
    m: usize,
    k: usize,
    data: Vec<f32>,
}

impl PackedA {
    /// Pack a row-major `m×k` matrix.
    pub fn pack(a: &[f32], m: usize, k: usize) -> PackedA {
        let mut data = Vec::new();
        pack_a_into(a, m, k, &mut data);
        PackedA { m, k, data }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() != len {
        buf.resize(len, 0.0);
    }
}

/// Pack row-major `A (m×k)` into panel layout inside `out` (resized to
/// exactly `ceil(m/MR)·MR·k`). Layout: `KC` slabs outermost, then one
/// `MR×lc` panel per row group, column-major within the panel.
fn pack_a_into(a: &[f32], m: usize, kk: usize, out: &mut Vec<f32>) {
    assert_eq!(a.len(), m * kk, "A shape mismatch");
    let m_panels = m.div_ceil(MR);
    grow(out, m_panels * MR * kk);
    let nb_k = kk.div_ceil(KC);
    let mut off = 0;
    for pc in 0..nb_k {
        let l0 = pc * KC;
        let lc = KC.min(kk - l0);
        for ip in 0..m_panels {
            let panel = &mut out[off..off + MR * lc];
            for l in 0..lc {
                for i in 0..MR {
                    let row = ip * MR + i;
                    panel[l * MR + i] = if row < m { a[row * kk + l0 + l] } else { 0.0 };
                }
            }
            off += MR * lc;
        }
    }
}

/// Pack one `KC` slab of row-major `B (k×n)` into `NR`-column panels.
fn pack_b_block(b: &[f32], n: usize, l0: usize, lc: usize, strips: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), strips * NR * lc);
    for jr in 0..strips {
        let j0 = jr * NR;
        let nr_eff = NR.min(n - j0);
        let panel = &mut out[jr * NR * lc..][..NR * lc];
        for l in 0..lc {
            let src = &b[(l0 + l) * n + j0..][..nr_eff];
            let dst = &mut panel[l * NR..][..NR];
            dst[..nr_eff].copy_from_slice(src);
            dst[nr_eff..].fill(0.0);
        }
    }
}

/// Pack all of `B` into `out`, slabs parallelized over up to `threads`
/// scoped threads. Pure data movement: thread count cannot affect the
/// packed bytes.
fn pack_b_into(b: &[f32], kk: usize, n: usize, out: &mut Vec<f32>, threads: usize) {
    let strips = n.div_ceil(NR);
    grow(out, strips * NR * kk);
    let nb_k = kk.div_ceil(KC);
    let t = threads.clamp(1, nb_k.max(1));
    if t <= 1 {
        let mut rest: &mut [f32] = out;
        for pc in 0..nb_k {
            let lc = KC.min(kk - pc * KC);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(strips * NR * lc);
            rest = tail;
            pack_b_block(b, n, pc * KC, lc, strips, chunk);
        }
        return;
    }
    let mut chunks: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(nb_k);
    let mut rest: &mut [f32] = out;
    for pc in 0..nb_k {
        let lc = KC.min(kk - pc * KC);
        let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(strips * NR * lc);
        rest = tail;
        chunks.push((pc * KC, lc, chunk));
    }
    let per = nb_k.div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = chunks;
        while !rest.is_empty() {
            let batch: Vec<_> = rest.drain(..per.min(rest.len())).collect();
            s.spawn(move || {
                for (l0, lc, chunk) in batch {
                    pack_b_block(b, n, l0, lc, strips, chunk);
                }
            });
        }
    });
}

/// `MR×NR` register-tile update over one packed `KC` slab.
#[inline(always)]
fn micro_kernel(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for i in 0..MR {
            let ai = a[i];
            let acc_row = &mut acc[i];
            for (c, &bv) in acc_row.iter_mut().zip(b) {
                *c += ai * bv;
            }
        }
    }
}

/// Compute output row panels `[ip0, ip1)` into `c_chunk` (the contiguous
/// row slice `[ip0·MR, min(ip1·MR, m)) × n` of C). Each thread of the
/// parallel path owns one such disjoint chunk.
fn compute_rows(
    ip0: usize,
    ip1: usize,
    m: usize,
    kk: usize,
    n: usize,
    pa: &[f32],
    pb: &[f32],
    c_chunk: &mut [f32],
) {
    let m_panels = m.div_ceil(MR);
    let strips = n.div_ceil(NR);
    let rows = (ip1 * MR).min(m) - ip0 * MR;
    debug_assert_eq!(c_chunk.len(), rows * n);
    let nb_k = kk.div_ceil(KC);
    for pc in 0..nb_k {
        let l0 = pc * KC;
        let lc = KC.min(kk - l0);
        let a_block = &pa[m_panels * MR * l0..][..m_panels * MR * lc];
        let b_block = &pb[strips * NR * l0..][..strips * NR * lc];
        for jr in 0..strips {
            let bp = &b_block[jr * NR * lc..][..NR * lc];
            let j0 = jr * NR;
            let nr_eff = NR.min(n - j0);
            for ip in ip0..ip1 {
                let ap = &a_block[ip * MR * lc..][..MR * lc];
                let mut acc = [[0f32; NR]; MR];
                micro_kernel(ap, bp, &mut acc);
                let mr_eff = MR.min(m - ip * MR);
                for (i, acc_row) in acc.iter().enumerate().take(mr_eff) {
                    let dst = &mut c_chunk[((ip - ip0) * MR + i) * n + j0..][..nr_eff];
                    for (d, &v) in dst.iter_mut().zip(&acc_row[..nr_eff]) {
                        *d += v;
                    }
                }
            }
        }
    }
}

/// Core entry: `C (m×n) = packed_A · B (k×n)` with caller-owned packed-B
/// scratch. `threads == 0` means [`default_threads`]. Results are
/// bitwise identical for every thread count (see module docs).
pub fn gemm_packed_slices(
    m: usize,
    kk: usize,
    pa: &[f32],
    b: &[f32],
    n: usize,
    c: &mut [f32],
    threads: usize,
    packed_b: &mut Vec<f32>,
) {
    assert_eq!(pa.len(), m.div_ceil(MR) * MR * kk, "packed A shape mismatch");
    assert_eq!(b.len(), kk * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    c.fill(0.0);
    if m == 0 || kk == 0 || n == 0 {
        return;
    }
    let threads = if threads == 0 { default_threads() } else { threads };
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(kk)
        .saturating_mul(n);
    let par = threads > 1 && flops >= PAR_FLOPS_MIN;
    let strips = n.div_ceil(NR);
    pack_b_into(b, kk, n, packed_b, if par { threads } else { 1 });
    let pb: &[f32] = &packed_b[..strips * NR * kk];
    let m_panels = m.div_ceil(MR);
    let comp_threads = if par { threads.min(m_panels) } else { 1 };
    if comp_threads <= 1 {
        compute_rows(0, m_panels, m, kk, n, pa, pb, c);
        return;
    }
    let per = m_panels.div_ceil(comp_threads);
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = c;
        let mut ip0 = 0usize;
        while ip0 < m_panels {
            let ip1 = (ip0 + per).min(m_panels);
            let rows = (ip1 * MR).min(m) - ip0 * MR;
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            rest = tail;
            s.spawn(move || compute_rows(ip0, ip1, m, kk, n, pa, pb, chunk));
            ip0 = ip1;
        }
    });
}

/// `C = A·B` with a pre-packed A (weights packed once at load time).
pub fn gemm_packed(pa: &PackedA, b: &[f32], n: usize, c: &mut [f32], threads: usize, scratch: &mut Scratch) {
    gemm_packed_slices(pa.m, pa.k, &pa.data, b, n, c, threads, &mut scratch.packed_b);
}

/// Convenience one-shot: pack A, allocate C, multiply. Bench/test entry.
pub fn gemm_tiled(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize, threads: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * kk, "A shape mismatch");
    assert_eq!(b.len(), kk * n, "B shape mismatch");
    let pa = PackedA::pack(a, m, kk);
    let mut c = vec![0f32; m * n];
    let mut packed_b = Vec::new();
    gemm_packed_slices(m, kk, &pa.data, b, n, &mut c, threads, &mut packed_b);
    c
}

/// Tiled-kernel conv of an already-padded input: im2col into scratch,
/// pack weights into scratch, multiply. Same contract as
/// [`ConvSpec::conv_padded`] (the scalar oracle).
pub fn conv_padded_tiled(
    spec: &ConvSpec,
    input: &Tensor,
    weights: &[f32],
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    spec.check_padded_input(input)?;
    ensure!(weights.len() == spec.weight_len(), "bad weight length");
    let h_o = spec.out_dim_padded(input.h);
    let w_o = spec.out_dim_padded(input.w);
    let (m, kk, n) = (spec.c_out, spec.c_in * spec.k_w * spec.k_w, h_o * w_o);
    let Scratch {
        im2col: col_buf,
        packed_a,
        packed_b,
    } = scratch;
    im2col::im2col_into(input, spec.k_w, spec.s_w, col_buf);
    pack_a_into(weights, m, kk, packed_a);
    let mut out = vec![0f32; m * n];
    gemm_packed_slices(m, kk, &packed_a[..], &col_buf[..kk * n], n, &mut out, threads, packed_b);
    Tensor::from_vec(m, h_o, w_o, out)
}

/// Tiled-kernel conv against weights packed once at load time — the
/// steady-state worker path (no per-subtask weight packing).
pub fn conv_padded_packed(
    spec: &ConvSpec,
    input: &Tensor,
    pa: &PackedA,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    spec.check_padded_input(input)?;
    let kk = spec.c_in * spec.k_w * spec.k_w;
    ensure!(
        pa.m == spec.c_out && pa.k == kk,
        "packed weights {}x{} do not match conv {}x{}",
        pa.m,
        pa.k,
        spec.c_out,
        kk
    );
    let h_o = spec.out_dim_padded(input.h);
    let w_o = spec.out_dim_padded(input.w);
    let n = h_o * w_o;
    let Scratch {
        im2col: col_buf,
        packed_b,
        ..
    } = scratch;
    im2col::im2col_into(input, spec.k_w, spec.s_w, col_buf);
    let mut out = vec![0f32; pa.m * n];
    gemm_packed_slices(pa.m, kk, &pa.data, &col_buf[..kk * n], n, &mut out, threads, packed_b);
    Tensor::from_vec(pa.m, h_o, w_o, out)
}

/// Batched conv for cross-request shard coalescing: all `inputs` share
/// one shape (same layer, same split width), and the GEMM's N dimension
/// spans their concatenated im2col columns — one prepacked-weight pass
/// serves every request, amortizing packing/dispatch overhead.
///
/// Each output element's f32 summation runs over K in the same fixed
/// `KC`-slab order regardless of which column strip the element lands in
/// or how wide N is, so every request's slice of the batched result is
/// **bitwise identical** to running that request alone (asserted in the
/// tests below and in `rust/tests/gemm_kernel.rs`).
pub fn conv_padded_packed_batch(
    spec: &ConvSpec,
    inputs: &[&Tensor],
    pa: &PackedA,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Vec<Tensor>> {
    ensure!(!inputs.is_empty(), "empty conv batch");
    if inputs.len() == 1 {
        return Ok(vec![conv_padded_packed(spec, inputs[0], pa, threads, scratch)?]);
    }
    let first = inputs[0];
    spec.check_padded_input(first)?;
    for t in &inputs[1..] {
        ensure!(
            t.c == first.c && t.h == first.h && t.w == first.w,
            "coalesced batch mixes input shapes"
        );
    }
    let kk = spec.c_in * spec.k_w * spec.k_w;
    ensure!(
        pa.m == spec.c_out && pa.k == kk,
        "packed weights {}x{} do not match conv {}x{}",
        pa.m,
        pa.k,
        spec.c_out,
        kk
    );
    let h_o = spec.out_dim_padded(first.h);
    let w_o = spec.out_dim_padded(first.w);
    let n = h_o * w_o;
    let r = inputs.len();
    let n_total = n * r;
    let Scratch {
        im2col: col_one,
        packed_b,
        im2col_batch: col_batch,
        ..
    } = scratch;
    // Interleave per-input im2col columns: row l of the batch matrix is
    // [input0's row l | input1's row l | ...] — a pure copy, so the
    // per-element arithmetic is untouched.
    grow(col_batch, kk * n_total);
    for (ri, input) in inputs.iter().enumerate() {
        im2col::im2col_into(input, spec.k_w, spec.s_w, col_one);
        for l in 0..kk {
            col_batch[l * n_total + ri * n..][..n].copy_from_slice(&col_one[l * n..][..n]);
        }
    }
    let mut out = vec![0f32; pa.m * n_total];
    gemm_packed_slices(
        pa.m,
        kk,
        &pa.data,
        &col_batch[..kk * n_total],
        n_total,
        &mut out,
        threads,
        packed_b,
    );
    // Un-interleave the output columns back into per-request tensors.
    (0..r)
        .map(|ri| {
            let mut flat = vec![0f32; pa.m * n];
            for i in 0..pa.m {
                flat[i * n..(i + 1) * n]
                    .copy_from_slice(&out[i * n_total + ri * n..][..n]);
            }
            Tensor::from_vec(pa.m, h_o, w_o, flat)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn gemm_f64(a: &[f32], m: usize, kk: usize, b: &[f32], n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..kk {
                    acc += a[i * kk + l] as f64 * b[l * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn tiled_matches_f64_oracle_on_random_shapes() {
        prop::check("tiled gemm == f64 oracle", 24, |rng| {
            let m = 1 + rng.below(20);
            let kk = 1 + rng.below(300); // crosses the KC boundary
            let n = 1 + rng.below(64);
            let mut a = vec![0.0f32; m * kk];
            let mut b = vec![0.0f32; kk * n];
            rng.fill_uniform_f32(&mut a, -1.0, 1.0);
            rng.fill_uniform_f32(&mut b, -1.0, 1.0);
            let got = gemm_tiled(&a, m, kk, &b, n, 1 + rng.below(4));
            let want = gemm_f64(&a, m, kk, &b, n);
            let tol = 1e-5 * (kk as f32).max(16.0);
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < tol, "{x} vs {y} (m={m} kk={kk} n={n})");
            }
        });
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        let mut rng = Rng::new(0x6E44);
        // Big enough to clear PAR_FLOPS_MIN; odd sizes exercise every
        // remainder path.
        let (m, kk, n) = (33, 300, 523);
        let mut a = vec![0.0f32; m * kk];
        let mut b = vec![0.0f32; kk * n];
        rng.fill_uniform_f32(&mut a, -1.0, 1.0);
        rng.fill_uniform_f32(&mut b, -1.0, 1.0);
        let c1 = gemm_tiled(&a, m, kk, &b, n, 1);
        for t in [2, 3, 4, 8] {
            assert_eq!(c1, gemm_tiled(&a, m, kk, &b, n, t), "threads={t}");
        }
    }

    #[test]
    fn scratch_reuse_is_bitwise_stable() {
        let mut rng = Rng::new(0x5C3A);
        let spec = ConvSpec::new(5, 7, 3, 1, 0);
        let mut input = Tensor::zeros(5, 12, 9);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut w = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let mut scratch = Scratch::new();
        let first = conv_padded_tiled(&spec, &input, &w, 2, &mut scratch).unwrap();
        // Dirty the scratch with a different geometry, then repeat.
        let other = ConvSpec::new(2, 3, 5, 2, 0);
        let mut oin = Tensor::zeros(2, 20, 17);
        rng.fill_uniform_f32(&mut oin.data, -1.0, 1.0);
        let mut ow = vec![0f32; other.weight_len()];
        rng.fill_uniform_f32(&mut ow, -1.0, 1.0);
        conv_padded_tiled(&other, &oin, &ow, 2, &mut scratch).unwrap();
        let again = conv_padded_tiled(&spec, &input, &w, 2, &mut scratch).unwrap();
        assert_eq!(first.data, again.data);
    }

    #[test]
    fn prepacked_matches_unpacked_bitwise() {
        let mut rng = Rng::new(0x9A7);
        let spec = ConvSpec::new(6, 10, 3, 1, 0);
        let mut input = Tensor::zeros(6, 14, 11);
        rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
        let mut w = vec![0f32; spec.weight_len()];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let mut scratch = Scratch::new();
        let unpacked = conv_padded_tiled(&spec, &input, &w, 2, &mut scratch).unwrap();
        let pa = PackedA::pack(&w, spec.c_out, spec.c_in * 9);
        let packed = conv_padded_packed(&spec, &input, &pa, 2, &mut scratch).unwrap();
        assert_eq!(unpacked.data, packed.data);
        // Shape-mismatched pack is rejected.
        let wrong = ConvSpec::new(6, 11, 3, 1, 0);
        assert!(conv_padded_packed(&wrong, &input, &pa, 2, &mut scratch).is_err());
    }

    /// The coalescing kernel's load-bearing property: each request's
    /// slice of a batched conv is bitwise identical to running it alone
    /// (per-element K-order accumulation is independent of column
    /// position and of total N).
    #[test]
    fn batched_conv_matches_singles_bitwise() {
        let mut rng = Rng::new(0xBA7C);
        // Odd W so n is not a multiple of NR: batch offsets shift every
        // column's strip position relative to the solo run.
        let spec = ConvSpec::new(5, 9, 3, 1, 0);
        let pa = {
            let mut w = vec![0f32; spec.weight_len()];
            rng.fill_uniform_f32(&mut w, -1.0, 1.0);
            PackedA::pack(&w, spec.c_out, spec.c_in * 9)
        };
        let inputs: Vec<Tensor> = (0..3)
            .map(|_| {
                let mut t = Tensor::zeros(5, 11, 9);
                rng.fill_uniform_f32(&mut t.data, -1.0, 1.0);
                t
            })
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        for threads in [1, 3] {
            let mut scratch = Scratch::new();
            let batched =
                conv_padded_packed_batch(&spec, &refs, &pa, threads, &mut scratch).unwrap();
            assert_eq!(batched.len(), inputs.len());
            for (input, got) in inputs.iter().zip(&batched) {
                let solo =
                    conv_padded_packed(&spec, input, &pa, threads, &mut scratch).unwrap();
                assert_eq!(solo.data, got.data, "threads={threads}");
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        // 1×k @ k×1 and thin strips — exercise single-panel paths.
        for (m, kk, n) in [(1, 577, 1), (1, 1, 1), (3, 2, 17), (9, 1, 40)] {
            let mut rng = Rng::new((m * 31 + kk * 7 + n) as u64);
            let mut a = vec![0.0f32; m * kk];
            let mut b = vec![0.0f32; kk * n];
            rng.fill_uniform_f32(&mut a, -1.0, 1.0);
            rng.fill_uniform_f32(&mut b, -1.0, 1.0);
            let got = gemm_tiled(&a, m, kk, &b, n, 4);
            let want = gemm_f64(&a, m, kk, &b, n);
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3);
            }
        }
    }
}
