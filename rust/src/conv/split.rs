//! Width-dimension splitting of a conv layer (paper §II-B.1, eqs. 1–2).
//!
//! The output feature map is cut into `k` equal-width pieces; each piece's
//! *input* range follows from the conv receptive field:
//!
//! ```text
//! W_O^p(k) = ⌊W_O / k⌋                      (equal source pieces)
//! W_I^p(k) = K_W + (W_O^p(k) − 1)·S_W       (eq. 1)
//! a_I = a_O·S_W,   b_I = (b_O − 1)·S_W + K_W  (eq. 2)
//! ```
//!
//! When `k ∤ W_O`, the trailing `W_O mod k` columns form a *remainder*
//! piece the master computes locally (paper footnote 2) — it ships no
//! bytes, so it is never the bottleneck.

use anyhow::{ensure, Result};

use super::layer::ConvSpec;

/// Half-open width range `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WidthRange {
    pub start: usize,
    pub end: usize,
}

impl WidthRange {
    pub fn width(&self) -> usize {
        self.end - self.start
    }
}

/// The full geometry of a `k`-way width split of one conv layer.
#[derive(Clone, Debug)]
pub struct SplitPlan {
    pub k: usize,
    /// Padded input width the plan was built for.
    pub w_i: usize,
    /// Full output width.
    pub w_o: usize,
    /// Width of each source piece's output, `⌊W_O/k⌋`.
    pub w_o_p: usize,
    /// Width of each source piece's input (eq. 1).
    pub w_i_p: usize,
    /// Output ranges of the `k` source pieces.
    pub out_ranges: Vec<WidthRange>,
    /// Input ranges (padded-input coordinates) of the `k` pieces (eq. 2).
    pub in_ranges: Vec<WidthRange>,
    /// Master-local remainder piece, if `k ∤ W_O`.
    pub remainder_out: Option<WidthRange>,
    pub remainder_in: Option<WidthRange>,
}

impl SplitPlan {
    /// Build the split of a conv with padded input width `w_i` into `k`
    /// source pieces. Requires `1 ≤ k ≤ W_O`.
    pub fn new(spec: &ConvSpec, w_i: usize, k: usize) -> Result<SplitPlan> {
        ensure!(w_i >= spec.k_w, "padded input narrower than kernel");
        let w_o = spec.out_dim_padded(w_i);
        ensure!(
            k >= 1 && k <= w_o,
            "k = {k} outside [1, W_O = {w_o}]"
        );
        let w_o_p = w_o / k;
        let w_i_p = spec.k_w + (w_o_p - 1) * spec.s_w;

        let in_range = |a_o: usize, b_o: usize| WidthRange {
            start: a_o * spec.s_w,
            end: (b_o - 1) * spec.s_w + spec.k_w,
        };

        let mut out_ranges = Vec::with_capacity(k);
        let mut in_ranges = Vec::with_capacity(k);
        for i in 0..k {
            let (a_o, b_o) = (i * w_o_p, (i + 1) * w_o_p);
            out_ranges.push(WidthRange { start: a_o, end: b_o });
            in_ranges.push(in_range(a_o, b_o));
        }

        let rem = w_o % k;
        let (remainder_out, remainder_in) = if rem > 0 {
            let (a_o, b_o) = (k * w_o_p, w_o);
            (
                Some(WidthRange { start: a_o, end: b_o }),
                Some(in_range(a_o, b_o)),
            )
        } else {
            (None, None)
        };

        Ok(SplitPlan {
            k,
            w_i,
            w_o,
            w_o_p,
            w_i_p,
            out_ranges,
            in_ranges,
            remainder_out,
            remainder_in,
        })
    }

    /// Total input elements shipped per subtask (the `N^rec` scale basis).
    pub fn subtask_input_width(&self) -> usize {
        self.w_i_p
    }

    /// Adjacent pieces overlap on input when the receptive fields do
    /// (`k·W_I^p ≥ W_I` — paper §II-B.1 note).
    pub fn input_overlap(&self) -> isize {
        self.k as isize * self.w_i_p as isize - self.w_i as isize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::tensor::Tensor;
    use crate::util::prop;

    #[test]
    fn paper_figure2_example() {
        // Fig. 2: 3x3 kernel, stride 1, n=3, k=2. A padded 8-wide input
        // gives W_O = 6, so each piece outputs 3 columns from 5 input cols.
        let spec = ConvSpec::new(1, 1, 3, 1, 0);
        let plan = SplitPlan::new(&spec, 8, 2).unwrap();
        assert_eq!(plan.w_o, 6);
        assert_eq!(plan.w_o_p, 3);
        assert_eq!(plan.w_i_p, 5); // K + (3-1)*1
        assert_eq!(plan.in_ranges[0], WidthRange { start: 0, end: 5 });
        assert_eq!(plan.in_ranges[1], WidthRange { start: 3, end: 8 });
        assert!(plan.remainder_out.is_none());
        assert_eq!(plan.input_overlap(), 2); // pieces share 2 columns
    }

    #[test]
    fn ranges_partition_output_exactly() {
        prop::check("split covers output", 128, |rng| {
            let k_w = [1, 3, 5, 7][rng.below(4)];
            let s_w = 1 + rng.below(2);
            let spec = ConvSpec::new(1, 1, k_w, s_w, 0);
            let w_i = k_w + rng.below(120);
            let w_o = spec.out_dim_padded(w_i);
            let k = 1 + rng.below(w_o.min(12));
            let plan = SplitPlan::new(&spec, w_i, k).unwrap();

            // Source pieces are equal width and contiguous from 0.
            let mut cursor = 0;
            for r in &plan.out_ranges {
                assert_eq!(r.start, cursor);
                assert_eq!(r.width(), plan.w_o_p);
                cursor = r.end;
            }
            // Remainder (if any) completes [0, W_O).
            if let Some(rem) = plan.remainder_out {
                assert_eq!(rem.start, cursor);
                assert_eq!(rem.end, plan.w_o);
                assert!(rem.width() < k, "remainder width must be < k");
            } else {
                assert_eq!(cursor, plan.w_o);
            }
            // Input ranges stay in bounds and have width W_I^p (eq. 1).
            for r in &plan.in_ranges {
                assert!(r.end <= w_i);
                assert_eq!(r.width(), plan.w_i_p);
            }
        });
    }

    /// The defining property (paper §II-B.1): convolving an input slice
    /// over range (eq. 2) yields exactly the matching slice of the full
    /// convolution output.
    #[test]
    fn piecewise_conv_equals_full_conv() {
        prop::check("split conv == sliced conv", 32, |rng| {
            let c_in = 1 + rng.below(3);
            let c_out = 1 + rng.below(3);
            let k_w = [1, 3, 5][rng.below(3)];
            let s_w = 1 + rng.below(2);
            let spec = ConvSpec::new(c_in, c_out, k_w, s_w, 0);
            let h = k_w + rng.below(5);
            let w_i = k_w + 1 + rng.below(40);
            let w_o = spec.out_dim_padded(w_i);
            let k = 1 + rng.below(w_o.min(6));
            let plan = SplitPlan::new(&spec, w_i, k).unwrap();

            let mut input = Tensor::zeros(c_in, h, w_i);
            rng.fill_uniform_f32(&mut input.data, -1.0, 1.0);
            let mut weights = vec![0.0f32; spec.weight_len()];
            rng.fill_uniform_f32(&mut weights, -1.0, 1.0);
            let full = spec.conv_padded(&input, &weights).unwrap();

            let mut all_ranges: Vec<(WidthRange, WidthRange)> = plan
                .in_ranges
                .iter()
                .copied()
                .zip(plan.out_ranges.iter().copied())
                .collect();
            if let (Some(ri), Some(ro)) = (plan.remainder_in, plan.remainder_out) {
                all_ranges.push((ri, ro));
            }
            for (ri, ro) in all_ranges {
                let piece_in = input.slice_w(ri.start, ri.end);
                let piece_out = spec.conv_padded(&piece_in, &weights).unwrap();
                let expect = full.slice_w(ro.start, ro.end);
                assert_eq!(piece_out.shape(), expect.shape());
                assert!(
                    piece_out.max_abs_diff(&expect) < 1e-4,
                    "piece mismatch (k_w={k_w} s_w={s_w} k={k})"
                );
            }
        });
    }

    #[test]
    fn rejects_bad_k() {
        let spec = ConvSpec::new(1, 1, 3, 1, 0);
        assert!(SplitPlan::new(&spec, 10, 0).is_err());
        assert!(SplitPlan::new(&spec, 10, 9).is_err()); // W_O = 8
        assert!(SplitPlan::new(&spec, 10, 8).is_ok());
    }
}
