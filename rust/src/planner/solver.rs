//! The approximate optimal splitting strategy `k°` (problem 17).
//!
//! Lemma 1 proves `L(k)` convex on `[1, n)`; the paper solves the relaxed
//! problem with CVX and rounds. We golden-section-search the relaxation
//! (no solver dependency) and compare `L(⌊k'⌋)` vs `L(⌈k'⌉)` — plus `L(n)`
//! (the no-redundancy corner the relaxation excludes), so `k° = n` is
//! still reachable when redundancy cannot pay for itself.

use crate::latency::approx::{l_integer, l_relaxed};
use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;

/// Result of the approximate solve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KCircle {
    /// Relaxed optimum `k̂°` in `[1, n)`.
    pub k_relaxed: f64,
    /// Integer `k°` after rounding + the `k = n` corner check.
    pub k: usize,
    /// `L(k°)` under the integer (harmonic) form.
    pub l_value: f64,
}

/// Golden-section minimization of a unimodal function on `[lo, hi]`.
pub fn golden_section<F: Fn(f64) -> f64>(f: F, mut lo: f64, mut hi: f64, tol: f64) -> f64 {
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let mut x1 = hi - INV_PHI * (hi - lo);
    let mut x2 = lo + INV_PHI * (hi - lo);
    let mut f1 = f(x1);
    let mut f2 = f(x2);
    while hi - lo > tol {
        if f1 < f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - INV_PHI * (hi - lo);
            f1 = f(x1);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + INV_PHI * (hi - lo);
            f2 = f(x2);
        }
    }
    0.5 * (lo + hi)
}

/// Solve problem (17) for one layer: the approximate optimal `k°`.
pub fn solve_k_circ(dims: &LayerDims, profile: &SystemProfile, n: usize) -> KCircle {
    assert!(n >= 1);
    let k_cap = n.min(dims.w_o); // cannot split finer than output columns
    if k_cap == 1 || n < 3 {
        // Degenerate: only k = 1 (or Lemma 1's n ≥ 3 premise fails —
        // enumerate the handful of candidates directly).
        let (k, l) = (1..=k_cap)
            .map(|k| (k, l_integer(dims, profile, n, k)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        return KCircle {
            k_relaxed: k as f64,
            k,
            l_value: l,
        };
    }

    // Relaxed convex problem on [1, n): k' = argmin L(k).
    let hi = (n as f64 - 1e-6).min(k_cap as f64);
    let k_relaxed = golden_section(|k| l_relaxed(dims, profile, n, k), 1.0, hi, 1e-6);

    // Integer rounding (⌊k'⌋ vs ⌈k'⌉), plus the k = n corner.
    let mut candidates = vec![
        (k_relaxed.floor() as usize).clamp(1, k_cap),
        (k_relaxed.ceil() as usize).clamp(1, k_cap),
    ];
    if k_cap == n {
        candidates.push(n);
    }
    candidates.sort_unstable();
    candidates.dedup();
    let (k, l_value) = candidates
        .into_iter()
        .map(|k| (k, l_integer(dims, profile, n, k)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();

    KCircle {
        k_relaxed,
        k,
        l_value,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;

    fn dims() -> LayerDims {
        LayerDims::new(ConvSpec::new(64, 64, 3, 1, 1), 56, 56)
    }

    #[test]
    fn golden_section_finds_parabola_min() {
        let m = golden_section(|x| (x - 2.75).powi(2), 0.0, 10.0, 1e-9);
        assert!((m - 2.75).abs() < 1e-6);
    }

    #[test]
    fn k_circ_is_integer_argmin_of_l() {
        // Rounded answer must beat every other integer k (convexity ⇒
        // checking all k is a valid oracle).
        let d = dims();
        for scale in [0.05, 0.3, 1.0, 3.0, 20.0] {
            let mut p = SystemProfile::paper_default();
            p.mu_cmp *= scale;
            p.mu_rec *= scale;
            p.mu_sen *= scale;
            let n = 10;
            let sol = solve_k_circ(&d, &p, n);
            let brute = (1..=n)
                .map(|k| (k, l_integer(&d, &p, n, k)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert_eq!(sol.k, brute.0, "scale={scale}: {sol:?} vs brute {brute:?}");
        }
    }

    #[test]
    fn heavy_straggling_pushes_k_down() {
        let d = dims();
        let n = 10;
        let mut weak = SystemProfile::paper_default();
        weak.mu_cmp *= 100.0;
        weak.mu_rec *= 100.0;
        weak.mu_sen *= 100.0; // almost deterministic workers
        let mut heavy = SystemProfile::paper_default();
        heavy.mu_cmp /= 100.0;
        heavy.mu_rec /= 100.0;
        heavy.mu_sen /= 100.0; // extreme straggling
        let k_weak = solve_k_circ(&d, &weak, n).k;
        let k_heavy = solve_k_circ(&d, &heavy, n).k;
        assert!(
            k_heavy < k_weak,
            "heavy straggling should reduce k: {k_heavy} !< {k_weak}"
        );
    }

    #[test]
    fn narrow_output_caps_k() {
        // A layer with 4 output columns cannot split more than 4 ways.
        let d = LayerDims::new(ConvSpec::new(8, 8, 3, 1, 0), 6, 6);
        assert_eq!(d.w_o, 4);
        let p = SystemProfile::paper_default();
        let sol = solve_k_circ(&d, &p, 10);
        assert!(sol.k <= 4);
    }
}
