//! Optimal-splitting planner (paper §IV): the Monte-Carlo exact optimum
//! `k*` (problem 13), the approximate convex optimum `k°` (problem 17),
//! parameter sensitivity (Prop. 1), and the per-model split plan the
//! coordinator consumes.

pub mod deadline;
pub mod hetero;
pub mod montecarlo;
pub mod sensitivity;
pub mod solver;

pub use deadline::solve_deadline_k;
pub use sensitivity::Param;
pub use solver::{solve_k_circ, KCircle};

use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;
use crate::util::Rng;

/// How the per-layer `k` is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitPolicy {
    /// Approximate optimum `k°` from the convex relaxation (default).
    KCircle,
    /// Monte-Carlo `k*` with the given sample budget (slow, exact-ish).
    KStar { samples: usize },
    /// Fixed k for every layer (benchmarks: uncoded uses `n`, replication
    /// `n/2`, etc.).
    Fixed(usize),
}

/// Choose `k` for one layer under a policy.
pub fn choose_k(
    policy: SplitPolicy,
    dims: &LayerDims,
    profile: &SystemProfile,
    n: usize,
    rng: &mut Rng,
) -> usize {
    let cap = n.min(dims.w_o);
    match policy {
        SplitPolicy::KCircle => solve_k_circ(dims, profile, n).k,
        SplitPolicy::KStar { samples } => {
            montecarlo::optimal_k_star(dims, profile, n, samples, rng).0
        }
        SplitPolicy::Fixed(k) => k.clamp(1, cap),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;

    /// App. D headline: "in most cases the difference of k* and k° does
    /// not exceed 1". We assert gap ≤ 1 in most of a 3×3 profile grid and
    /// never worse than 2 (the paper's Fig. 9a shows gaps up to ~2 in the
    /// weak-straggling corner).
    #[test]
    fn k_star_vs_k_circ_gap_small() {
        let dims = LayerDims::new(ConvSpec::new(64, 64, 3, 1, 1), 56, 56);
        let n = 10;
        let mut rng = Rng::new(99);
        let mut within_one = 0;
        let mut total = 0;
        for cmp_scale in [0.1, 1.0, 10.0] {
            for tr_scale in [0.1, 1.0, 10.0] {
                let mut p = SystemProfile::paper_default();
                p.mu_cmp *= cmp_scale;
                p.mu_rec *= tr_scale;
                p.mu_sen *= tr_scale;
                let k_circ = solve_k_circ(&dims, &p, n).k;
                let (k_star, _) =
                    montecarlo::optimal_k_star(&dims, &p, n, 12_000, &mut rng);
                let gap = (k_star as isize - k_circ as isize).abs();
                assert!(
                    gap <= 3,
                    "cmp×{cmp_scale} tr×{tr_scale}: k*={k_star} k°={k_circ}"
                );
                if gap <= 1 {
                    within_one += 1;
                }
                total += 1;
            }
        }
        assert!(
            within_one * 5 >= total * 3,
            "gap ≤ 1 in only {within_one}/{total} cases"
        );
    }

    #[test]
    fn fixed_policy_clamps() {
        let dims = LayerDims::new(ConvSpec::new(4, 4, 3, 1, 0), 8, 8);
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(1);
        assert_eq!(choose_k(SplitPolicy::Fixed(100), &dims, &p, 10, &mut rng), 6); // W_O = 6
        assert_eq!(choose_k(SplitPolicy::Fixed(0), &dims, &p, 10, &mut rng), 1);
    }
}
