//! Deadline-driven redundancy (Dutta et al., "Coded convolution within a
//! deadline"): instead of minimizing the *expected* layer latency, pick
//! the split `k` — and therefore the redundancy `n − k` — whose fitted
//! *tail quantile* still fits the request's remaining slack. Less
//! redundancy (large k) is cheaper in encode/decode and per-task work
//! but has a heavier straggler tail; the solver walks down from the
//! mean-optimal cap until the tail fits, and reports `None` when even
//! maximum redundancy (`k = 1`) misses — the scheme selector's cue to
//! flip the layer to rateless LT.

use crate::latency::approx::l_tail_quantile;
use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;

/// Largest `k ∈ [1, k_max]` whose `z`-quantile latency estimate fits
/// within `slack` seconds, preferring less redundancy (mean-optimal
/// splits are at the top of the range; walking down only buys tail).
/// `None` when no k fits — including non-finite or non-positive slack.
pub fn solve_deadline_k(
    dims: &LayerDims,
    profile: &SystemProfile,
    n: usize,
    k_max: usize,
    slack: f64,
    z: f64,
) -> Option<usize> {
    if n == 0 || !slack.is_finite() || slack <= 0.0 {
        return None;
    }
    let cap = k_max.clamp(1, n.min(dims.w_o).max(1));
    (1..=cap)
        .rev()
        .find(|&k| l_tail_quantile(dims, profile, n, k, z) <= slack)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;
    use crate::latency::approx::l_integer;

    fn dims() -> LayerDims {
        LayerDims::new(ConvSpec::new(64, 64, 3, 1, 1), 56, 56)
    }

    #[test]
    fn generous_slack_keeps_the_cap_and_none_when_impossible() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let (n, k_max) = (8, 6);
        // Slack far above any estimate: keep the mean-optimal cap.
        assert_eq!(solve_deadline_k(&d, &p, n, k_max, 1e9, 1.65), Some(k_max));
        // Slack below even the k = 1 tail: impossible.
        let floor = l_tail_quantile(&d, &p, n, 1, 1.65);
        assert_eq!(solve_deadline_k(&d, &p, n, k_max, floor * 0.5, 1.65), None);
        assert_eq!(solve_deadline_k(&d, &p, n, k_max, f64::NAN, 1.65), None);
        assert_eq!(solve_deadline_k(&d, &p, n, k_max, -1.0, 1.65), None);
    }

    #[test]
    fn tighter_slack_never_raises_k() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let (n, k_max) = (8, 6);
        let hi = l_tail_quantile(&d, &p, n, k_max, 1.65) * 2.0;
        let mut slack = hi;
        let mut prev = usize::MAX;
        // Shrink slack geometrically: the chosen k must be monotone
        // non-increasing until it disappears.
        while slack > l_integer(&d, &p, n, 1) * 1e-4 {
            match solve_deadline_k(&d, &p, n, k_max, slack, 1.65) {
                Some(k) => {
                    assert!(k <= prev.min(k_max), "slack={slack}: k={k} prev={prev}");
                    prev = k;
                }
                None => prev = 0,
            }
            slack *= 0.7;
        }
        assert_eq!(prev, 0, "slack shrank to ~0 but a k still fit");
    }
}
