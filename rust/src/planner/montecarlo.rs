//! Monte-Carlo estimation of the exact objective `E[T^c(k)]` (problem 13).
//!
//! The k-th order statistic of *sums* of shift-exponentials has no closed
//! form (§IV-A), so the optimal `k*` is found by simulation, exactly as the
//! paper's App. D does (they use 3×10⁵ samples; callers pick the budget).

use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;
use crate::util::Rng;

/// Monte-Carlo estimate of `E[T^c(k)]` for one layer: encode + k-th order
/// statistic of per-worker (rec + cmp + sen) sums + decode.
pub fn expected_total_latency(
    dims: &LayerDims,
    profile: &SystemProfile,
    n: usize,
    k: usize,
    samples: usize,
    rng: &mut Rng,
) -> f64 {
    assert!(k >= 1 && k <= n);
    let enc = profile.enc_dist(dims, n, k);
    let dec = profile.dec_dist(dims, k);
    let rec = profile.rec_dist(dims, k);
    let cmp = profile.cmp_dist(dims, k);
    let sen = profile.sen_dist(dims, k);

    let mut worker = vec![0.0f64; n];
    let mut total = 0.0;
    for _ in 0..samples {
        for w in worker.iter_mut() {
            *w = rec.sample(rng) + cmp.sample(rng) + sen.sample(rng);
        }
        // k-th smallest via select_nth (O(n)).
        worker.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        total += enc.sample(rng) + worker[k - 1] + dec.sample(rng);
    }
    total / samples as f64
}

/// Sweep `k = 1..=k_max` and return `(k*, per-k estimates)`.
pub fn optimal_k_star(
    dims: &LayerDims,
    profile: &SystemProfile,
    n: usize,
    samples: usize,
    rng: &mut Rng,
) -> (usize, Vec<f64>) {
    let k_max = n.min(dims.w_o); // k cannot exceed the output width
    let estimates: Vec<f64> = (1..=k_max)
        .map(|k| expected_total_latency(dims, profile, n, k, samples, rng))
        .collect();
    let k_star = estimates
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i + 1)
        .unwrap();
    (k_star, estimates)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;
    use crate::latency::approx::l_integer;

    fn dims() -> LayerDims {
        LayerDims::new(ConvSpec::new(64, 64, 3, 1, 1), 56, 56)
    }

    #[test]
    fn mc_tracks_analytic_approx() {
        // The approximation (15)–(16) should be within a few percent of the
        // MC estimate of the true objective for interior k (App. D Fig. 9b).
        let d = dims();
        let p = SystemProfile::paper_default();
        let n = 10;
        let mut rng = Rng::new(2024);
        for k in [2usize, 4, 6, 8] {
            let mc = expected_total_latency(&d, &p, n, k, 20_000, &mut rng);
            let approx = l_integer(&d, &p, n, k);
            let rel = (mc - approx).abs() / mc;
            // The (15) per-phase split underestimates more at small k
            // (paper Fig. 9b shows the same asymmetry).
            let tol = if k <= 2 { 0.20 } else { 0.12 };
            assert!(rel < tol, "k={k}: mc={mc:.4} approx={approx:.4} rel={rel:.3}");
        }
    }

    #[test]
    fn k_star_interior_under_straggling() {
        // With strong straggling the optimum must keep redundancy: k* < n.
        let d = dims();
        let mut p = SystemProfile::paper_default();
        p.mu_cmp /= 30.0; // heavy compute straggling
        p.mu_rec /= 30.0;
        p.mu_sen /= 30.0;
        let mut rng = Rng::new(7);
        let (k_star, est) = optimal_k_star(&d, &p, 10, 8_000, &mut rng);
        assert!(k_star < 10, "k*={k_star}, estimates={est:?}");
        assert!(k_star >= 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let a = expected_total_latency(&d, &p, 8, 4, 2000, &mut Rng::new(5));
        let b = expected_total_latency(&d, &p, 8, 4, 2000, &mut Rng::new(5));
        assert_eq!(a, b);
    }
}
