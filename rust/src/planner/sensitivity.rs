//! Proposition 1: how system parameters move the approximate optimum
//! `k̂°`. Exposed as sweep helpers (Fig. 10) plus numeric monotonicity
//! checks in tests.

use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;

use super::solver::solve_k_circ;

/// Which profile coefficient a sweep perturbs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Param {
    MuM,
    ThetaM,
    MuCmp,
    ThetaCmp,
    MuRec,
    ThetaRec,
    MuSen,
    ThetaSen,
    /// μ^rec and μ^sen together (the paper's `μ_tr`).
    MuTr,
    /// θ^rec and θ^sen together.
    ThetaTr,
}

impl Param {
    pub fn apply(&self, base: &SystemProfile, value: f64) -> SystemProfile {
        let mut p = *base;
        match self {
            Param::MuM => p.mu_m = value,
            Param::ThetaM => p.theta_m = value,
            Param::MuCmp => p.mu_cmp = value,
            Param::ThetaCmp => p.theta_cmp = value,
            Param::MuRec => p.mu_rec = value,
            Param::ThetaRec => p.theta_rec = value,
            Param::MuSen => p.mu_sen = value,
            Param::ThetaSen => p.theta_sen = value,
            Param::MuTr => {
                p.mu_rec = value;
                p.mu_sen = value;
            }
            Param::ThetaTr => {
                p.theta_rec = value;
                p.theta_sen = value;
            }
        }
        p
    }

    pub fn name(&self) -> &'static str {
        match self {
            Param::MuM => "mu_m",
            Param::ThetaM => "theta_m",
            Param::MuCmp => "mu_cmp",
            Param::ThetaCmp => "theta_cmp",
            Param::MuRec => "mu_rec",
            Param::ThetaRec => "theta_rec",
            Param::MuSen => "mu_sen",
            Param::ThetaSen => "theta_sen",
            Param::MuTr => "mu_tr",
            Param::ThetaTr => "theta_tr",
        }
    }
}

/// Sweep one parameter over `values`, returning `(value, k°)` pairs.
pub fn sweep_k_circ(
    dims: &LayerDims,
    base: &SystemProfile,
    n: usize,
    param: Param,
    values: &[f64],
) -> Vec<(f64, usize)> {
    values
        .iter()
        .map(|&v| (v, solve_k_circ(dims, &param.apply(base, v), n).k))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;

    fn dims() -> LayerDims {
        LayerDims::new(ConvSpec::new(128, 128, 3, 1, 1), 112, 112)
    }

    fn is_nondecreasing(xs: &[(f64, usize)]) -> bool {
        xs.windows(2).all(|w| w[0].1 <= w[1].1)
    }

    fn is_nonincreasing(xs: &[(f64, usize)]) -> bool {
        xs.windows(2).all(|w| w[0].1 >= w[1].1)
    }

    fn logspace(lo: f64, hi: f64, steps: usize) -> Vec<f64> {
        (0..steps)
            .map(|i| lo * (hi / lo).powf(i as f64 / (steps - 1) as f64))
            .collect()
    }

    /// Prop. 1(i): k̂° increases in every worker straggler coefficient μ.
    #[test]
    fn prop1_mu_worker_monotone() {
        let d = dims();
        let base = SystemProfile::paper_default();
        let n = 10;
        for param in [Param::MuCmp, Param::MuTr] {
            let vals = logspace(1e6, 1e10, 9);
            let sweep = sweep_k_circ(&d, &base, n, param, &vals);
            assert!(
                is_nondecreasing(&sweep),
                "{}: {:?}",
                param.name(),
                sweep
            );
        }
    }

    /// Prop. 1(ii): k̂° increases in worker shift coefficients θ.
    #[test]
    fn prop1_theta_worker_monotone() {
        let d = dims();
        let base = SystemProfile::paper_default();
        let n = 10;
        for param in [Param::ThetaCmp, Param::ThetaTr] {
            let lo = match param {
                Param::ThetaCmp => 1e-10,
                _ => 1e-9,
            };
            let vals = logspace(lo, lo * 1e4, 9);
            let sweep = sweep_k_circ(&d, &base, n, param, &vals);
            assert!(
                is_nondecreasing(&sweep),
                "{}: {:?}",
                param.name(),
                sweep
            );
        }
    }

    /// Prop. 1(iii): a weaker master (larger θ^m, smaller μ^m) ⇒ smaller k̂°.
    #[test]
    fn prop1_master_monotone() {
        let d = dims();
        let base = SystemProfile::paper_default();
        let n = 10;
        let theta_sweep = sweep_k_circ(&d, &base, n, Param::ThetaM, &logspace(1e-11, 1e-7, 9));
        assert!(is_nonincreasing(&theta_sweep), "theta_m: {theta_sweep:?}");
        let mu_sweep = sweep_k_circ(&d, &base, n, Param::MuM, &logspace(1e7, 1e11, 9));
        assert!(is_nondecreasing(&mu_sweep), "mu_m: {mu_sweep:?}");
    }

    /// App. E: larger n gives a (weakly) larger optimal split.
    #[test]
    fn k_circ_grows_with_n() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let ks: Vec<usize> = (4..=16).map(|n| solve_k_circ(&d, &p, n).k).collect();
        assert!(ks.windows(2).all(|w| w[0] <= w[1]), "{ks:?}");
    }
}
