//! Heterogeneous-worker allocation — the paper's §VI future-work item
//! ("optimize the subtask allocation across heterogeneous workers").
//!
//! With an MDS code the source pieces must stay equal-sized, so the
//! heterogeneity lever is *which* workers participate and how much
//! redundancy to carry: a chronically slow device can contribute less
//! than it costs (it drags the k-th order statistic once `n − k` faster
//! workers are exhausted). We solve
//!
//! ```text
//! min over (S ⊆ workers, k ≤ |S|)   E[T^c(S, k)]
//! ```
//!
//! by Monte-Carlo over the non-iid per-worker distributions (closed forms
//! do not exist for non-iid order statistics of sums), searching subsets
//! in fastest-first order — the optimal subset under monotone speeds is a
//! prefix of the speed-sorted worker list.

use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;
use crate::util::Rng;

/// Per-worker speed multipliers (1.0 = the profile's nominal device;
/// larger = slower). `cmp` scales compute, `tr` scales both transfers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerSpeed {
    pub cmp: f64,
    pub tr: f64,
}

impl WorkerSpeed {
    pub fn nominal() -> WorkerSpeed {
        WorkerSpeed { cmp: 1.0, tr: 1.0 }
    }

    pub fn slow(factor: f64) -> WorkerSpeed {
        WorkerSpeed {
            cmp: factor,
            tr: factor,
        }
    }

    /// Sort key: expected per-unit cost (compute-dominated workloads).
    fn mean_cost(&self) -> f64 {
        self.cmp + 0.25 * self.tr
    }
}

/// The chosen allocation.
#[derive(Clone, Debug, PartialEq)]
pub struct HeteroPlan {
    /// Indices of the selected workers (into the input slice).
    pub workers: Vec<usize>,
    pub k: usize,
    /// Monte-Carlo estimate of the expected layer latency.
    pub expected_latency: f64,
}

/// MC estimate of `E[T^c]` for one layer over a concrete worker subset.
pub fn expected_latency_subset(
    dims: &LayerDims,
    profile: &SystemProfile,
    speeds: &[WorkerSpeed],
    subset: &[usize],
    k: usize,
    samples: usize,
    rng: &mut Rng,
) -> f64 {
    let n = subset.len();
    assert!(k >= 1 && k <= n);
    let rec = profile.rec_dist(dims, k);
    let cmp = profile.cmp_dist(dims, k);
    let sen = profile.sen_dist(dims, k);
    let enc = profile.enc_dist(dims, n, k);
    let dec = profile.dec_dist(dims, k);

    let mut worker = vec![0.0f64; n];
    let mut total = 0.0;
    for _ in 0..samples {
        for (slot, &w) in worker.iter_mut().zip(subset) {
            let s = speeds[w];
            *slot = rec.sample(rng) * s.tr + cmp.sample(rng) * s.cmp + sen.sample(rng) * s.tr;
        }
        worker.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        total += enc.sample(rng) + worker[k - 1] + dec.sample(rng);
    }
    total / samples as f64
}

/// Jointly choose the worker subset (fastest-first prefixes) and `k`.
pub fn optimize(
    dims: &LayerDims,
    profile: &SystemProfile,
    speeds: &[WorkerSpeed],
    samples: usize,
    rng: &mut Rng,
) -> HeteroPlan {
    assert!(!speeds.is_empty());
    // Fastest-first ordering.
    let mut order: Vec<usize> = (0..speeds.len()).collect();
    order.sort_by(|&a, &b| {
        speeds[a]
            .mean_cost()
            .partial_cmp(&speeds[b].mean_cost())
            .unwrap()
    });

    let mut best = HeteroPlan {
        workers: vec![order[0]],
        k: 1,
        expected_latency: f64::INFINITY,
    };
    for m in 1..=order.len() {
        let subset = &order[..m];
        let k_cap = m.min(dims.w_o);
        for k in 1..=k_cap {
            let est =
                expected_latency_subset(dims, profile, speeds, subset, k, samples, rng);
            if est < best.expected_latency {
                best = HeteroPlan {
                    workers: subset.to_vec(),
                    k,
                    expected_latency: est,
                };
            }
        }
    }
    best.workers.sort_unstable();
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvSpec;
    use crate::planner::montecarlo;

    fn dims() -> LayerDims {
        LayerDims::new(ConvSpec::new(64, 64, 3, 1, 1), 56, 56)
    }

    #[test]
    fn homogeneous_reduces_to_standard_k_star() {
        let d = dims();
        let p = SystemProfile::paper_default();
        let speeds = vec![WorkerSpeed::nominal(); 8];
        let mut rng = Rng::new(4);
        let plan = optimize(&d, &p, &speeds, 6_000, &mut rng);
        // All equal workers: use everyone; k matches the iid MC optimum ±1.
        assert_eq!(plan.workers.len(), 8);
        let (k_star, _) = montecarlo::optimal_k_star(&d, &p, 8, 12_000, &mut rng);
        assert!(
            (plan.k as isize - k_star as isize).abs() <= 1,
            "hetero k={} vs iid k*={k_star}",
            plan.k
        );
    }

    #[test]
    fn excludes_a_chronic_straggler_when_it_pays() {
        let d = dims();
        let p = SystemProfile::paper_default();
        // Worker 0 is 8x slower than the rest.
        let mut speeds = vec![WorkerSpeed::nominal(); 6];
        speeds[0] = WorkerSpeed::slow(8.0);
        let mut rng = Rng::new(5);
        let plan = optimize(&d, &p, &speeds, 6_000, &mut rng);
        assert!(
            !plan.workers.contains(&0),
            "the 8x straggler should be excluded: {plan:?}"
        );
        // And the chosen plan must beat naively using all 6 at any k.
        let all: Vec<usize> = (0..6).collect();
        let naive_best = (1..=6)
            .map(|k| expected_latency_subset(&d, &p, &speeds, &all, k, 6_000, &mut rng))
            .fold(f64::INFINITY, f64::min);
        assert!(
            plan.expected_latency <= naive_best * 1.02,
            "hetero plan {:.3}s vs naive-all best {naive_best:.3}s",
            plan.expected_latency
        );
    }

    #[test]
    fn mildly_slow_worker_is_kept_as_redundancy() {
        let d = dims();
        let p = SystemProfile::paper_default();
        // 1.3x slower is still useful redundancy under straggling.
        let mut speeds = vec![WorkerSpeed::nominal(); 6];
        speeds[5] = WorkerSpeed::slow(1.3);
        let mut rng = Rng::new(6);
        let plan = optimize(&d, &p, &speeds, 6_000, &mut rng);
        assert!(plan.workers.contains(&5), "mild slowdown should stay: {plan:?}");
    }
}
