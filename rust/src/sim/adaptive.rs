//! Drifting-capacity serving simulation: the deterministic validation
//! harness for the telemetry → estimation → replanning loop.
//!
//! A request stream runs over `n` workers whose capacities *drift*
//! mid-run ([`DriftScenario`]): workers slow down, die and return, or
//! the shared link congests. Two control policies are compared on
//! **common random numbers** — every trial draws the same per-worker
//! phase times for all `n` workers regardless of policy, so:
//!
//! * with no drift and no plan swap, the adaptive run's latency trace is
//!   *bitwise identical* to the static run's (hysteresis really did
//!   nothing), and
//! * under drift, the latency difference is attributable to the plan,
//!   not sampling noise.
//!
//! The static policy keeps the plan solved against the initial
//! calibrated profile. The adaptive policy feeds every subtask's timing
//! into a [`CapacityRegistry`] (execution normalized by FLOPs,
//! transmission by bytes — the same observables the real coordinator
//! records), quarantines/probes stragglers, and lets a [`Replanner`]
//! re-solve `(n, k)` between requests.

use anyhow::Result;

use crate::latency::SystemProfile;
use crate::model::{ModelPlan, ModelSpec};
use crate::planner::SplitPolicy;
use crate::telemetry::{
    CapacityRegistry, Replanner, ReplanConfig, TelemetryConfig, TelemetryEvent,
};
use crate::util::Rng;

/// Capacity drift applied to the worker pool mid-run. Request indices
/// are the time axis (the drift applies from request `at` onward).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftScenario {
    /// Stationary capacities (the hysteresis/no-thrash baseline).
    None,
    /// The first `m` workers run their *compute* `factor`× slower from
    /// request `at` (wall-time stretch: shift and tail both scale).
    /// `m = 1` is the paper-style chronic straggler appearing mid-run;
    /// `m = n` models a pool-wide slowdown (thermal throttle).
    ComputeSlowdown { m: usize, factor: f64, at: usize },
    /// Worker `worker` fails every subtask in requests `[down_at,
    /// up_at)` and then recovers — the quarantine/reintegration
    /// round-trip scenario.
    DieAndReturn {
        worker: usize,
        down_at: usize,
        up_at: usize,
    },
    /// The shared link congests: every worker's transmission *excess*
    /// (the exponential tail) grows `factor`× from request `at`. Heavy
    /// transmission straggling moves the optimal split k° down, so this
    /// is the scenario where replanning (not just quarantine) pays.
    TransmissionCongestion { factor: f64, at: usize },
    /// Membership churn: worker `leave` is evicted (link death) at
    /// request `leave_at`, and a brand-new worker — stable id `n`,
    /// beyond the initial pool — joins at request `join_at`. Unlike
    /// [`DriftScenario::DieAndReturn`] the departure is a *membership*
    /// transition (the pool shrinks; nothing is dispatched to the
    /// ghost), mirroring the coordinator's evict/admit paths.
    Churn {
        leave: usize,
        leave_at: usize,
        join_at: usize,
    },
}

impl DriftScenario {
    pub fn label(&self) -> String {
        match self {
            DriftScenario::None => "none".into(),
            DriftScenario::ComputeSlowdown { m, factor, at } => {
                format!("slowdown(m={m},x{factor},at={at})")
            }
            DriftScenario::DieAndReturn {
                worker,
                down_at,
                up_at,
            } => format!("die-return(w={worker},[{down_at},{up_at}))"),
            DriftScenario::TransmissionCongestion { factor, at } => {
                format!("congestion(x{factor},at={at})")
            }
            DriftScenario::Churn {
                leave,
                leave_at,
                join_at,
            } => format!("churn(leave={leave}@{leave_at},join@{join_at})"),
        }
    }

    /// Compute wall-time multiplier of `worker` at request `req`.
    pub fn cmp_slowdown(&self, worker: usize, req: usize) -> f64 {
        match self {
            DriftScenario::ComputeSlowdown { m, factor, at } if worker < *m && req >= *at => {
                *factor
            }
            _ => 1.0,
        }
    }

    /// Transmission-excess multiplier at request `req`.
    pub fn tr_excess(&self, req: usize) -> f64 {
        match self {
            DriftScenario::TransmissionCongestion { factor, at } if req >= *at => *factor,
            _ => 1.0,
        }
    }

    /// Is `worker` alive at request `req`?
    pub fn alive(&self, worker: usize, req: usize) -> bool {
        !matches!(
            self,
            DriftScenario::DieAndReturn { worker: w, down_at, up_at }
                if worker == *w && (*down_at..*up_at).contains(&req)
        )
    }

    /// Is `worker` a pool *member* at request `req`, given an initial
    /// pool of `n`? Beyond liveness: churn removes a member for good
    /// and admits a new one (stable id `n`); every other scenario keeps
    /// the initial `0..n` pool.
    pub fn present(&self, worker: usize, n: usize, req: usize) -> bool {
        match self {
            DriftScenario::Churn {
                leave,
                leave_at,
                join_at,
            } => {
                if worker == n {
                    req >= *join_at
                } else if worker == *leave {
                    req < *leave_at
                } else {
                    worker < n
                }
            }
            _ => worker < n,
        }
    }

    /// Workers the trial draws phase times for: churn trials always
    /// draw for the joiner too (ids `0..n+1`), so the static and
    /// adaptive policies consume the RNG identically whatever the
    /// membership at each request — the common-random-numbers contract.
    pub fn draw_pool(&self, n: usize) -> usize {
        match self {
            DriftScenario::Churn { .. } => n + 1,
            _ => n,
        }
    }
}

/// Result of one policy's run over the request stream.
#[derive(Clone, Debug)]
pub struct AdaptiveSimResult {
    /// End-to-end latency per request (seconds).
    pub latencies: Vec<f64>,
    /// Plan swaps performed (0 for the static policy).
    pub switches: u64,
    /// Quarantine/reintegration log (empty for the static policy).
    pub events: Vec<TelemetryEvent>,
    /// Final per-distributed-layer k.
    pub final_ks: Vec<(String, usize)>,
    /// The registry after the run (adaptive policy; fresh for static).
    pub registry: CapacityRegistry,
}

impl AdaptiveSimResult {
    pub fn mean(&self) -> f64 {
        self.latencies.iter().sum::<f64>() / self.latencies.len().max(1) as f64
    }

    /// Mean over requests `from..` (post-drift window).
    pub fn mean_from(&self, from: usize) -> f64 {
        let tail = &self.latencies[from.min(self.latencies.len())..];
        tail.iter().sum::<f64>() / tail.len().max(1) as f64
    }
}

/// Serve `n_requests` inferences of `model` over `n` workers whose
/// capacities follow `drift`, under the static or adaptive policy.
/// `replan_every` is in requests; phase times are drawn from `profile`
/// (the true *initial* capacities) modulated by the drift.
#[allow(clippy::too_many_arguments)]
pub fn simulate_adaptive(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    drift: DriftScenario,
    n_requests: usize,
    adaptive: bool,
    replan_every: usize,
    rng: &mut Rng,
) -> Result<AdaptiveSimResult> {
    anyhow::ensure!(n >= 2 && n_requests >= 1 && replan_every >= 1);
    let mut plan = ModelPlan::build(model, profile, n, SplitPolicy::KCircle, rng)?;
    let layers: Vec<(String, crate::latency::LayerDims)> = plan
        .convs
        .iter()
        .filter(|c| c.distributed)
        .map(|c| (c.node_id.clone(), c.dims))
        .collect();
    // Master-local (type-2) work at its mean, identical for both policies.
    let local_mean: f64 = plan
        .convs
        .iter()
        .filter(|c| !c.distributed)
        .map(|c| profile.local_conv_dist(c.dims.full_flops()).mean())
        .sum();

    let mut registry = CapacityRegistry::new(n, TelemetryConfig::default());
    let mut replanner = Replanner::new(ReplanConfig::default());
    let mut round: u64 = 0;
    let mut latencies = Vec::with_capacity(n_requests);

    let draw_pool = drift.draw_pool(n);
    for req in 0..n_requests {
        // Membership transitions feed the registry exactly like the
        // coordinator's evict/admit paths (the static policy tracks
        // membership through `present` alone).
        if adaptive {
            if let DriftScenario::Churn {
                leave,
                leave_at,
                join_at,
            } = drift
            {
                if req == leave_at {
                    registry.evict(leave);
                }
                if req == join_at {
                    registry.admit(n);
                }
            }
        }
        let mut total = local_mean;
        for (node_id, dims) in &layers {
            round += 1;
            let k = plan
                .conv(node_id)
                .map(|c| c.k)
                .unwrap_or(1)
                .clamp(1, n.min(dims.w_o));
            // Dispatch set: the registry's active workers (probes
            // included) under the adaptive policy, everyone otherwise.
            let targets = if adaptive {
                registry.active_workers(round)
            } else {
                (0..draw_pool)
                    .filter(|&w| drift.present(w, n, req))
                    .collect::<Vec<usize>>()
            };
            let n_tasks = targets.len();
            // Keep one parity shard when quarantine shrank the dispatch
            // set (mirrors the coordinator's adaptive clamp): MDS(n, n)
            // would have zero redundancy exactly when workers misbehave.
            let k = if adaptive && n_tasks > 1 {
                k.min(n_tasks - 1)
            } else {
                k.min(n_tasks)
            };

            let enc = profile.enc_dist(dims, n_tasks, k).sample(rng);
            let dec = profile.dec_dist(dims, k).sample(rng);
            let rec = profile.rec_dist(dims, k);
            let cmp = profile.cmp_dist(dims, k);
            let sen = profile.sen_dist(dims, k);
            let mean_sub = rec.mean() + cmp.mean() + sen.mean();
            let flops = dims.n_cmp(k as f64);
            let bytes = dims.n_rec(k as f64) + dims.n_sen(k as f64);

            // Common random numbers: draw all n workers' phase times in a
            // fixed order, whatever the dispatch set — both policies then
            // consume the RNG identically, and a no-swap adaptive run is
            // bitwise identical to the static one.
            let mut arrivals: Vec<(f64, usize, f64, f64)> = Vec::with_capacity(n_tasks);
            let mut failed: Vec<usize> = Vec::new();
            for w in 0..draw_pool {
                let t_rec = rec.shift()
                    + rng.exponential(rec.mu / rec.n_scale) * drift.tr_excess(req);
                let t_cmp = cmp.sample(rng) * drift.cmp_slowdown(w, req);
                let t_sen = sen.shift()
                    + rng.exponential(sen.mu / sen.n_scale) * drift.tr_excess(req);
                if !targets.contains(&w) {
                    continue; // drawn for RNG parity, not dispatched
                }
                if !drift.alive(w, req) {
                    failed.push(w);
                    continue;
                }
                let t = t_rec + t_cmp + t_sen + 2.0 * profile.theta_msg;
                arrivals.push((t, w, t_cmp, t_rec + t_sen));
            }
            arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

            let workers_t = if arrivals.len() >= k {
                arrivals[k - 1].0
            } else {
                // Not enough survivors: the master times out (1.5x the
                // expected subtask, the §III detection threshold) and
                // re-executes the missing pieces serially on a survivor.
                // Deterministic penalty — no extra RNG draws, so both
                // policies stay on common random numbers.
                1.5 * mean_sub + (k - arrivals.len()) as f64 * mean_sub
            };
            total += enc + workers_t + dec;

            if adaptive {
                for &(_, w, t_cmp, t_tr) in &arrivals {
                    registry.record_success(w, flops, bytes, t_cmp, t_tr, round);
                }
                for &w in &failed {
                    registry.record_failure(w, round);
                }
            }
        }
        latencies.push(total);

        if adaptive && (req + 1) % replan_every == 0 {
            replanner.replan(&mut plan, &registry, profile, round);
        }
    }

    Ok(AdaptiveSimResult {
        latencies,
        switches: replanner.switches,
        events: registry.events().to_vec(),
        final_ks: plan
            .convs
            .iter()
            .filter(|c| c.distributed)
            .map(|c| (c.node_id.clone(), c.k))
            .collect(),
        registry,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn run(drift: DriftScenario, n_req: usize, adaptive: bool, seed: u64) -> AdaptiveSimResult {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(seed);
        simulate_adaptive(&model, &p, 10, drift, n_req, adaptive, 4, &mut rng).unwrap()
    }

    #[test]
    fn finite_and_deterministic() {
        let a = run(DriftScenario::None, 6, true, 3);
        let b = run(DriftScenario::None, 6, true, 3);
        assert_eq!(a.latencies, b.latencies);
        assert!(a.latencies.iter().all(|t| t.is_finite() && *t > 0.0));
        assert_eq!(a.latencies.len(), 6);
    }

    #[test]
    fn drift_labels_and_predicates() {
        let d = DriftScenario::ComputeSlowdown { m: 2, factor: 3.0, at: 5 };
        assert_eq!(d.cmp_slowdown(1, 4), 1.0);
        assert_eq!(d.cmp_slowdown(1, 5), 3.0);
        assert_eq!(d.cmp_slowdown(2, 9), 1.0);
        let d = DriftScenario::DieAndReturn { worker: 3, down_at: 2, up_at: 4 };
        assert!(d.alive(3, 1) && !d.alive(3, 2) && !d.alive(3, 3) && d.alive(3, 4));
        assert!(d.alive(0, 3));
        let d = DriftScenario::TransmissionCongestion { factor: 8.0, at: 1 };
        assert_eq!(d.tr_excess(0), 1.0);
        assert_eq!(d.tr_excess(1), 8.0);
        assert!(DriftScenario::None.label() == "none");
    }

    /// Churn is a *membership* transition: the leaver disappears from
    /// the pool, the joiner (stable id n) appears, the registry logs
    /// Evicted/Joined, and the run stays deterministic (CRN holds with
    /// the n+1 draw pool).
    #[test]
    fn churn_swaps_membership_and_stays_deterministic() {
        use crate::telemetry::EventKind;
        let drift = DriftScenario::Churn {
            leave: 0,
            leave_at: 3,
            join_at: 5,
        };
        assert!(drift.present(0, 10, 2) && !drift.present(0, 10, 3));
        assert!(!drift.present(10, 10, 4) && drift.present(10, 10, 5));
        assert!(drift.present(4, 10, 9));
        assert_eq!(drift.draw_pool(10), 11);

        let a = run(drift, 8, true, 7);
        let b = run(drift, 8, true, 7);
        assert_eq!(a.latencies, b.latencies);
        assert!(a.latencies.iter().all(|t| t.is_finite() && *t > 0.0));
        assert!(a
            .events
            .iter()
            .any(|e| e.kind == EventKind::Evicted && e.worker == 0));
        assert!(a
            .events
            .iter()
            .any(|e| e.kind == EventKind::Joined && e.worker == 10));
        assert!(!a.registry.contains(0) && a.registry.contains(10));
        // The joiner actually accumulated samples after admission.
        assert!(a.registry.samples_of(10) > 0);

        // The static policy survives the same churn (membership via the
        // `present` predicate alone).
        let s = run(drift, 8, false, 7);
        assert!(s.latencies.iter().all(|t| t.is_finite() && *t > 0.0));
        assert_eq!(s.switches, 0);
    }
}
