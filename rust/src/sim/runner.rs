//! Figure-scale simulation of one CNN inference under each §V method.
//!
//! Every latency is drawn from the calibrated shift-exponential phase
//! model (eqs. 7–12); scenario effects (extra transmission delay,
//! failures + re-dispatch, chronic straggler) are applied with the same
//! semantics as the real coordinator's fault injectors.

use std::collections::HashMap;

use anyhow::Result;

use crate::coding::lt::LtCode;
use crate::coding::RedundancyScheme;
use crate::latency::approx::l_integer;
use crate::latency::phases::LayerDims;
use crate::latency::SystemProfile;
use crate::model::{ModelPlan, ModelSpec};
use crate::planner::{solve_k_circ, SplitPolicy};
use crate::util::Rng;

/// Methods of the §V comparison.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodSim {
    /// CoCoI-k*: per-layer Monte-Carlo optimum.
    CocoiKStar { samples: usize },
    /// CoCoI-k°: approximate convex optimum.
    CocoiKCirc,
    Uncoded,
    Replication,
    /// LtCoI-k_l (k = W_O, finest split).
    LtFine,
    /// LtCoI-k_s (planner k ≤ n).
    LtCoarse,
    /// The live `--scheme auto` selector: per-layer k° MDS while the
    /// pool is calm, rateless LT under worker churn — the sim mirror of
    /// `SchemeSelector::refine`, which flips a layer to LT when recent
    /// membership events make fixed-rate rounds pay timeout +
    /// re-dispatch. Calm draws are bitwise those of [`Self::CocoiKCirc`]
    /// (same rng stream); failure-scenario draws are bitwise those of
    /// [`Self::LtCoarse`].
    AutoSelect,
}

impl MethodSim {
    pub fn label(&self) -> &'static str {
        match self {
            MethodSim::CocoiKStar { .. } => "cocoi-k*",
            MethodSim::CocoiKCirc => "cocoi-k0",
            MethodSim::Uncoded => "uncoded",
            MethodSim::Replication => "replication",
            MethodSim::LtFine => "ltcoi-kl",
            MethodSim::LtCoarse => "ltcoi-ks",
            MethodSim::AutoSelect => "cocoi-auto",
        }
    }
}

/// Per-layer mean breakdown (Fig. 4's stacks).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerBreakdown {
    pub enc: f64,
    pub workers: f64,
    pub dec: f64,
}

/// Result of simulating a model under one method/scenario.
#[derive(Clone, Debug)]
pub struct ModelSimResult {
    pub method: String,
    pub scenario: String,
    /// End-to-end inference latency per trial (seconds).
    pub trials: Vec<f64>,
    /// Mean per-type-1-layer breakdown, in layer order.
    pub per_layer: Vec<(String, LayerBreakdown)>,
    /// Chosen k per type-1 layer.
    pub k_per_layer: Vec<(String, usize)>,
}

/// The shared percentile helper behind every serving table: tail
/// latency is the thing coded redundancy buys, so results report
/// p50/p95/p99 next to mean/std instead of hiding the tail in a mean.
/// Delegates to [`crate::util::stats::percentile`] — one interpolation
/// convention for the sim tables and the `Summary`-based reports alike.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    crate::util::stats::percentile(xs, q)
}

impl ModelSimResult {
    pub fn mean(&self) -> f64 {
        self.trials.iter().sum::<f64>() / self.trials.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        (self.trials.iter().map(|t| (t - m).powi(2)).sum::<f64>()
            / self.trials.len().max(1) as f64)
            .sqrt()
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.trials, 0.50)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.trials, 0.95)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.trials, 0.99)
    }
}

use super::scenario::Scenario;

/// Empirical LT decode-overhead sampler: how many received symbols until
/// rank k. Cached per k (rank tracking over random Soliton vectors).
pub struct LtOverheadCache {
    samples: HashMap<usize, Vec<usize>>,
}

impl LtOverheadCache {
    pub fn new() -> LtOverheadCache {
        LtOverheadCache {
            samples: HashMap::new(),
        }
    }

    pub fn sample(&mut self, k: usize, rng: &mut Rng) -> usize {
        let samples = self.samples.entry(k).or_insert_with(|| {
            let mut rng = Rng::new(0x17C0DE ^ k as u64);
            let trials = if k > 64 { 12 } else { 32 };
            (0..trials)
                .map(|t| {
                    let code = LtCode::new(1, k, 0xBEEF + t as u64);
                    let mut dec = code.decoder();
                    let mut used = 0;
                    // Feed vectors only (payload content irrelevant for rank).
                    for id in 0..code.num_subtasks() * 4 {
                        used += 1;
                        if crate::coding::Decoder::add(&mut *dec, id, vec![0.0]) {
                            break;
                        }
                    }
                    let _ = &mut rng;
                    used
                })
                .collect()
        });
        samples[rng.below(samples.len())]
    }
}

impl Default for LtOverheadCache {
    fn default() -> Self {
        Self::new()
    }
}

/// One trial of one distributed layer under an MDS-semantics scheme
/// (mds / uncoded / replication). Returns (enc, workers, dec) seconds.
/// `hedge` mirrors the engine's watchdog: `Some(q)` gives any subtask
/// whose completion exceeds the q-quantile of its nominal phase model a
/// speculative backup draw on a random surviving worker, started at the
/// threshold — first copy wins. `None` consumes no extra rng draws, so
/// unhedged traces stay bitwise-pinned.
#[allow(clippy::too_many_arguments)]
fn trial_mds_like(
    dims: &LayerDims,
    p: &SystemProfile,
    n: usize,
    k: usize,
    needed: Needed,
    coded: bool,
    scenario: &Scenario,
    hedge: Option<f64>,
    rng: &mut Rng,
) -> (f64, f64, f64) {
    let rec = p.rec_dist(dims, k);
    let cmp = p.cmp_dist(dims, k);
    let sen = p.sen_dist(dims, k);
    let extra_mean = scenario.lambda_tr() * (rec.mean() + sen.mean());
    let failed = scenario.draw_failures(n, rng);

    // Nominal per-worker completion times (task i on worker i).
    let mut arrivals: Vec<Option<f64>> = Vec::with_capacity(n);
    let mut own_finish: Vec<f64> = Vec::with_capacity(n);
    let mut signals: Vec<(usize, f64)> = Vec::new(); // (task, failure signal time)
    for i in 0..n {
        let slow = scenario.cmp_slowdown(i);
        let t_rec = rec.sample(rng);
        let t_cmp = cmp.sample(rng) * slow;
        let t_sen = sen.sample(rng);
        let extra = if extra_mean > 0.0 {
            rng.exponential(1.0 / extra_mean)
        } else {
            0.0
        };
        if failed.contains(&i) {
            // Failure detected by the master's timeout threshold (§III:
            // "longer than a pre-defined timeout ⇒ failed"): 1.5× the
            // expected subtask completion time.
            let timeout = 1.5 * (rec.mean() + cmp.mean() + sen.mean());
            signals.push((i, timeout));
            arrivals.push(None);
            own_finish.push(0.0); // failed host does no useful work
        } else {
            let t = t_rec + t_cmp + t_sen + extra + 2.0 * p.theta_msg;
            arrivals.push(Some(t));
            own_finish.push(t);
        }
    }

    // Re-dispatch failed pieces when redundancy cannot absorb them.
    let alive: Vec<usize> = (0..n).filter(|i| !failed.contains(i)).collect();
    let must_redispatch = |task: usize, arrivals: &[Option<f64>]| -> bool {
        match needed {
            Needed::All => true,
            // Enough surviving arrivals already?
            Needed::KOfN(kk) => arrivals.iter().flatten().count() < kk,
            Needed::PerSource(src_k) => {
                // Replication: does a sibling replica survive?
                let src = task % src_k;
                !(0..n).any(|t| t != task && t % src_k == src && arrivals[t].is_some())
            }
        }
    };
    for (task, signal) in signals {
        if alive.is_empty() || !must_redispatch(task, &arrivals) {
            continue;
        }
        let host = alive[rng.below(alive.len())];
        let slow = scenario.cmp_slowdown(host);
        let t = own_finish[host].max(signal)
            + rec.sample(rng)
            + cmp.sample(rng) * slow
            + sen.sample(rng)
            + 2.0 * p.theta_msg;
        arrivals[task] = Some(t);
        own_finish[host] = t;
    }

    // Watchdog hedging: a subtask past its fitted completion quantile
    // races a backup copy dispatched at the threshold; the earlier of
    // the two arrivals wins (exactly-one-result semantics — the loser
    // is cancelled, so it costs pool occupancy, not correctness).
    if let Some(q) = hedge.filter(|q| *q > 0.0 && *q < 1.0) {
        if !alive.is_empty() {
            let tau =
                rec.quantile(q) + cmp.quantile(q) + sen.quantile(q) + 2.0 * p.theta_msg;
            for a in arrivals.iter_mut() {
                if let Some(t) = *a {
                    if t > tau {
                        let host = alive[rng.below(alive.len())];
                        let slow = scenario.cmp_slowdown(host);
                        let backup = tau
                            + rec.sample(rng)
                            + cmp.sample(rng) * slow
                            + sen.sample(rng)
                            + 2.0 * p.theta_msg;
                        *a = Some(t.min(backup));
                    }
                }
            }
        }
    }

    let mut done: Vec<f64> = arrivals.iter().flatten().copied().collect();
    done.sort_unstable_by(f64::total_cmp);
    let workers = match needed {
        Needed::All => done.last().copied().unwrap_or(f64::INFINITY),
        Needed::KOfN(kk) => done.get(kk - 1).copied().unwrap_or(f64::INFINITY),
        Needed::PerSource(src_k) => {
            // Max over sources of min over that source's replicas.
            let mut per_src = vec![f64::INFINITY; src_k];
            for (t, a) in arrivals.iter().enumerate() {
                if let Some(v) = a {
                    let s = t % src_k;
                    per_src[s] = per_src[s].min(*v);
                }
            }
            per_src.iter().cloned().fold(0.0, f64::max)
        }
    };

    let (enc, dec) = if coded {
        (
            p.enc_dist(dims, n, k).sample(rng),
            p.dec_dist(dims, k).sample(rng),
        )
    } else {
        (0.0, 0.0)
    };
    (enc, workers, dec)
}

enum Needed {
    All,
    KOfN(usize),
    PerSource(usize),
}

/// One trial of one layer under LT coding.
fn trial_lt(
    dims: &LayerDims,
    p: &SystemProfile,
    n: usize,
    k_lt: usize,
    budget: usize,
    lt_cache: &mut LtOverheadCache,
    scenario: &Scenario,
    rng: &mut Rng,
) -> (f64, f64, f64) {
    // Per-symbol phase scales: a k_lt-way piece.
    let rec = p.rec_dist(dims, k_lt);
    let cmp = p.cmp_dist(dims, k_lt);
    let sen = p.sen_dist(dims, k_lt);
    let extra_mean = scenario.lambda_tr() * (rec.mean() + sen.mean());
    let failed = scenario.draw_failures(n, rng);

    // Each worker sequentially processes its round-robin share of symbols.
    let mut arrivals: Vec<f64> = Vec::with_capacity(budget);
    for w in 0..n {
        if failed.contains(&w) {
            continue;
        }
        let slow = scenario.cmp_slowdown(w);
        let mut t = 0.0;
        let mut sym = w;
        while sym < budget {
            let extra = if extra_mean > 0.0 {
                rng.exponential(1.0 / extra_mean)
            } else {
                0.0
            };
            t += rec.sample(rng)
                + cmp.sample(rng) * slow
                + sen.sample(rng)
                + extra
                + 2.0 * p.theta_msg;
            arrivals.push(t);
            sym += n;
        }
    }
    arrivals.sort_unstable_by(f64::total_cmp);
    let needed = lt_cache.sample(k_lt, rng);
    let workers = arrivals
        .get(needed.saturating_sub(1))
        .copied()
        .unwrap_or_else(|| arrivals.last().copied().unwrap_or(f64::INFINITY) * 1.5);

    // LT encode: additions only (mean degree × budget × m); decode ~ 2k²m.
    let mean_degree: f64 = crate::coding::lt::robust_soliton(k_lt)
        .iter()
        .enumerate()
        .map(|(i, p)| (i + 1) as f64 * p)
        .sum();
    let enc_flops = mean_degree * budget as f64 * dims.n_rec(k_lt as f64) / 4.0;
    let dec_flops = dims.n_dec(k_lt as f64);
    let enc = p.master_dist(enc_flops).sample(rng);
    let dec = p.master_dist(dec_flops).sample(rng);
    (enc, workers, dec)
}

/// One layer draw under `method`: (enc, workers, dec) seconds. `hedge`
/// enables the watchdog-backup model for the MDS-semantics schemes (LT's
/// rateless stream hedges by construction — extra symbols — so the knob
/// is a no-op there).
#[allow(clippy::too_many_arguments)]
fn draw_layer(
    method: MethodSim,
    dims: &LayerDims,
    k: usize,
    profile: &SystemProfile,
    n: usize,
    scenario: &Scenario,
    hedge: Option<f64>,
    lt_cache: &mut LtOverheadCache,
    rng: &mut Rng,
) -> (f64, f64, f64) {
    match method {
        MethodSim::CocoiKStar { .. } | MethodSim::CocoiKCirc => {
            trial_mds_like(dims, profile, n, k, Needed::KOfN(k), true, scenario, hedge, rng)
        }
        MethodSim::Uncoded => {
            trial_mds_like(dims, profile, n, k, Needed::All, false, scenario, hedge, rng)
        }
        MethodSim::Replication => {
            trial_mds_like(dims, profile, n, k, Needed::PerSource(k), false, scenario, hedge, rng)
        }
        MethodSim::LtFine | MethodSim::LtCoarse => {
            let budget = 2 * k + 16;
            trial_lt(dims, profile, n, k, budget, lt_cache, scenario, rng)
        }
        MethodSim::AutoSelect => match scenario {
            // Churn (failure scenarios): the selector flips the layer to
            // rateless LT — lost symbols are just lost, no timeout wait
            // or re-dispatch round trip.
            Scenario::Failures { .. } | Scenario::FailuresPlusStraggler { .. } => {
                let budget = 2 * k + 16;
                trial_lt(dims, profile, n, k, budget, lt_cache, scenario, rng)
            }
            // Calm pool: k° MDS, identical draws to CocoiKCirc.
            _ => trial_mds_like(
                dims,
                profile,
                n,
                k,
                Needed::KOfN(k),
                true,
                scenario,
                hedge,
                rng,
            ),
        },
    }
}

/// Per-layer `k` choice + the (method-independent) master-local mean for
/// the type-2 layers. Shared by the single-inference and serving sims.
fn plan_layers(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    method: MethodSim,
    scenario: &Scenario,
    rng: &mut Rng,
) -> Result<(Vec<(String, LayerDims, usize)>, f64)> {
    // Type-1 classification is shared across methods (App. A): use the
    // default plan.
    let plan = ModelPlan::build(model, profile, n, SplitPolicy::KCircle, rng)?;

    // Per-layer k choice for this method.
    let mut layer_cfg: Vec<(String, LayerDims, usize)> = Vec::new();
    for c in &plan.convs {
        if !c.distributed {
            continue;
        }
        let k = match method {
            MethodSim::CocoiKCirc => solve_k_circ(&c.dims, profile, n).k,
            // The paper's CoCoI-k*: "obtained by testing all feasible k's
            // and choosing the best one" — i.e. measured under the active
            // scenario, so k* bakes in failure resilience.
            MethodSim::CocoiKStar { samples } => {
                let probes = (samples / 500).clamp(8, 64);
                let mut best = (f64::INFINITY, 1usize);
                for k in 1..=n.min(c.dims.w_o) {
                    let mean: f64 = (0..probes)
                        .map(|_| {
                            let (e, w, d) = trial_mds_like(
                                &c.dims,
                                profile,
                                n,
                                k,
                                Needed::KOfN(k),
                                true,
                                scenario,
                                None,
                                rng,
                            );
                            e + w + d
                        })
                        .sum::<f64>()
                        / probes as f64;
                    if mean < best.0 {
                        best = (mean, k);
                    }
                }
                best.1
            }
            MethodSim::Uncoded => n.min(c.dims.w_o),
            MethodSim::Replication => (n / 2).max(1).min(c.dims.w_o),
            MethodSim::LtFine => c.dims.w_o,
            MethodSim::LtCoarse | MethodSim::AutoSelect => solve_k_circ(&c.dims, profile, n).k,
        };
        layer_cfg.push((c.node_id.clone(), c.dims, k));
    }

    // Master-local (type-2) work: mean latency, same for all methods.
    let local_mean: f64 = plan
        .convs
        .iter()
        .filter(|c| !c.distributed)
        .map(|c| profile.local_conv_dist(c.dims.full_flops()).mean())
        .sum();
    Ok((layer_cfg, local_mean))
}

/// Simulate `trials` inferences of `model` under one method + scenario.
pub fn simulate_model(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    method: MethodSim,
    scenario: Scenario,
    trials: usize,
    rng: &mut Rng,
) -> Result<ModelSimResult> {
    let (layer_cfg, local_mean) = plan_layers(model, profile, n, method, &scenario, rng)?;
    let mut lt_cache = LtOverheadCache::new();

    let mut trials_out = Vec::with_capacity(trials);
    let mut sums: Vec<LayerBreakdown> = vec![LayerBreakdown::default(); layer_cfg.len()];
    for _ in 0..trials {
        let mut total = local_mean;
        for (li, (_, dims, k)) in layer_cfg.iter().enumerate() {
            let (enc, workers, dec) =
                draw_layer(method, dims, *k, profile, n, &scenario, None, &mut lt_cache, rng);
            sums[li].enc += enc;
            sums[li].workers += workers;
            sums[li].dec += dec;
            total += enc + workers + dec;
        }
        trials_out.push(total);
    }

    let tf = trials.max(1) as f64;
    Ok(ModelSimResult {
        method: method.label().to_string(),
        scenario: scenario.label(),
        trials: trials_out,
        per_layer: layer_cfg
            .iter()
            .zip(&sums)
            .map(|((id, _, _), s)| {
                (
                    id.clone(),
                    LayerBreakdown {
                        enc: s.enc / tf,
                        workers: s.workers / tf,
                        dec: s.dec / tf,
                    },
                )
            })
            .collect(),
        k_per_layer: layer_cfg.iter().map(|(id, _, k)| (id.clone(), *k)).collect(),
    })
}

/// Earliest-ready-first list schedule over two single-server resources:
/// the master (encode/decode/type-2 work) and the worker pool (a coded
/// round spreads its shards over *all* n workers, so concurrent rounds
/// contend for the pool rather than overlapping freely). The pipelined
/// gain is therefore hiding master work behind other requests' pool
/// phases — exactly what the real engine does — not fictitious extra
/// worker capacity. `ops[r]` = chain of `(master_seconds, pool_seconds)`
/// pairs executed strictly in order within a request. Returns the
/// makespan.
fn schedule_master_pool(ops: &[Vec<(f64, f64)>]) -> f64 {
    let mut ready = vec![0.0f64; ops.len()];
    let mut idx = vec![0usize; ops.len()];
    let mut phase = vec![0u8; ops.len()]; // 0 = master op next, 1 = pool op next
    let mut master_free = 0.0f64;
    let mut pool_free = 0.0f64;
    let mut makespan = 0.0f64;
    loop {
        let mut pick: Option<usize> = None;
        for r in 0..ops.len() {
            if idx[r] < ops[r].len() && pick.map_or(true, |p| ready[r] < ready[p]) {
                pick = Some(r);
            }
        }
        let Some(r) = pick else { break };
        let (m, w) = ops[r][idx[r]];
        if phase[r] == 0 {
            let end = master_free.max(ready[r]) + m;
            master_free = end;
            ready[r] = end;
            phase[r] = 1;
            makespan = makespan.max(end);
        } else {
            if w > 0.0 {
                let end = pool_free.max(ready[r]) + w;
                pool_free = end;
                ready[r] = end;
                makespan = makespan.max(end);
            }
            phase[r] = 0;
            idx[r] += 1;
        }
    }
    makespan
}

/// Serving-scale simulation: `n_requests` concurrent inferences of one
/// model under a method + scenario, served either by the round-barrier
/// engine (strictly sequential: the master idles through every worker
/// phase) or by the pipelined engine (master encode/decode overlaps other
/// requests' worker phases). `trials` makespans are returned; phase times
/// are drawn exactly like [`simulate_model`], so a fixed seed gives a
/// bitwise-reproducible trace.
#[allow(clippy::too_many_arguments)]
pub fn simulate_serving(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    method: MethodSim,
    scenario: Scenario,
    n_requests: usize,
    pipelined: bool,
    trials: usize,
    rng: &mut Rng,
) -> Result<ModelSimResult> {
    anyhow::ensure!(n_requests >= 1, "need at least one request");
    let (layer_cfg, local_mean) = plan_layers(model, profile, n, method, &scenario, rng)?;
    let mut lt_cache = LtOverheadCache::new();

    let mut trials_out = Vec::with_capacity(trials);
    let mut sums: Vec<LayerBreakdown> = vec![LayerBreakdown::default(); layer_cfg.len()];
    for _ in 0..trials {
        // Draw every request's phase times first, in a fixed order, so
        // the trace does not depend on the scheduling policy.
        let draws: Vec<Vec<(f64, f64, f64)>> = (0..n_requests)
            .map(|_| {
                layer_cfg
                    .iter()
                    .map(|(_, dims, k)| {
                        draw_layer(
                            method, dims, *k, profile, n, &scenario, None, &mut lt_cache, rng,
                        )
                    })
                    .collect()
            })
            .collect();
        for layers in &draws {
            for (li, (e, w, d)) in layers.iter().enumerate() {
                sums[li].enc += e;
                sums[li].workers += w;
                sums[li].dec += d;
            }
        }
        let makespan = if pipelined {
            // Chain per request: [local + enc_0] ~workers_0~ [dec_0 +
            // enc_1] ~workers_1~ ... [dec_last].
            let ops: Vec<Vec<(f64, f64)>> = draws
                .iter()
                .map(|layers| {
                    let l = layers.len();
                    let mut chain = Vec::with_capacity(l + 1);
                    for i in 0..l {
                        let m = if i == 0 {
                            local_mean + layers[0].0
                        } else {
                            layers[i - 1].2 + layers[i].0
                        };
                        chain.push((m, layers[i].1));
                    }
                    chain.push((if l == 0 { local_mean } else { layers[l - 1].2 }, 0.0));
                    chain
                })
                .collect();
            schedule_master_pool(&ops)
        } else {
            // Round barrier: nothing overlaps; the makespan is the sum.
            local_mean * n_requests as f64
                + draws
                    .iter()
                    .flat_map(|layers| layers.iter())
                    .map(|(e, w, d)| e + w + d)
                    .sum::<f64>()
        };
        trials_out.push(makespan);
    }

    let tf = (trials.max(1) * n_requests) as f64;
    Ok(ModelSimResult {
        method: format!(
            "{}+{}",
            method.label(),
            if pipelined { "pipelined" } else { "barrier" }
        ),
        scenario: scenario.label(),
        trials: trials_out,
        per_layer: layer_cfg
            .iter()
            .zip(&sums)
            .map(|((id, _, _), s)| {
                (
                    id.clone(),
                    LayerBreakdown {
                        enc: s.enc / tf,
                        workers: s.workers / tf,
                        dec: s.dec / tf,
                    },
                )
            })
            .collect(),
        k_per_layer: layer_cfg.iter().map(|(id, _, k)| (id.clone(), *k)).collect(),
    })
}

// ====================================================================
// Open-loop serving: Poisson arrivals, per-request latency, shedding.
// ====================================================================

/// Serving modes for [`simulate_serving_open`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeSimMode {
    /// Round-barrier master: one request at a time, nothing overlaps.
    Barrier,
    /// Pipelined engine: master work overlaps other requests' pool
    /// phases (two-resource schedule).
    Pipelined,
    /// Pipelined + telemetry-fitted replanning: the per-layer `k` is
    /// re-solved on the scenario's effective (drifted) profile — the
    /// profile a converged registry fit would report — and deadline
    /// shedding predicts from it instead of the stale base profile.
    PipelinedAdaptive,
}

impl ServeSimMode {
    pub fn label(&self) -> &'static str {
        match self {
            ServeSimMode::Barrier => "barrier",
            ServeSimMode::Pipelined => "pipelined",
            ServeSimMode::PipelinedAdaptive => "pipelined+adaptive",
        }
    }
}

/// Result of one open-loop serving simulation.
#[derive(Clone, Debug)]
pub struct ServingSimResult {
    pub mode: &'static str,
    pub scenario: String,
    /// Offered arrival rate (requests/second).
    pub rate: f64,
    /// Sojourn time (arrival → completion) of every *served* request.
    pub latencies: Vec<f64>,
    /// Requests shed at dispatch (deadline unmeetable).
    pub shed: usize,
    pub arrivals: usize,
}

impl ServingSimResult {
    pub fn mean(&self) -> f64 {
        self.latencies.iter().sum::<f64>() / self.latencies.len().max(1) as f64
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.latencies, 0.50)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.latencies, 0.95)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.latencies, 0.99)
    }

    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.arrivals.max(1) as f64
    }
}

/// Fold scenario-1's extra `Exp(λ_tr · T̄_tr)` transmission delay into
/// the profile's transmission rates: each phase's exponential part grows
/// by `λ_tr (θ + 1/μ)`, i.e. `1/μ' = 1/μ + λ_tr (θ + 1/μ)`. This is the
/// effective profile a converged telemetry fit observes under the
/// scenario (the sim mirror of `CapacityRegistry::fitted_profile`).
pub fn straggling_profile(base: &SystemProfile, lambda_tr: f64) -> SystemProfile {
    if lambda_tr <= 0.0 {
        return *base;
    }
    let fold = |mu: f64, theta: f64| 1.0 / (1.0 / mu + lambda_tr * (theta + 1.0 / mu));
    let mut p = *base;
    p.mu_rec = fold(base.mu_rec, base.theta_rec);
    p.mu_sen = fold(base.mu_sen, base.theta_sen);
    p
}

/// Open-loop generalization of [`schedule_master_pool`]: per-request
/// release (arrival) times, per-request completion times, and a shed
/// hook consulted when a request's *first* op would start (a shed
/// request consumes no resources). Among all schedulable next-ops it
/// runs the one with the earliest feasible start — ties to the earliest
/// request — which keeps service arrival-FIFO under equal readiness and
/// (validated in the serving experiment's gate) never loses to the
/// serialized barrier on tail latency. Returns `None` for shed requests.
fn schedule_master_pool_open(
    ops: &[Vec<(f64, f64)>],
    release: &[f64],
    shed_if: impl Fn(usize, f64) -> bool,
) -> Vec<Option<f64>> {
    let n_req = ops.len();
    let mut ready: Vec<f64> = release.to_vec();
    let mut idx = vec![0usize; n_req];
    let mut phase = vec![0u8; n_req]; // 0 = master op next, 1 = pool op next
    let mut master_free = 0.0f64;
    let mut pool_free = 0.0f64;
    let mut done: Vec<Option<f64>> = vec![None; n_req];
    loop {
        let mut pick: Option<(f64, usize)> = None;
        for r in 0..n_req {
            if idx[r] >= ops[r].len() {
                continue;
            }
            let (m, w) = ops[r][idx[r]];
            let (res_free, dur) = if phase[r] == 0 {
                (master_free, m)
            } else {
                (pool_free, w)
            };
            let start = if dur > 0.0 { ready[r].max(res_free) } else { ready[r] };
            if pick.map_or(true, |(s, _)| start < s) {
                pick = Some((start, r));
            }
        }
        let Some((start, r)) = pick else { break };
        let (m, w) = ops[r][idx[r]];
        if phase[r] == 0 {
            if idx[r] == 0 && shed_if(r, start) {
                idx[r] = ops[r].len();
                continue;
            }
            if m > 0.0 {
                master_free = start + m;
                ready[r] = master_free;
            }
            phase[r] = 1;
        } else {
            if w > 0.0 {
                pool_free = start + w;
                ready[r] = pool_free;
            }
            phase[r] = 0;
            idx[r] += 1;
            if idx[r] == ops[r].len() {
                done[r] = Some(ready[r]);
            }
        }
    }
    done
}

/// Engine knobs mirrored into the open-loop model: cross-request shard
/// coalescing, intra-worker concurrency, and watchdog hedging (the
/// `MasterConfig::coalesce`, `--worker-slots`, and `--hedge-quantile`
/// counterparts).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeKnobs {
    /// Max same-layer requests batched into one pool round (≤1 = off).
    /// A batch occupies the pool once for `w_max + β_co · Σ(others)`
    /// instead of Σ(all): the lead request pays its full phase (tail,
    /// messaging, dispatch overhead) and each extra payload adds only
    /// its *marginal* share `β_co` (per-subtask transmission + compute
    /// scaling; the straggler tail and per-dispatch fixed costs are paid
    /// once per batch — that is the amortization the real engine's
    /// multi-payload `WorkOrder` buys).
    pub coalesce: usize,
    /// Convs each worker keeps in flight (≤1 = sequential device). With
    /// ≥2 slots the pool station is only occupied for the *compute*
    /// share `β_cmp` of a round — receive/send latency overlaps the next
    /// round's compute, which is what a second in-flight conv buys; the
    /// request still experiences the full duration.
    pub worker_slots: usize,
    /// Watchdog hedge quantile (0 = off): subtasks past the q-quantile
    /// of their nominal phase model race a backup draw — the sim mirror
    /// of the engine's fitted-quantile hedged dispatch. Affects the
    /// phase *draws*, not the schedule, so it composes with both knobs
    /// above.
    pub hedge_quantile: f64,
}

impl Default for ServeKnobs {
    fn default() -> ServeKnobs {
        ServeKnobs {
            coalesce: 1,
            worker_slots: 1,
            hedge_quantile: 0.0,
        }
    }
}

impl ServeKnobs {
    fn active(&self) -> bool {
        self.coalesce.max(1) > 1 || self.worker_slots.max(1) > 1
    }
}

/// [`schedule_master_pool_open`] generalized to the engine knobs: the
/// pool serves same-stage requests in coalesced batches (lockstep
/// completion, amortized duration — see [`ServeKnobs::coalesce`]) and,
/// with worker slots, is only *occupied* for the compute share of each
/// round. `betas[stage] = (β_co, β_cmp)`. With both knobs at 1 this
/// reduces to the same earliest-feasible-start single-station schedule
/// (kept as the separate, byte-identical function for the existing
/// bitwise regression pins).
fn schedule_master_pool_knobs(
    ops: &[Vec<(f64, f64)>],
    release: &[f64],
    shed_if: impl Fn(usize, f64) -> bool,
    knobs: ServeKnobs,
    betas: &[(f64, f64)],
) -> Vec<Option<f64>> {
    let n_req = ops.len();
    let coalesce = knobs.coalesce.max(1);
    let overlap = knobs.worker_slots.max(1) > 1;
    let mut ready: Vec<f64> = release.to_vec();
    let mut idx = vec![0usize; n_req];
    let mut phase = vec![0u8; n_req]; // 0 = master op next, 1 = pool op next
    let mut master_free = 0.0f64;
    let mut pool_free = 0.0f64;
    let mut done: Vec<Option<f64>> = vec![None; n_req];
    loop {
        let mut pick: Option<(f64, usize)> = None;
        for r in 0..n_req {
            if idx[r] >= ops[r].len() {
                continue;
            }
            let (m, w) = ops[r][idx[r]];
            let (res_free, dur) = if phase[r] == 0 {
                (master_free, m)
            } else {
                (pool_free, w)
            };
            let start = if dur > 0.0 { ready[r].max(res_free) } else { ready[r] };
            if pick.map_or(true, |(s, _)| start < s) {
                pick = Some((start, r));
            }
        }
        let Some((start, r)) = pick else { break };
        let (m, w) = ops[r][idx[r]];
        if phase[r] == 0 {
            if idx[r] == 0 && shed_if(r, start) {
                idx[r] = ops[r].len();
                continue;
            }
            if m > 0.0 {
                master_free = start + m;
                ready[r] = master_free;
            }
            phase[r] = 1;
        } else if w > 0.0 {
            // Batch service: pull every same-stage request that is also
            // waiting on the pool and already ready, up to the cap.
            let stage = idx[r];
            let mut batch = vec![r];
            for r2 in 0..n_req {
                if batch.len() >= coalesce {
                    break;
                }
                if r2 != r
                    && idx[r2] == stage
                    && idx[r2] < ops[r2].len()
                    && phase[r2] == 1
                    && ready[r2] <= start
                {
                    batch.push(r2);
                }
            }
            let (beta_co, beta_cmp) = betas.get(stage).copied().unwrap_or((1.0, 1.0));
            let durs: Vec<f64> = batch.iter().map(|&b| ops[b][idx[b]].1).collect();
            let w_max = durs.iter().cloned().fold(0.0, f64::max);
            let sum: f64 = durs.iter().sum();
            let duration = w_max + beta_co * (sum - w_max);
            let occupancy = if overlap { beta_cmp * duration } else { duration };
            pool_free = start + occupancy;
            for &b in &batch {
                ready[b] = start + duration;
                phase[b] = 0;
                idx[b] += 1;
                if idx[b] == ops[b].len() {
                    done[b] = Some(ready[b]);
                }
            }
        } else {
            phase[r] = 0;
            idx[r] += 1;
            if idx[r] == ops[r].len() {
                done[r] = Some(ready[r]);
            }
        }
    }
    done
}

/// Open-loop serving simulation: Poisson arrivals at `rate` requests/s
/// into the serving stack, per-request sojourn recording, and — with a
/// relative `deadline` — predictive shedding at dispatch. Phase times
/// are drawn exactly like [`simulate_model`] in a fixed order (arrival
/// stream first, then per-request layer draws), so a fixed seed gives a
/// bitwise-reproducible trace per mode. Default [`ServeKnobs`]; see
/// [`simulate_serving_open_with`] for the coalescing / worker-slot
/// variants.
#[allow(clippy::too_many_arguments)]
pub fn simulate_serving_open(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    method: MethodSim,
    scenario: Scenario,
    mode: ServeSimMode,
    rate: f64,
    arrivals: usize,
    deadline: Option<f64>,
    rng: &mut Rng,
) -> Result<ServingSimResult> {
    simulate_serving_open_with(
        model,
        profile,
        n,
        method,
        scenario,
        mode,
        rate,
        arrivals,
        deadline,
        ServeKnobs::default(),
        rng,
    )
}

/// [`simulate_serving_open`] with explicit engine knobs. With the
/// default knobs the schedule (and the rng stream) is identical to the
/// plain entry point, so traces stay bitwise-pinned.
#[allow(clippy::too_many_arguments)]
pub fn simulate_serving_open_with(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    method: MethodSim,
    scenario: Scenario,
    mode: ServeSimMode,
    rate: f64,
    arrivals: usize,
    deadline: Option<f64>,
    knobs: ServeKnobs,
    rng: &mut Rng,
) -> Result<ServingSimResult> {
    anyhow::ensure!(rate > 0.0, "need a positive arrival rate");
    anyhow::ensure!(arrivals >= 1, "need at least one arrival");
    let (mut layer_cfg, local_mean) = plan_layers(model, profile, n, method, &scenario, rng)?;
    // The adaptive mode re-solves each layer's k on the drifted profile
    // the telemetry fit converges to — but, like the live `Replanner`,
    // leaves the type-1/type-2 classification alone. Static modes keep
    // the stale base-profile plan. (Only meaningful for the CoCoI
    // methods, whose k comes from the solver.)
    let fitted = straggling_profile(profile, scenario.lambda_tr());
    let adaptive = mode == ServeSimMode::PipelinedAdaptive
        && matches!(
            method,
            MethodSim::CocoiKCirc | MethodSim::CocoiKStar { .. } | MethodSim::AutoSelect
        );
    if adaptive {
        for (_, dims, k) in layer_cfg.iter_mut() {
            *k = solve_k_circ(dims, &fitted, n).k.clamp(1, n.min(dims.w_o));
        }
    }
    // Deadline predictions come from the profile the mode believes in.
    let pred_profile = if adaptive { fitted } else { *profile };
    let mut lt_cache = LtOverheadCache::new();

    // Arrival instants (Poisson process at `rate`).
    let mut t = 0.0;
    let release: Vec<f64> = (0..arrivals)
        .map(|_| {
            t += rng.exponential(rate);
            t
        })
        .collect();

    // Per-request phase draws, in arrival order (scheduling-independent).
    // `hedge = None` (the default knob) consumes no extra rng draws, so
    // unhedged traces stay bitwise-pinned.
    let hedge = (knobs.hedge_quantile > 0.0).then_some(knobs.hedge_quantile);
    let draws: Vec<Vec<(f64, f64, f64)>> = (0..arrivals)
        .map(|_| {
            layer_cfg
                .iter()
                .map(|(_, dims, k)| {
                    draw_layer(
                        method, dims, *k, profile, n, &scenario, hedge, &mut lt_cache, rng,
                    )
                })
                .collect()
        })
        .collect();

    // Op chains: the barrier serializes the whole service on one
    // resource; pipelined alternates [dec_{i-1}+enc_i] master ops with
    // pool phases (the same chain shape as `simulate_serving`).
    let ops: Vec<Vec<(f64, f64)>> = match mode {
        ServeSimMode::Barrier => draws
            .iter()
            .map(|layers| {
                let service: f64 = layers.iter().map(|(e, w, d)| e + w + d).sum();
                vec![(local_mean + service, 0.0)]
            })
            .collect(),
        _ => draws
            .iter()
            .map(|layers| {
                let l = layers.len();
                let mut chain = Vec::with_capacity(l + 1);
                for i in 0..l {
                    let m = if i == 0 {
                        local_mean + layers[0].0
                    } else {
                        layers[i - 1].2 + layers[i].0
                    };
                    chain.push((m, layers[i].1));
                }
                chain.push((if l == 0 { local_mean } else { layers[l - 1].2 }, 0.0));
                chain
            })
            .collect(),
    };

    // Shedding predictor: mean service under the *believed* profile —
    // the adaptive fit predicts the drifted system accurately; the
    // static modes mispredict under drift exactly like a stale plan.
    let predicted: f64 = match deadline {
        Some(_) => {
            local_mean
                + layer_cfg
                    .iter()
                    .map(|(_, dims, k)| l_integer(dims, &pred_profile, n, (*k).min(n)))
                    .sum::<f64>()
        }
        None => 0.0,
    };
    let shed_if = |r: usize, start: f64| match deadline {
        Some(d) => start + predicted > release[r] + d,
        None => false,
    };
    let completions = if !knobs.active() || mode == ServeSimMode::Barrier {
        // Byte-identical legacy path (the sim_regression/serving pins).
        schedule_master_pool_open(&ops, &release, shed_if)
    } else {
        // Per-layer amortization shares from a fixed-seed pilot: β_co is
        // the marginal (per-payload) share of a pool phase — one
        // subtask's receive+compute+send mean over the *whole* phase
        // mean (which also carries the straggler tail and messaging
        // overhead, both paid once per batch); β_cmp is the compute
        // share alone (what still serializes on a multi-slot device).
        let mut pilot_rng = Rng::new(0xC0A1E5CE);
        let pilots = 12;
        let betas: Vec<(f64, f64)> = layer_cfg
            .iter()
            .map(|(_, dims, k)| {
                let m_rec = profile.rec_dist(dims, *k).mean();
                let m_cmp = profile.cmp_dist(dims, *k).mean();
                let m_sen = profile.sen_dist(dims, *k).mean();
                let w_bar = (0..pilots)
                    .map(|_| {
                        draw_layer(
                            method,
                            dims,
                            *k,
                            profile,
                            n,
                            &scenario,
                            None,
                            &mut lt_cache,
                            &mut pilot_rng,
                        )
                        .1
                    })
                    .sum::<f64>()
                    / pilots as f64;
                let w_bar = w_bar.max(1e-12);
                (
                    ((m_rec + m_cmp + m_sen) / w_bar).clamp(0.05, 1.0),
                    (m_cmp / w_bar).clamp(0.05, 1.0),
                )
            })
            .collect();
        schedule_master_pool_knobs(&ops, &release, shed_if, knobs, &betas)
    };

    let mut latencies = Vec::with_capacity(arrivals);
    let mut shed = 0usize;
    for (r, c) in completions.iter().enumerate() {
        match c {
            Some(t_done) => latencies.push(t_done - release[r]),
            None => shed += 1,
        }
    }
    Ok(ServingSimResult {
        mode: mode.label(),
        scenario: scenario.label(),
        rate,
        latencies,
        shed,
        arrivals,
    })
}

// ====================================================================
// Multi-tenant serving: weighted fair sharing vs FIFO, per-tenant rng.
// ====================================================================

/// One tenant's offered load in [`simulate_serving_tenants`].
#[derive(Clone, Debug)]
pub struct TenantLoad {
    pub name: String,
    /// Poisson arrival rate (requests/second).
    pub rate: f64,
    /// Fair-share weight (the `MasterConfig::tenant_weights` mirror).
    pub weight: f64,
    /// Seed of this tenant's *private* rng stream. Arrivals and service
    /// draws come only from it, so a tenant's trace is bitwise-identical
    /// no matter who else shares the box — the starvation gate compares
    /// a victim's isolated run against its flooded run and any latency
    /// difference is pure scheduling interference, not different draws.
    pub seed: u64,
}

/// Per-tenant outcome of [`simulate_serving_tenants`].
#[derive(Clone, Debug)]
pub struct TenantSimResult {
    pub name: String,
    pub arrivals: usize,
    /// Requests shed at arrival (predicted sojourn past the deadline).
    pub shed: usize,
    /// Sojourn (arrival → completion) of every served request.
    pub latencies: Vec<f64>,
}

impl TenantSimResult {
    pub fn mean(&self) -> f64 {
        self.latencies.iter().sum::<f64>() / self.latencies.len().max(1) as f64
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.latencies, 0.50)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.latencies, 0.95)
    }
}

/// Multi-tenant open-loop serving: each tenant offers a Poisson stream
/// at its own rate and the serving stack is modelled as one station.
///
/// * `fair = true` — preemptive-resume weighted fair sharing (the fluid
///   limit of the engine's deficit-round-robin admission): at every
///   instant the backlogged tenants split the station proportionally to
///   weight, FIFO within a tenant. A tenant's worst-case drain rate is
///   its guaranteed share, so a flooding neighbour cannot starve it.
/// * `fair = false` — global arrival-FIFO, non-preemptive: the
///   pre-tenancy single-queue baseline, where a flooder's backlog sits
///   in front of everyone else's requests.
///
/// The DRR admission quantizes at whole requests while this mirror is
/// fluid, so the live engine adds at most one residual service time of
/// blocking on top of the fluid prediction — the 1.2× headroom in the
/// starvation gate covers exactly that quantization.
///
/// With a relative `deadline`, a request is shed at arrival when its
/// predicted sojourn — tenant backlog drained at the tenant's guaranteed
/// share (fair) or the global backlog (FIFO) — already exceeds it.
pub fn simulate_serving_tenants(
    model: &ModelSpec,
    profile: &SystemProfile,
    n: usize,
    method: MethodSim,
    scenario: Scenario,
    tenants: &[TenantLoad],
    horizon: f64,
    deadline: Option<f64>,
    fair: bool,
) -> Result<Vec<TenantSimResult>> {
    anyhow::ensure!(!tenants.is_empty(), "need at least one tenant");
    anyhow::ensure!(horizon > 0.0, "need a positive horizon");
    // The layer plan is shared and drawn from a dedicated rng so that
    // planning never perturbs any tenant's private stream.
    let mut plan_rng = Rng::new(0x7E4A_9C01);
    let (layer_cfg, local_mean) = plan_layers(model, profile, n, method, &scenario, &mut plan_rng)?;
    let mut lt_cache = LtOverheadCache::new();

    struct Job {
        tenant: usize,
        arrival: f64,
        service: f64,
        remaining: f64,
    }
    let mut jobs: Vec<Job> = Vec::new();
    let mut per_tenant_arrivals = vec![0usize; tenants.len()];
    for (ti, t) in tenants.iter().enumerate() {
        anyhow::ensure!(t.rate > 0.0, "tenant {} needs a positive rate", t.name);
        let mut rng = Rng::new(t.seed);
        let mut at = 0.0;
        let mut instants = Vec::new();
        loop {
            at += rng.exponential(t.rate);
            if at >= horizon {
                break;
            }
            instants.push(at);
        }
        per_tenant_arrivals[ti] = instants.len();
        for a in instants {
            let service: f64 = local_mean
                + layer_cfg
                    .iter()
                    .map(|(_, dims, k)| {
                        let (e, w, d) = draw_layer(
                            method, dims, *k, profile, n, &scenario, None, &mut lt_cache,
                            &mut rng,
                        );
                        e + w + d
                    })
                    .sum::<f64>();
            jobs.push(Job { tenant: ti, arrival: a, service, remaining: service });
        }
    }
    jobs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.tenant.cmp(&b.tenant)));

    // Weights clamped like `coordinator::fair` clamps DRR quanta: a zero
    // weight throttles, it does not starve.
    let w: Vec<f64> = tenants.iter().map(|t| t.weight.max(0.01)).collect();
    let w_all: f64 = w.iter().sum();

    let mut done: Vec<Option<f64>> = vec![None; jobs.len()];
    let mut shed = vec![0usize; tenants.len()];
    if fair {
        // Event-driven fluid weighted fair sharing: advance to the next
        // arrival or head-of-line completion, progressing every
        // backlogged tenant's head at rate weight/Σ(backlogged weights).
        let mut queues: Vec<std::collections::VecDeque<usize>> =
            vec![std::collections::VecDeque::new(); tenants.len()];
        let mut now = 0.0f64;
        let mut next = 0usize;
        loop {
            let backlogged: Vec<usize> =
                (0..tenants.len()).filter(|&ti| !queues[ti].is_empty()).collect();
            if backlogged.is_empty() {
                let Some(job) = jobs.get(next) else { break };
                now = job.arrival;
            }
            let w_active: f64 = backlogged.iter().map(|&ti| w[ti]).sum();
            let mut t_fin = f64::INFINITY;
            let mut fin_tenant = usize::MAX;
            for &ti in &backlogged {
                let j = *queues[ti].front().unwrap();
                let tf = now + jobs[j].remaining.max(0.0) * w_active / w[ti];
                if tf < t_fin {
                    t_fin = tf;
                    fin_tenant = ti;
                }
            }
            let t_arr = jobs.get(next).map_or(f64::INFINITY, |j| j.arrival);
            if t_arr == f64::INFINITY && backlogged.is_empty() {
                break;
            }
            let t_next = t_fin.min(t_arr);
            let dt = (t_next - now).max(0.0);
            for &ti in &backlogged {
                let j = *queues[ti].front().unwrap();
                jobs[j].remaining -= dt * w[ti] / w_active;
            }
            now = t_next;
            if t_arr <= t_fin {
                // Admission: shed when even the guaranteed share cannot
                // drain the tenant's backlog plus this request in time.
                let ti = jobs[next].tenant;
                let backlog: f64 = queues[ti].iter().map(|&j| jobs[j].remaining).sum();
                let drains = (backlog + jobs[next].service) * w_all / w[ti];
                if deadline.is_some_and(|d| drains > d) {
                    shed[ti] += 1;
                } else {
                    queues[ti].push_back(next);
                }
                next += 1;
            } else {
                let j = queues[fin_tenant].pop_front().unwrap();
                done[j] = Some(now);
            }
        }
    } else {
        // Non-preemptive global FIFO: one backlog, arrival order.
        let mut server_free = 0.0f64;
        for (ji, job) in jobs.iter().enumerate() {
            let start = job.arrival.max(server_free);
            if deadline.is_some_and(|d| start + job.service - job.arrival > d) {
                shed[job.tenant] += 1;
                continue;
            }
            server_free = start + job.service;
            done[ji] = Some(server_free);
        }
    }

    let mut out: Vec<TenantSimResult> = tenants
        .iter()
        .enumerate()
        .map(|(ti, t)| TenantSimResult {
            name: t.name.clone(),
            arrivals: per_tenant_arrivals[ti],
            shed: shed[ti],
            latencies: Vec::new(),
        })
        .collect();
    for (ji, job) in jobs.iter().enumerate() {
        if let Some(t_done) = done[ji] {
            out[job.tenant].latencies.push(t_done - job.arrival);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn quick(
        method: MethodSim,
        scenario: Scenario,
        seed: u64,
    ) -> ModelSimResult {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(seed);
        simulate_model(&model, &p, 10, method, scenario, 8, &mut rng).unwrap()
    }

    #[test]
    fn all_methods_produce_finite_latencies() {
        for method in [
            MethodSim::CocoiKCirc,
            MethodSim::Uncoded,
            MethodSim::Replication,
            MethodSim::LtCoarse,
        ] {
            let r = quick(method, Scenario::None, 1);
            assert_eq!(r.trials.len(), 8);
            assert!(
                r.trials.iter().all(|t| t.is_finite() && *t > 0.0),
                "{}: {:?}",
                r.method,
                r.trials
            );
        }
    }

    /// `--scheme auto`'s sim mirror: calm draws are bitwise those of
    /// CoCoI-k° (the selector keeps the MDS plan), failure-scenario
    /// draws are bitwise those of LtCoI-k_s (the churn flip). Both
    /// delegations share the rng stream, so equality is exact.
    #[test]
    fn auto_select_delegates_bitwise() {
        let calm_auto = quick(MethodSim::AutoSelect, Scenario::None, 7);
        let calm_circ = quick(MethodSim::CocoiKCirc, Scenario::None, 7);
        assert_eq!(calm_auto.trials, calm_circ.trials);

        let churn = Scenario::Failures { n_f: 2 };
        let churn_auto = quick(MethodSim::AutoSelect, churn, 7);
        let churn_lt = quick(MethodSim::LtCoarse, churn, 7);
        assert_eq!(churn_auto.trials, churn_lt.trials);
    }

    #[test]
    fn straggling_hurts_uncoded_more_than_cocoi() {
        // The headline qualitative claim (Fig. 5): under strong straggling
        // CoCoI beats uncoded; with (almost) none, uncoded wins slightly.
        let calm_unc = quick(MethodSim::Uncoded, Scenario::None, 3).mean();
        let calm_coc = quick(MethodSim::CocoiKCirc, Scenario::None, 3).mean();
        let hard_unc = quick(
            MethodSim::Uncoded,
            Scenario::Straggling { lambda_tr: 1.0 },
            3,
        )
        .mean();
        let hard_coc = quick(
            MethodSim::CocoiKCirc,
            Scenario::Straggling { lambda_tr: 1.0 },
            3,
        )
        .mean();
        // Relative degradation must be worse for uncoded.
        let unc_blowup = hard_unc / calm_unc;
        let coc_blowup = hard_coc / calm_coc;
        assert!(
            unc_blowup > coc_blowup,
            "uncoded blowup {unc_blowup:.2} vs cocoi {coc_blowup:.2}"
        );
    }

    /// The pipelined engine can only hide master work behind worker
    /// phases, never add time: per-trial makespans are ≤ the barrier's
    /// (same seed ⇒ identical phase draws), and strictly better on mean.
    #[test]
    fn pipelined_serving_never_slower_than_barrier() {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        for scenario in [Scenario::None, Scenario::Failures { n_f: 1 }] {
            let run = |pipelined: bool| {
                let mut rng = Rng::new(11);
                simulate_serving(
                    &model,
                    &p,
                    10,
                    MethodSim::CocoiKCirc,
                    scenario,
                    4,
                    pipelined,
                    6,
                    &mut rng,
                )
                .unwrap()
            };
            let barrier = run(false);
            let pipe = run(true);
            for (b, q) in barrier.trials.iter().zip(&pipe.trials) {
                assert!(q <= &(b * (1.0 + 1e-9)), "pipelined {q} > barrier {b}");
            }
            assert!(pipe.mean() < barrier.mean());
        }
    }

    /// Degenerate serving case: one request, pipelined == barrier totals.
    #[test]
    fn single_request_serving_matches_sum() {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let run = |pipelined: bool| {
            let mut rng = Rng::new(5);
            simulate_serving(
                &model,
                &p,
                10,
                MethodSim::CocoiKCirc,
                Scenario::None,
                1,
                pipelined,
                4,
                &mut rng,
            )
            .unwrap()
        };
        let barrier = run(false);
        let pipe = run(true);
        for (b, q) in barrier.trials.iter().zip(&pipe.trials) {
            assert!((b - q).abs() < 1e-9, "barrier {b} vs pipelined {q}");
        }
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.95) - 3.85).abs() < 1e-12);
        assert!(percentile(&[], 0.5).is_nan());
    }

    fn open(
        mode: ServeSimMode,
        rate: f64,
        arrivals: usize,
        deadline: Option<f64>,
        seed: u64,
    ) -> ServingSimResult {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(seed);
        simulate_serving_open(
            &model,
            &p,
            10,
            MethodSim::CocoiKCirc,
            Scenario::Straggling { lambda_tr: 0.5 },
            mode,
            rate,
            arrivals,
            deadline,
            &mut rng,
        )
        .unwrap()
    }

    /// A lone request overlaps with nothing: its pipelined chain and the
    /// barrier's serialized service are the same sum, so the sojourn is
    /// identical (and equals the service time).
    #[test]
    fn open_loop_single_request_same_in_both_modes() {
        let b = open(ServeSimMode::Barrier, 1e-6, 1, None, 3);
        let p = open(ServeSimMode::Pipelined, 1e-6, 1, None, 3);
        assert_eq!(b.latencies.len(), 1);
        assert_eq!(p.latencies.len(), 1);
        assert!((b.latencies[0] - p.latencies[0]).abs() < 1e-9);
        assert_eq!(b.shed, 0);
    }

    /// Fixed seed ⇒ bitwise-identical open-loop trace.
    #[test]
    fn open_loop_trace_is_reproducible() {
        for mode in [ServeSimMode::Pipelined, ServeSimMode::PipelinedAdaptive] {
            let a = open(mode, 0.01, 24, Some(200.0), 7);
            let b = open(mode, 0.01, 24, Some(200.0), 7);
            assert_eq!(a.latencies.len(), b.latencies.len());
            for (x, y) in a.latencies.iter().zip(&b.latencies) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert_eq!(a.shed, b.shed);
        }
    }

    /// Mean isolated service time (requests far enough apart that they
    /// never overlap) — the load scale for the open-loop tests.
    fn isolated_service(seed: u64) -> f64 {
        let r = open(ServeSimMode::Barrier, 1e-9, 16, None, seed);
        r.latencies.iter().sum::<f64>() / r.latencies.len() as f64
    }

    /// At-and-above the barrier's saturation point — the regime that
    /// motivates pipelined serving — the pipelined schedule must beat
    /// the serialized barrier on tail latency (the serving experiment's
    /// CI gate, pinned here at test scale). Below saturation both are
    /// stable and the FIFO barrier keeps the classic tail advantage for
    /// near-deterministic service times; the pipelined win there is
    /// capacity headroom, not per-request latency.
    #[test]
    fn open_loop_pipelined_p95_not_worse_than_barrier_at_saturation() {
        let service = isolated_service(5);
        for rho in [1.15, 1.35] {
            let rate = rho / service;
            let b = open(ServeSimMode::Barrier, rate, 200, None, 11);
            let p = open(ServeSimMode::Pipelined, rate, 200, None, 11);
            assert_eq!(b.shed + p.shed, 0);
            assert!(
                p.p95() <= b.p95() * (1.0 + 1e-9),
                "rho={rho}: pipelined p95 {} > barrier p95 {}",
                p.p95(),
                b.p95()
            );
        }
    }

    fn open_knobs(
        mode: ServeSimMode,
        rate: f64,
        arrivals: usize,
        knobs: ServeKnobs,
        seed: u64,
    ) -> ServingSimResult {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let mut rng = Rng::new(seed);
        simulate_serving_open_with(
            &model,
            &p,
            10,
            MethodSim::CocoiKCirc,
            Scenario::Straggling { lambda_tr: 0.5 },
            mode,
            rate,
            arrivals,
            None,
            knobs,
            &mut rng,
        )
        .unwrap()
    }

    /// Default knobs through the `_with` entry point are bitwise the
    /// plain entry point (the legacy schedule is reused verbatim).
    #[test]
    fn default_knobs_are_bitwise_transparent() {
        let a = open(ServeSimMode::Pipelined, 0.01, 24, None, 9);
        let b = open_knobs(ServeSimMode::Pipelined, 0.01, 24, ServeKnobs::default(), 9);
        assert_eq!(a.latencies.len(), b.latencies.len());
        for (x, y) in a.latencies.iter().zip(&b.latencies) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// The coalescing arm's CI gate at test scale: at and beyond the
    /// barrier's saturation point, batching same-layer rounds must not
    /// lose on p95 to the uncoalesced pipelined schedule — the batch
    /// amortizes the straggler tail and per-dispatch overhead across
    /// its members, which is pure capacity at overload.
    #[test]
    fn coalesced_p95_not_worse_than_uncoalesced_at_saturation() {
        let service = isolated_service(5);
        for rho in [1.15, 1.35] {
            let rate = rho / service;
            let plain = open_knobs(
                ServeSimMode::Pipelined,
                rate,
                200,
                ServeKnobs::default(),
                11,
            );
            let coal = open_knobs(
                ServeSimMode::Pipelined,
                rate,
                200,
                ServeKnobs {
                    coalesce: 4,
                    ..ServeKnobs::default()
                },
                11,
            );
            assert!(
                coal.p95() <= plain.p95() * (1.0 + 1e-9),
                "rho={rho}: coalesced p95 {} > uncoalesced p95 {}",
                coal.p95(),
                plain.p95()
            );
        }
    }

    /// Worker slots overlap transmission behind compute: at saturation
    /// a 2-slot pool must not be slower than the sequential device.
    #[test]
    fn worker_slots_not_worse_at_saturation() {
        let service = isolated_service(5);
        let rate = 1.25 / service;
        let plain = open_knobs(ServeSimMode::Pipelined, rate, 160, ServeKnobs::default(), 17);
        let slotted = open_knobs(
            ServeSimMode::Pipelined,
            rate,
            160,
            ServeKnobs {
                worker_slots: 2,
                ..ServeKnobs::default()
            },
            17,
        );
        assert!(
            slotted.p95() <= plain.p95() * (1.0 + 1e-9),
            "slotted p95 {} > sequential p95 {}",
            slotted.p95(),
            plain.p95()
        );
    }

    /// The reliability layer's sim mirror: under a chronic straggler,
    /// watchdog-hedged draws must beat the unhedged trace on tail *and*
    /// mean — every uncoded round waits on the slow worker's shard, and
    /// the backup draw races past it. Fixed seed: this is the serving
    /// experiment's hedging gate at test scale.
    #[test]
    fn hedged_tail_not_worse_under_chronic_straggler() {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        let scenario = Scenario::FailuresPlusStraggler {
            n_f: 0,
            slowdown: 3.0,
        };
        let run = |hedge_quantile: f64| {
            let mut rng = Rng::new(31);
            simulate_serving_open_with(
                &model,
                &p,
                10,
                MethodSim::Uncoded,
                scenario,
                ServeSimMode::Pipelined,
                0.01,
                40,
                None,
                ServeKnobs {
                    hedge_quantile,
                    ..ServeKnobs::default()
                },
                &mut rng,
            )
            .unwrap()
        };
        let plain = run(0.0);
        let hedged = run(0.9);
        assert_eq!(plain.shed + hedged.shed, 0);
        assert!(
            hedged.p95() <= plain.p95() * (1.0 + 1e-9),
            "hedged p95 {} > unhedged p95 {}",
            hedged.p95(),
            plain.p95()
        );
        assert!(
            hedged.mean() < plain.mean(),
            "hedged mean {} >= unhedged mean {}",
            hedged.mean(),
            plain.mean()
        );
    }

    /// Fixed seed ⇒ bitwise-identical trace with knobs on, too.
    #[test]
    fn knobs_trace_is_reproducible() {
        let knobs = ServeKnobs {
            coalesce: 3,
            worker_slots: 2,
            ..ServeKnobs::default()
        };
        let a = open_knobs(ServeSimMode::Pipelined, 0.02, 40, knobs, 23);
        let b = open_knobs(ServeSimMode::Pipelined, 0.02, 40, knobs, 23);
        assert_eq!(a.latencies.len(), b.latencies.len());
        for (x, y) in a.latencies.iter().zip(&b.latencies) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Overload + deadline ⇒ some requests are shed (but not all), and
    /// removing the deadline sheds none.
    #[test]
    fn open_loop_deadline_sheds_under_overload() {
        let service = isolated_service(5);
        let rate = 2.0 / service;
        let with = open(ServeSimMode::Barrier, rate, 60, Some(3.0 * service), 13);
        assert!(with.shed > 0, "overloaded barrier should shed");
        assert!(with.shed < with.arrivals, "not everything can be shed");
        assert_eq!(with.latencies.len() + with.shed, with.arrivals);
        let without = open(ServeSimMode::Barrier, rate, 60, None, 13);
        assert_eq!(without.shed, 0);
    }

    fn tenant(name: &str, rate: f64, weight: f64, seed: u64) -> TenantLoad {
        TenantLoad { name: name.to_string(), rate, weight, seed }
    }

    fn run_tenants(loads: &[TenantLoad], horizon: f64, fair: bool) -> Vec<TenantSimResult> {
        let model = zoo::model("vgg16").unwrap();
        let p = SystemProfile::paper_default();
        simulate_serving_tenants(
            &model,
            &p,
            10,
            MethodSim::CocoiKCirc,
            Scenario::None,
            loads,
            horizon,
            None,
            fair,
        )
        .unwrap()
    }

    /// A tenant's arrival/service draws come from its private seed, so
    /// its offered trace is the same whether it runs alone or next to a
    /// flooder — and a repeated run is bitwise-identical.
    #[test]
    fn tenant_streams_are_private_and_reproducible() {
        let service = isolated_service(5);
        let victim = tenant("victim", 0.25 / service, 1.0, 41);
        let horizon = 30.0 * service;
        let a = run_tenants(&[victim.clone()], horizon, true);
        let b = run_tenants(&[victim.clone()], horizon, true);
        assert!(a[0].arrivals > 0);
        assert_eq!(a[0].latencies.len(), b[0].latencies.len());
        for (x, y) in a[0].latencies.iter().zip(&b[0].latencies) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let flooder = tenant("flooder", 1.3 / service, 1.0, 42);
        let both = run_tenants(&[victim, flooder], horizon, true);
        assert_eq!(both[0].arrivals, a[0].arrivals);
    }

    /// The starvation gate at test scale: a trickle tenant weighted over
    /// a flooding tenant keeps near-isolated tail latency under fair
    /// sharing (its guaranteed share bounds the slowdown), while the
    /// pre-tenancy FIFO queue buries it behind the flooder's backlog.
    #[test]
    fn fair_sharing_bounds_flood_interference() {
        let service = isolated_service(5);
        let horizon = 40.0 * service;
        let victim = tenant("victim", 0.25 / service, 16.0, 41);
        let flooder = tenant("flooder", 1.3 / service, 1.0, 42);
        let iso = run_tenants(&[victim.clone()], horizon, true);
        let fair = run_tenants(&[victim.clone(), flooder.clone()], horizon, true);
        let fifo = run_tenants(&[victim, flooder], horizon, false);
        assert!(
            fair[0].p95() <= 1.2 * iso[0].p95(),
            "fair victim p95 {} > 1.2x isolated {}",
            fair[0].p95(),
            iso[0].p95()
        );
        assert!(
            fifo[0].p95() > fair[0].p95(),
            "FIFO victim p95 {} should exceed fair {}",
            fifo[0].p95(),
            fair[0].p95()
        );
    }

    /// Weights shift capacity: two equally-overloaded tenants, 3:1
    /// weights ⇒ the heavy tenant's backlog grows slower, so its mean
    /// sojourn stays below the light tenant's.
    #[test]
    fn weights_shift_capacity_between_overloaded_tenants() {
        let service = isolated_service(5);
        let horizon = 30.0 * service;
        let heavy = tenant("heavy", 1.0 / service, 3.0, 51);
        let light = tenant("light", 1.0 / service, 1.0, 52);
        let out = run_tenants(&[heavy, light], horizon, true);
        assert!(
            out[0].mean() < out[1].mean(),
            "heavy mean {} should undercut light mean {}",
            out[0].mean(),
            out[1].mean()
        );
    }

    #[test]
    fn failures_hurt_uncoded() {
        let ok = quick(MethodSim::Uncoded, Scenario::None, 5).mean();
        let fail = quick(MethodSim::Uncoded, Scenario::Failures { n_f: 2 }, 5).mean();
        // Paper: 68-79% latency increase for uncoded at n_f = 2.
        assert!(fail > 1.2 * ok, "ok={ok:.1}s fail={fail:.1}s");
        let coc_ok = quick(MethodSim::CocoiKCirc, Scenario::None, 5).mean();
        let coc_fail =
            quick(MethodSim::CocoiKCirc, Scenario::Failures { n_f: 2 }, 5).mean();
        assert!(
            (coc_fail - coc_ok) / coc_ok < (fail - ok) / ok,
            "CoCoI must degrade less: cocoi {:.2}% vs uncoded {:.2}%",
            100.0 * (coc_fail - coc_ok) / coc_ok,
            100.0 * (fail - ok) / ok
        );
    }
}
