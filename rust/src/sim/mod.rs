//! Calibrated latency simulator.
//!
//! The paper's figures run VGG16/ResNet18 on 10 Raspberry Pis 20 times per
//! point; this testbed is one CPU core, so the figure-scale sweeps replay
//! the §III latency model (validated against the real execution path at
//! tiny scale — see EXPERIMENTS.md §Calibration) instead of wall-clock
//! executing 50-second inferences. Scenario semantics follow §V exactly.

pub mod adaptive;
pub mod runner;
pub mod scenario;

pub use adaptive::{simulate_adaptive, AdaptiveSimResult, DriftScenario};
pub use runner::{
    percentile, simulate_model, simulate_serving, simulate_serving_open,
    simulate_serving_open_with, simulate_serving_tenants, straggling_profile, MethodSim,
    ModelSimResult, ServeKnobs, ServeSimMode, ServingSimResult, TenantLoad, TenantSimResult,
};
pub use scenario::Scenario;
